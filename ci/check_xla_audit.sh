#!/usr/bin/env bash
# Thread-safety audit gate for the `xla-shared-client` cargo feature.
#
# Thin wrapper: the gate's logic (opt-in-only feature, the scheduler
# spawn-site ratchet, and the pinned-rev == rust/XLA_AUDIT == lockfile
# audit trail when CI enables the feature) lives in
# rust/tools/contract-lint (`xla-gate` subcommand) with unit-tested
# pass/fail fixtures — see docs/static-analysis.md. The tool is a
# zero-dependency binary, so this needs nothing but a Rust toolchain.
#
# Run from the repo root: ci/check_xla_audit.sh
set -euo pipefail

cd "$(dirname "$0")/.."

exec cargo run --quiet --manifest-path rust/tools/contract-lint/Cargo.toml -- xla-gate
