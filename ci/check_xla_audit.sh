#!/usr/bin/env bash
# Thread-safety audit gate for the `xla-shared-client` cargo feature.
#
# The feature turns on `unsafe impl Send/Sync` for the PJRT wrappers and
# real thread fan-out in the run scheduler. It is only sound against an
# audited xla-rs revision (see rust/XLA_AUDIT). This script enforces:
#
#   1. the feature is never in the crate's default feature set;
#   2. every scheduler entry point that spawns host threads over
#      xla-backed state (the WorkerPool scatter in rust/src/sched/mod.rs
#      and the RunQueue workers in rust/src/sched/queue.rs) carries the
#      feature cfg-gate in its file, so new thread fan-out cannot land
#      ungated;
#   3. if CI (workflows/Makefiles/scripts) builds with the feature, then
#      rust/Cargo.toml must pin `xla` to `rev = "<sha>"`, that sha must
#      equal the audited sha recorded in rust/XLA_AUDIT, and — when a
#      Cargo.lock is checked in — the lockfile must resolve xla to the
#      same sha.
#
# Run from the repo root: ci/check_xla_audit.sh
set -euo pipefail

cd "$(dirname "$0")/.."

FEATURE="xla-shared-client"
CARGO_TOML="rust/Cargo.toml"
AUDIT_FILE="rust/XLA_AUDIT"

fail() {
    echo "xla audit gate: FAIL — $1" >&2
    exit 1
}

[ -f "$CARGO_TOML" ] || fail "missing $CARGO_TOML"
[ -f "$AUDIT_FILE" ] || fail "missing $AUDIT_FILE (see rust/Cargo.toml, thread-safety gate)"

# 1. The feature must be strictly opt-in: never a default feature.
if sed -n '/^\[features\]/,/^\[/p' "$CARGO_TOML" \
    | grep -E '^default *=' | grep -q "$FEATURE"; then
    fail "$FEATURE is in the crate's default features; it must stay opt-in"
fi

# 2. Probe the scheduler's thread entry points — a *ratchet*, not just a
# presence check: each scheduler file carries an audited count of
# `thread::spawn`/`thread::scope` sites (all of which are cfg-gated on
# the feature today). A new spawn site in either file fails CI until a
# human verifies it is gated and bumps the count here, so ungated
# fan-out over shared xla state cannot land silently. Audited sites:
#   sched/mod.rs   1 — WorkerPool::scatter's thread::scope (cfg-gated)
#   sched/queue.rs 2 — RunQueue worker thread::spawn (cfg-gated) + the
#                      gated-only concurrent-submitters test's scope
#                      (the preempt/park/resume, completions-stream, and
#                      backpressure machinery reuses these workers and
#                      the queue's condvars — zero new spawn sites)
# (The data pipeline spawns plain host threads over host-only data; it
# is deliberately not probed.)
for spec in "rust/src/sched/mod.rs:1" "rust/src/sched/queue.rs:2"; do
    f="${spec%%:*}"
    want="${spec##*:}"
    [ -f "$f" ] || fail "probe list out of date: missing $f"
    got=$(grep -cE 'thread::(spawn|scope)' "$f" || true)
    [ "$got" = "$want" ] || fail "$f has $got thread entry points, audited count is $want — \
new spawn sites must be cfg-gated on $FEATURE and the audited count updated here"
    grep -q "feature = \"$FEATURE\"" "$f" \
        || fail "$f spawns threads but carries no $FEATURE cfg-gate"
done

# Does anything under CI control enable the feature? Look at workflows and
# any Makefile/scripts that invoke cargo. Compile-only `cargo check` lines
# are exempt: type-checking the unsafe impls and the threaded scatter runs
# nothing, so it is sound against any xla revision — and it is how CI keeps
# the gated path from rotting while the feature stays off.
enabled_by=""
for f in .github/workflows/*.yml .github/workflows/*.yaml Makefile rust/Makefile ci/*.sh; do
    [ -f "$f" ] || continue
    case "$f" in */check_xla_audit.sh) continue ;; esac
    # Match --features/--all-features and cargo's -F shorthand in all its
    # spellings (-F feat, -F=feat, -Ffeat).
    if grep -E -- "--all-features|(--features|[[:space:]'\"]-F)[= ]?[^#]*$FEATURE" "$f" \
        | grep -vE "cargo +check" | grep -q .; then
        enabled_by="$f"
        break
    fi
done

if [ -z "$enabled_by" ]; then
    echo "xla audit gate: OK — $FEATURE not enabled anywhere in CI; default"
    echo "builds compile the scheduler without thread fan-out (sound against"
    echo "any xla revision)."
    exit 0
fi

echo "xla audit gate: $enabled_by builds with $FEATURE — verifying the audit trail"

# 3a. Cargo.toml must pin a rev (a floating branch cannot be audited).
pinned=$(grep -E '^xla *=' "$CARGO_TOML" | grep -oE 'rev *= *"[0-9a-f]{7,40}"' \
    | grep -oE '[0-9a-f]{7,40}' || true)
[ -n "$pinned" ] || fail "$enabled_by enables $FEATURE but $CARGO_TOML does not pin xla to a rev (still floating on a branch)"

# 3b. The pinned rev must be the audited one.
audited=$(grep -vE '^\s*(#|$)' "$AUDIT_FILE" | head -n 1 | tr -d '[:space:]')
[ -n "$audited" ] && [ "$audited" != "none" ] \
    || fail "$enabled_by enables $FEATURE but $AUDIT_FILE records no audited rev"
[ "$pinned" = "$audited" ] \
    || fail "pinned xla rev ($pinned) != audited rev ($audited) in $AUDIT_FILE"

# 3c. If a lockfile is checked in, it must resolve xla to the audited rev.
for lock in rust/Cargo.lock Cargo.lock; do
    [ -f "$lock" ] || continue
    if ! grep -A2 '^name = "xla"' "$lock" | grep -q "$audited"; then
        fail "$lock resolves xla to a different rev than the audited $audited"
    fi
done

echo "xla audit gate: OK — $FEATURE is backed by audited rev $audited"
