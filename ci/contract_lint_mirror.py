#!/usr/bin/env python3
"""Toolchain-free mirror of rust/tools/contract-lint.

CI runs the Rust binary (it builds with nothing but rustc); this script
re-implements the same scanner and rules in Python so the committed
artifacts (rust/UNSAFE_LEDGER, rust/CONTRACT_ALLOW) can be generated and
sanity-checked in environments without a Rust toolchain. The Rust tool
is the source of truth — if the two ever disagree, fix the mirror.

Usage (from the repo root):
    python3 ci/contract_lint_mirror.py check      # rules + allowlist + ledger drift
    python3 ci/contract_lint_mirror.py ledger     # print the generated UNSAFE_LEDGER
    python3 ci/contract_lint_mirror.py ledger --write
    python3 ci/contract_lint_mirror.py findings   # raw findings + allowlist-entry counts
"""

import os
import sys
from collections import OrderedDict

# --------------------------------------------------------------- scanner
# Mirrors rust/tools/contract-lint/src/scan.rs

def blank_noncode(content):
    """Blank comments and string/char-literal contents to spaces,
    preserving line structure and delimiter characters."""
    CODE, LINE, BLOCK, STR, RAWSTR = 0, 1, 2, 3, 4
    b = list(content)
    out = []
    st, depth, hashes = CODE, 0, 0
    i, n = 0, len(b)

    def is_raw_string_start(i):
        if i > 0 and (b[i - 1].isalnum() or b[i - 1] == "_"):
            return False
        j = i + 1
        if b[i] == "b" and j < n and b[j] == "r":
            j += 1
        elif b[i] == "b":
            return False
        while j < n and b[j] == "#":
            j += 1
        return j < n and b[j] == '"' and b[i] in ("r", "b")

    while i < n:
        c = b[i]
        nxt = b[i + 1] if i + 1 < n else None
        if st == CODE:
            if c == "/" and nxt == "/":
                st = LINE
                out.append("  ")
                i += 2
            elif c == "/" and nxt == "*":
                st, depth = BLOCK, 1
                out.append("  ")
                i += 2
            elif c == '"':
                st = STR
                out.append('"')
                i += 1
            elif c in ("r", "b") and is_raw_string_start(i):
                j = i + 1
                if j < n and b[j] == "r":
                    j += 1
                hashes = 0
                while j < n and b[j] == "#":
                    hashes += 1
                    j += 1
                out.append("".join(b[i : j + 1]))
                st = RAWSTR
                i = j + 1
            elif c == "'":
                if nxt == "\\":
                    out.append("'")
                    i += 1
                    while i < n and b[i] != "'":
                        if b[i] == "\\" and i + 1 < n:
                            out.append("  ")
                            i += 2
                        else:
                            out.append("\n" if b[i] == "\n" else " ")
                            i += 1
                    if i < n:
                        out.append("'")
                        i += 1
                elif i + 2 < n and b[i + 2] == "'" and nxt is not None:
                    out.append("'")
                    out.append("\n" if nxt == "\n" else " ")
                    out.append("'")
                    i += 3
                else:
                    out.append("'")
                    i += 1
            else:
                out.append(c)
                i += 1
        elif st == LINE:
            if c == "\n":
                st = CODE
                out.append("\n")
            else:
                out.append(" ")
            i += 1
        elif st == BLOCK:
            if c == "/" and nxt == "*":
                depth += 1
                out.append("  ")
                i += 2
            elif c == "*" and nxt == "/":
                depth -= 1
                if depth == 0:
                    st = CODE
                out.append("  ")
                i += 2
            else:
                out.append("\n" if c == "\n" else " ")
                i += 1
        elif st == STR:
            if c == "\\":
                out.append(" ")
                if nxt is not None:
                    out.append("\n" if nxt == "\n" else " ")
                i += 2
            elif c == '"':
                st = CODE
                out.append('"')
                i += 1
            else:
                out.append("\n" if c == "\n" else " ")
                i += 1
        else:  # RAWSTR
            if c == '"' and all(
                i + k < n and b[i + k] == "#" for k in range(1, hashes + 1)
            ):
                out.append("".join(b[i : i + hashes + 1]))
                st = CODE
                i += hashes + 1
            else:
                out.append("\n" if c == "\n" else " ")
                i += 1
    return "".join(out).split("\n")


def test_mask(code):
    mask = [False] * len(code)
    i = 0
    while i < len(code):
        if code[i].lstrip().startswith("#[cfg(test)]"):
            depth, opened, j = 0, False, i
            while j < len(code):
                mask[j] = True
                for c in code[j]:
                    if c == "{":
                        depth += 1
                        opened = True
                    elif c == "}":
                        depth -= 1
                    elif c == ";" and not opened and depth == 0:
                        mask[j] = True
                        depth = -1
                if opened and depth <= 0:
                    break
                if depth < 0:
                    break
                j += 1
            i = j + 1
        else:
            i += 1
    return mask


class SourceFile:
    def __init__(self, rel, content):
        self.rel = rel
        self.raw = content.split("\n")
        self.code = blank_noncode(content)
        # rust's .lines() drops a trailing final newline's empty tail
        if self.raw and self.raw[-1] == "":
            self.raw.pop()
        if self.code and self.code[-1] == "":
            self.code.pop()
        assert len(self.raw) == len(self.code), rel
        self.test = test_mask(self.code)


def load_tree(root, sub):
    rels = []
    for dirpath, _dirnames, filenames in os.walk(os.path.join(root, sub)):
        for name in filenames:
            if name.endswith(".rs"):
                full = os.path.join(dirpath, name)
                rels.append(os.path.relpath(full, root).replace(os.sep, "/"))
    out = []
    for rel in sorted(rels):
        with open(os.path.join(root, rel), encoding="utf-8") as f:
            out.append(SourceFile(rel, f.read()))
    return out


def token_hits(line, token):
    self_delimiting = token.startswith(".")
    hits, frm = [], 0
    while True:
        pos = line.find(token, frm)
        if pos < 0:
            return hits
        pre = line[pos - 1] if pos > 0 else None
        if self_delimiting or pre is None or not (pre.isalnum() or pre in "_."):
            hits.append(pos)
        frm = pos + len(token)


def receiver_path(line, at):
    head = line[:at]
    start = 0
    for p in range(len(head) - 1, -1, -1):
        c = head[p]
        if not (c.isalnum() or c in "._"):
            start = p + 1
            break
    return head[start:].strip(".")


# ----------------------------------------------------------------- rules
# Mirrors rust/tools/contract-lint/src/rules.rs

CLIENT_PRIMS = [".execute_b(", ".to_literal_sync(", ".buffer_from_host_buffer("]
WRAPPER_RAWS = [".execute_raw(", ".execute_raw_donated(", ".execute_buffers(", ".download_output("]
RT_HELPERS = [".upload_f32(", ".upload_i32(", ".upload_scalar(", ".upload_tensor(", ".download_f32("]
METER_EXEMPT_FILE = "rust/src/runtime/mod.rs"


def meter_bypass(files):
    out = []
    for f in files:
        if f.rel == METER_EXEMPT_FILE:
            continue
        for i, line in enumerate(f.code):
            if f.test[i]:
                continue
            for tok in CLIENT_PRIMS + WRAPPER_RAWS:
                for _ in token_hits(line, tok):
                    out.append(("meter-bypass", f.rel, i + 1, tok, "raw transfer primitive"))
            for tok in RT_HELPERS:
                for at in token_hits(line, tok):
                    recv = receiver_path(line, at)
                    last = recv.rsplit(".", 1)[-1]
                    if last in ("rt", "runtime"):
                        out.append(("meter-bypass", f.rel, i + 1, tok, f"unmetered Runtime helper on `{recv}`"))
    return out


def is_unsafe_item(code_line):
    for at in token_hits(code_line, "unsafe"):
        rest = code_line[at + len("unsafe") :].lstrip()
        if rest.startswith(("impl", "fn", "trait", "{")) or rest == "":
            return True
    return False


def fnv1a64(s):
    h = 0xCBF29CE484222325
    for byte in s.encode("utf-8"):
        h ^= byte
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def unsafe_sites(files):
    out = []
    for f in files:
        for i, code in enumerate(f.code):
            if not is_unsafe_item(code):
                continue
            start = i
            while start > 0:
                t = f.raw[start - 1].lstrip()
                if t.startswith("//") or t.startswith("#["):
                    start -= 1
                else:
                    break
            ctx = [l.strip() for l in f.raw[start : i + 1]]
            safety = next((l for l in ctx if "SAFETY:" in l), None)
            rationale = ""
            if safety is not None:
                r = safety[safety.find("SAFETY:") + len("SAFETY:") :].strip()
                if len(r) > 160:
                    r = r[:157] + "..."
                rationale = r if r else "(see comment)"
            out.append(
                dict(file=f.rel, line=i + 1, has_safety=safety is not None,
                     rationale=rationale, hash=fnv1a64("\n".join(ctx)))
            )
    return out


LEDGER_HEADER = """\
# UNSAFE_LEDGER — generated by `contract-lint unsafe-ledger --write`. Do not edit by hand.
# One entry per `unsafe` item in rust/src: file:line|fnv1a64(comment+attrs+item)|rationale.
# CI regenerates this file and fails on any diff, so moving, adding, or rewording an
# unsafe item is always a reviewed change (docs/static-analysis.md, unsafe ledger).
"""


def generate_ledger(files):
    lines = [LEDGER_HEADER]
    for s in unsafe_sites(files):
        lines.append("%s:%d|%016x|%s\n" % (s["file"], s["line"], s["hash"], s["rationale"]))
    return "".join(lines)


def unsafe_safety(files):
    return [
        ("unsafe-safety", s["file"], s["line"], "unsafe", "`unsafe` item without a `// SAFETY:` comment")
        for s in unsafe_sites(files)
        if not s["has_safety"]
    ]


def donating_programs(model_py):
    out = set()
    for dict_name, suffix in (("PROGRAM_DONATE", ""), ("BATCHED_DONATE", "_batched")):
        inside = False
        for line in model_py.split("\n"):
            t = line.strip()
            if t.startswith(dict_name) and "{" in t:
                inside = True
                continue
            if inside:
                if t.startswith("}"):
                    inside = False
                    continue
                q0 = t.find('"')
                if q0 >= 0:
                    q1 = t.find('"', q0 + 1)
                    if q1 >= 0:
                        out.add(t[q0 + 1 : q1] + suffix)
    return sorted(out)


NONDONATED_EXEC = [".execute_raw(", ".execute_buffers(", ".execute_buffers_metered("]


def binding_idents(code):
    t = code.lstrip()
    if t.startswith("let "):
        rest = t[len("let ") :]
        eq = rest.find("=")
        if eq >= 0:
            words = []
            for w in __import__("re").split(r"[^A-Za-z0-9_]+", rest[:eq]):
                if w and w not in ("mut", "ref"):
                    words.append(w)
            return words
    colon = t.find(":")
    if colon > 0:
        head = t[:colon]
        if all(c.isalnum() or c == "_" for c in head):
            return [head]
    return []


def donation(files, donating):
    out = []
    for f in files:
        assoc = []
        for i, code in enumerate(f.code):
            if f.test[i]:
                continue
            for at in token_hits(code, ".program("):
                raw_tail = f.raw[i][at + len(".program(") :]
                q0 = raw_tail.find('"')
                if q0 < 0:
                    continue
                q1 = raw_tail.find('"', q0 + 1)
                if q1 < 0:
                    continue
                name = raw_tail[q0 + 1 : q1].split("{")[0]
                if name not in donating:
                    continue
                for ident in binding_idents(code):
                    assoc.append((ident, name))
        if not assoc:
            continue
        for i, code in enumerate(f.code):
            if f.test[i]:
                continue
            for tok in NONDONATED_EXEC:
                for at in token_hits(code, tok):
                    recv = receiver_path(code, at)
                    last = recv.rsplit(".", 1)[-1]
                    for ident, prog in assoc:
                        if ident == last:
                            out.append(("donation", f.rel, i + 1, tok, f"`{recv}` is donating program '{prog}'"))
                            break
    return out


QUEUE_LOCKS = {
    "pack_pool": ("queue.pack_pool", 10),
    "tenants": ("queue.tenants", 30),
    "running": ("queue.running", 32),
    "feed": ("stream.feed", 33),
    "streams": ("queue.streams", 34),
    "data": ("queue.pack_data", 38),
    "slot": ("queue.pack_data", 38),
    "windows": ("queue.windows", 41),
    "quotas": ("queue.quotas", 42),
    "quantum": ("queue.quantum", 43),
    "park_file": ("queue.park_file", 50),
}
MOD_LOCKS = {
    "cached": ("cache.map", 60),
    "slot": ("cache.slot", 45),
    "pins": ("cache.pins", 55),
    "queue": ("pool.queue", 70),
    "slots": ("pool.slots", 71),
}
REGISTRY = dict(
    [v for v in QUEUE_LOCKS.values()]
    + [v for v in MOD_LOCKS.values()]
    + [("queue.state", 20), ("handle.state", 35)]
)


def lock_name(rel, expr):
    cleaned = expr.strip().lstrip("&")
    if cleaned.startswith("mut "):
        cleaned = cleaned[4:]
    cleaned = cleaned.strip()
    if cleaned.startswith("self."):
        cleaned = cleaned[5:]
    segs = cleaned.split(".")
    last = segs[-1] if segs else ""
    if rel.endswith("sched/queue.rs"):
        if last == "state":
            if len(segs) >= 2 and segs[-2] == "shared":
                return ("queue.state", 20)
            return ("handle.state", 35)
        return QUEUE_LOCKS.get(last)
    if rel.endswith("sched/mod.rs"):
        return MOD_LOCKS.get(last)
    return None


def brace_delta(code):
    return code.count("{") - code.count("}")


def paren_arg(code, frm):
    depth, end = 1, frm
    for off, c in enumerate(code[frm:]):
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                end = frm + off
                break
    return code[frm:end]


def pure_binding_ident(head):
    if not head.startswith("let "):
        return None
    rest = head[len("let ") :]
    if rest.startswith("mut "):
        rest = rest[4:]
    eq = rest.find("=")
    if eq < 0:
        return None
    ident = rest[:eq].strip()
    if ident and all(c.isalnum() or c == "_" for c in ident):
        return ident
    return None


def lock_order(files):
    out = []
    for f in files:
        if "/sched/" not in f.rel:
            continue
        held = []  # (name, level, depth, ident_or_None)
        depth = 0
        for i, code in enumerate(f.code):
            if f.test[i]:
                depth += brace_delta(code)
                held = [h for h in held if h[2] <= depth]
                continue
            if token_hits(code, "fn ") and "(" in code:
                held = []
                j = i
                while j > 0:
                    t = f.raw[j - 1].lstrip()
                    if t.startswith("//") or t.startswith("#["):
                        marker = "contract-lint: holds "
                        pos = t.find(marker)
                        if pos >= 0:
                            name = t[pos + len(marker) :].split()[0]
                            if name in REGISTRY:
                                held.append((name, REGISTRY[name], depth + 1, None))
                            else:
                                out.append(("lock-order", f.rel, j, "holds-directive", f"unregistered lock {name}"))
                        j -= 1
                    else:
                        break
            for at in token_hits(code, "drop("):
                arg = paren_arg(code, at + len("drop(")).strip()
                held = [h for h in held if h[3] != arg]
            for at in token_hits(code, "lock("):
                arg = paren_arg(code, at + len("lock("))
                nl = lock_name(f.rel, arg)
                if nl is None:
                    out.append(("lock-order", f.rel, i + 1, "unregistered", f"lock(&{arg.strip()}) not in registry"))
                    continue
                name, level = nl
                for h in held:
                    if level <= h[1]:
                        out.append(
                            ("lock-order", f.rel, i + 1, name,
                             f"acquires `{name}` (level {level}) while holding `{h[0]}` (level {h[1]})")
                        )
                head = code[:at].lstrip()
                after = at + len("lock(") + len(arg) + 1
                tail_ok = code[after:].strip() == ";"
                if tail_ok:
                    ident = pure_binding_ident(head)
                    if ident:
                        held.append((name, level, depth + brace_delta(code[:at]), ident))
            depth += brace_delta(code)
            held = [h for h in held if h[2] <= depth]
    return out


# ------------------------------------------------------------- allowlist

def parse_allowlist(text):
    out = []
    for i, line in enumerate(text.split("\n")):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split("|", 4)
        if len(parts) != 5:
            raise SystemExit(f"CONTRACT_ALLOW:{i + 1}: expected rule|file|token|count|reason")
        out.append((parts[0].strip(), parts[1].strip(), parts[2].strip(), int(parts[3]), parts[4].strip()))
    return out


def apply_allowlist(findings, allow):
    grouped = OrderedDict()
    for rule, file, line, token, msg in sorted(findings, key=lambda x: (x[0], x[1], x[3], x[2])):
        grouped.setdefault((rule, file, token), []).append((line, msg))
    errors = []
    used = [False] * len(allow)
    for (rule, file, token), group in grouped.items():
        idx = next(
            (k for k, e in enumerate(allow) if e[0] == rule and e[1] == file and e[2] == token),
            None,
        )
        if idx is None:
            for line, msg in group:
                errors.append(f"[{rule}] {file}:{line}: {msg} (no CONTRACT_ALLOW entry)")
        else:
            used[idx] = True
            if len(group) != allow[idx][3]:
                errors.append(
                    f"[{rule}] {file}: {len(group)} site(s) of `{token}`, ratchet says {allow[idx][3]}"
                )
    for k, e in enumerate(allow):
        if not used[k]:
            errors.append(f"[stale-allowlist] {e[0]}|{e[1]}|{e[2]}|{e[3]} matches nothing")
    return errors


# ------------------------------------------------------------------ main

def main():
    cmd = sys.argv[1] if len(sys.argv) > 1 else "check"
    root = os.getcwd()
    if not os.path.isdir(os.path.join(root, "rust", "src")):
        raise SystemExit("run from the repo root")
    files = load_tree(root, "rust/src")

    if cmd == "ledger":
        text = generate_ledger(files)
        missing = unsafe_safety(files)
        for m in missing:
            print(f"[{m[0]}] {m[1]}:{m[2]}: {m[4]}", file=sys.stderr)
        if missing:
            raise SystemExit(1)
        if "--write" in sys.argv:
            with open(os.path.join(root, "rust", "UNSAFE_LEDGER"), "w", encoding="utf-8") as fh:
                fh.write(text)
            print("wrote rust/UNSAFE_LEDGER")
        else:
            sys.stdout.write(text)
        return

    with open(os.path.join(root, "python/compile/model.py"), encoding="utf-8") as fh:
        donating = donating_programs(fh.read())
    findings = meter_bypass(files) + unsafe_safety(files) + lock_order(files) + donation(files, donating)

    if cmd == "findings":
        counts = OrderedDict()
        for rule, file, line, token, msg in findings:
            print(f"[{rule}] {file}:{line}: {token}  {msg}")
            counts[(rule, file, token)] = counts.get((rule, file, token), 0) + 1
        print("\n# allowlist-entry shaped counts:")
        for (rule, file, token), c in sorted(counts.items()):
            print(f"{rule}|{file}|{token}|{c}|<reason>")
        return

    if cmd == "check":
        allow_path = os.path.join(root, "rust", "CONTRACT_ALLOW")
        allow_text = ""
        if os.path.exists(allow_path):
            with open(allow_path, encoding="utf-8") as fh:
                allow_text = fh.read()
        errors = apply_allowlist(findings, parse_allowlist(allow_text))
        ledger_path = os.path.join(root, "rust", "UNSAFE_LEDGER")
        if not os.path.exists(ledger_path):
            errors.append("rust/UNSAFE_LEDGER is missing")
        else:
            with open(ledger_path, encoding="utf-8") as fh:
                if fh.read() != generate_ledger(files):
                    errors.append("UNSAFE_LEDGER drift — regenerate")
        for e in errors:
            print(f"mirror: {e}", file=sys.stderr)
        if errors:
            raise SystemExit(1)
        print(f"mirror: OK — {len(files)} files, {len(findings)} finding(s) all allowlisted, ledger in sync")
        return

    raise SystemExit(f"unknown subcommand {cmd!r}")


if __name__ == "__main__":
    main()
