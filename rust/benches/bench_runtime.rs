//! Bench: PJRT runtime layer — artifact compile time, host↔device upload,
//! and raw program dispatch overhead (execute with cached inputs). This is
//! the floor under every training step; §Perf tracks the coordinator
//! overhead = (sgd_step wall) − (program execute wall). Each section also
//! reports the uploaded/downloaded bytes it moved per iteration, using the
//! runtime's transfer meters.

use std::path::{Path, PathBuf};
use std::time::Duration;

use fastforward::model::init::init_params;
use fastforward::runtime::{Artifact, ParamSet, Runtime};
use fastforward::util::bench::bench;
use fastforward::util::rng::Rng;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let root = artifacts_root();

    // compile latency (fresh Artifact each iteration)
    let s = bench("compile/ff-tiny_lora_r8/eval_loss", 0, 3, Duration::from_secs(2), || {
        let art = Artifact::load(&rt, &root.join("ff-tiny_lora_r8")).unwrap();
        art.program("eval_loss").unwrap();
    });
    println!("{}", s.report());

    let art = Artifact::load(&rt, &root.join("ff-tiny_lora_r8"))?;
    let man = &art.manifest;
    let vals = init_params(&man.config, 3);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals)?;
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals)?;
    let prog = art.program("eval_loss")?;
    let (b, t) = (man.config.model.eval_batch, man.config.model.seq_len);
    let mut rng = Rng::new(1);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(512) as i32).collect();
    let mask = vec![1.0f32; b * t];

    // upload cost for the full frozen set (dominates bytes)
    let t0 = rt.stats.snapshot();
    let s = bench("upload/frozen_params(~160K f32)", 1, 10, Duration::from_secs(1), || {
        let snap = fr.snapshot();
        fr.restore(&snap); // mark all host-ahead
        fr.device_buffers().unwrap();
    });
    let per = rt.stats.snapshot().since(&t0).per_iter(s.iters as u64 + 1);
    println!("{}", s.report());
    println!("    transfers/iter: {}", per.report());

    // dispatch with everything cached except the batch
    let t0 = rt.stats.snapshot();
    let s = bench("execute/eval_loss(cached params)", 2, 20, Duration::from_secs(2), || {
        let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
        let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tok);
        inputs.push(&msk);
        std::hint::black_box(prog.execute_buffers(&inputs).unwrap());
    });
    let per = rt.stats.snapshot().since(&t0).per_iter(s.iters as u64 + 2);
    println!("{}", s.report());
    println!("    transfers/iter: {}", per.report());

    // device-resident adam_apply: outputs retained as raw buffers, only
    // the trainable set synced back — the trainer's steady-state step.
    let adam = art.program("adam_apply")?;
    let mut m = ParamSet::zeros_like(&rt, &tr);
    let mut v = ParamSet::zeros_like(&rt, &tr);
    let grads: Vec<xla::PjRtBuffer> = tr
        .tensors()
        .iter()
        .map(|x| rt.upload_f32(&vec![1e-4f32; x.len()], &x.shape).unwrap())
        .collect();
    let lr = rt.upload_scalar(1e-3)?;
    let mut step = 0f32;
    let t0 = rt.stats.snapshot();
    let s = bench("adam_apply/device_resident(sync tr only)", 2, 10, Duration::from_secs(2), || {
        let step_buf = rt.upload_scalar(step).unwrap();
        step += 1.0;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(m.device_buffers().unwrap());
        inputs.extend(v.device_buffers().unwrap());
        inputs.push(&step_buf);
        inputs.extend(grads.iter());
        inputs.push(&lr);
        let outs = adam.execute_raw(&inputs).unwrap();
        drop(inputs);
        let mut outs = outs.into_iter();
        tr.adopt_all(&mut outs).unwrap();
        m.adopt_all(&mut outs).unwrap();
        v.adopt_all(&mut outs).unwrap();
        tr.sync_host().unwrap(); // Δ_W host view; m/v stay device-only
    });
    let per = rt.stats.snapshot().since(&t0).per_iter(s.iters as u64 + 2);
    println!("{}", s.report());
    println!("    transfers/adam_step: {}", per.report());
    println!(
        "    param uploads after warmup: tr={} m={} v={} (flat = no re-upload)",
        tr.upload_count(),
        m.upload_count(),
        v.upload_count()
    );
    Ok(())
}
