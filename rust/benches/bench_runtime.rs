//! Bench: PJRT runtime layer — artifact compile time, host↔device upload,
//! raw program dispatch overhead (execute with cached inputs), and the
//! donated steady-state optimizer step (grad_step → adam_apply with every
//! state/gradient buffer aliased in place). This is the floor under every
//! training step; §Perf tracks the coordinator overhead = (sgd_step wall)
//! − (program execute wall). Each section also reports the uploaded/
//! downloaded/donated bytes it moved per iteration, using the runtime's
//! transfer meters, and the whole run lands in `BENCH_runtime.json`
//! (next to Cargo.toml) so the perf trajectory is tracked across PRs.

use std::path::{Path, PathBuf};
use std::time::Duration;

use fastforward::model::init::init_params;
use fastforward::runtime::{Artifact, InputBuf, ParamSet, Runtime};
use fastforward::store::ArtifactStore;
use fastforward::util::bench::bench;
use fastforward::util::json::Json;
use fastforward::util::rng::Rng;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let mut report = Json::obj();

    // compile latency (fresh Artifact each iteration)
    let s = bench("compile/ff-tiny_lora_r8/eval_loss", 0, 3, Duration::from_secs(2), || {
        let art = Artifact::load(&rt, &root.join("ff-tiny_lora_r8")).unwrap();
        art.program("eval_loss").unwrap();
    });
    println!("{}", s.report());
    report = report.set("compile_eval_loss", s.to_json());

    let art = Artifact::load(&rt, &root.join("ff-tiny_lora_r8"))?;
    let man = &art.manifest;
    let vals = init_params(&man.config, 3);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals)?;
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals)?;
    let prog = art.program("eval_loss")?;
    let (b, t) = (man.config.model.eval_batch, man.config.model.seq_len);
    let mut rng = Rng::new(1);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(512) as i32).collect();
    let mask = vec![1.0f32; b * t];

    // upload cost for the full frozen set (dominates bytes)
    let t0 = rt.stats.snapshot();
    let s = bench("upload/frozen_params(~160K f32)", 1, 10, Duration::from_secs(1), || {
        let snap = fr.snapshot();
        fr.restore(&snap); // mark all host-ahead
        fr.device_buffers().unwrap();
    });
    let per = rt.stats.snapshot().since(&t0).per_iter(s.iters as u64 + 1);
    println!("{}", s.report());
    println!("    transfers/iter: {}", per.report());
    report = report
        .set("upload_frozen", s.to_json())
        .set("upload_frozen_transfers_per_iter", per.to_json());

    // dispatch with everything cached except the batch
    let t0 = rt.stats.snapshot();
    let s = bench("execute/eval_loss(cached params)", 2, 20, Duration::from_secs(2), || {
        let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
        let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tok);
        inputs.push(&msk);
        std::hint::black_box(prog.execute_buffers(&inputs).unwrap());
    });
    let per = rt.stats.snapshot().since(&t0).per_iter(s.iters as u64 + 2);
    println!("{}", s.report());
    println!("    transfers/iter: {}", per.report());
    report = report
        .set("execute_eval_loss", s.to_json())
        .set("execute_eval_loss_transfers_per_iter", per.to_json());

    // donated steady-state step: grad_step (raw) feeds adam_apply with
    // every state/gradient buffer donated in place — the trainer's hot
    // loop with a single micro-batch. Nothing but the 4-byte step scalar
    // is uploaded per iteration; gradients never exist host-side.
    let grad = art.program("grad_step")?;
    let adam = art.program("adam_apply")?;
    let mut m = ParamSet::zeros_like(&rt, &tr);
    let mut v = ParamSet::zeros_like(&rt, &tr);
    let (mb, t2) = (man.config.model.micro_batch, man.config.model.seq_len);
    let mtokens: Vec<i32> = (0..mb * t2).map(|_| rng.below(512) as i32).collect();
    let mtok = rt.upload_i32(&mtokens, &[mb, t2])?;
    let mmask = rt.upload_f32(&vec![1.0f32; mb * t2], &[mb, t2])?;
    let lr = rt.upload_scalar(1e-3)?;
    let mut step = 0f32;
    let t0 = rt.stats.snapshot();
    let s = bench(
        "grad_step+adam_apply/donated(device-resident)",
        2,
        10,
        Duration::from_secs(2),
        || {
            let step_buf = rt.upload_scalar(step).unwrap();
            step += 1.0;
            let mut ginputs: Vec<&xla::PjRtBuffer> = Vec::new();
            ginputs.extend(tr.device_buffers().unwrap());
            ginputs.extend(fr.device_buffers().unwrap());
            ginputs.push(&mtok);
            ginputs.push(&mtok);
            ginputs.push(&mmask);
            let gouts = grad.execute_raw(&ginputs).unwrap();
            drop(ginputs);
            let grads = gouts.into_iter().skip(1); // drop the loss leaf
            let tr_b = tr.take_device_buffers().unwrap();
            let m_b = m.take_device_buffers().unwrap();
            let v_b = v.take_device_buffers().unwrap();
            let mut inputs: Vec<InputBuf> = Vec::new();
            inputs.extend(tr_b.into_iter().map(InputBuf::Donated));
            inputs.extend(m_b.into_iter().map(InputBuf::Donated));
            inputs.extend(v_b.into_iter().map(InputBuf::Donated));
            inputs.push(InputBuf::Borrowed(&step_buf));
            inputs.extend(grads.map(InputBuf::Donated));
            inputs.push(InputBuf::Borrowed(&lr));
            let outs = adam.execute_raw_donated(inputs).unwrap();
            let mut outs = outs.into_iter();
            tr.adopt_all(&mut outs).unwrap();
            m.adopt_all(&mut outs).unwrap();
            v.adopt_all(&mut outs).unwrap();
        },
    );
    let per = rt.stats.snapshot().since(&t0).per_iter(s.iters as u64 + 2);
    println!("{}", s.report());
    println!("    transfers/adam_step: {}", per.report());
    println!(
        "    param uploads after warmup: tr={} m={} v={} (flat = no re-upload); \
         donated {} per step (state + grads reused in place)",
        tr.upload_count(),
        m.upload_count(),
        v.upload_count(),
        fastforward::runtime::human_bytes(per.donated_bytes),
    );
    report = report
        .set("donated_step", s.to_json())
        .set("donated_step_transfers_per_iter", per.to_json())
        .set(
            "donated_step_state_uploads",
            (tr.upload_count() + m.upload_count() + v.upload_count()) as i64,
        );

    // content-addressed store (docs/artifact-store.md): cold ingest (hash
    // + bundle + publish) vs warm materialize — the "second host" path
    // whose saving is everything the compile section above costs, on
    // every host after the first.
    let scratch = std::env::temp_dir().join(format!("ff-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    let store = ArtifactStore::open(scratch.join("store"))?;
    let key = "ff-tiny_lora_r8";
    let s = bench("store/ingest(cold hash+publish)", 0, 5, Duration::from_secs(2), || {
        // drop the whole store so every iteration re-hashes and re-writes
        let _ = std::fs::remove_dir_all(store.root());
        store.ingest_artifact(key, &root.join(key)).unwrap();
    });
    println!("{}", s.report());
    report = report.set("store_ingest_cold", s.to_json());

    // warm: populate once, then materialize onto a fresh "host" each
    // iteration — hash-verified in memory before a byte lands on disk
    store.ingest_artifact(key, &root.join(key))?;
    let warm0 = store.stats.snapshot();
    let mut host = 0usize;
    let s = bench("store/materialize(warm second host)", 0, 5, Duration::from_secs(2), || {
        let dest = scratch.join(format!("host-{host}")).join(key);
        host += 1;
        store.materialize_artifact(key, None, &dest).unwrap();
    });
    let delta = store.stats.snapshot().since(&warm0);
    println!("{}", s.report());
    println!("    {}", delta.report());
    report = report
        .set("store_materialize_warm", s.to_json())
        .set("store_materialize_warm_counters", delta.to_json());
    let _ = std::fs::remove_dir_all(&scratch);

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_runtime.json");
    std::fs::write(&out, report.to_string_pretty())?;
    println!("wrote {}", out.display());
    Ok(())
}
