//! Bench: PJRT runtime layer — artifact compile time, host↔device upload,
//! and raw program dispatch overhead (execute with cached inputs). This is
//! the floor under every training step; §Perf tracks the coordinator
//! overhead = (sgd_step wall) − (program execute wall).

use std::path::{Path, PathBuf};
use std::time::Duration;

use fastforward::model::init::init_params;
use fastforward::runtime::{Artifact, ParamSet, Runtime};
use fastforward::util::bench::bench;
use fastforward::util::rng::Rng;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let root = artifacts_root();

    // compile latency (fresh Artifact each iteration)
    let s = bench("compile/ff-tiny_lora_r8/eval_loss", 0, 3, Duration::from_secs(2), || {
        let art = Artifact::load(&rt, &root.join("ff-tiny_lora_r8")).unwrap();
        art.program("eval_loss").unwrap();
    });
    println!("{}", s.report());

    let art = Artifact::load(&rt, &root.join("ff-tiny_lora_r8"))?;
    let man = &art.manifest;
    let vals = init_params(&man.config, 3);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals)?;
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals)?;
    let prog = art.program("eval_loss")?;
    let (b, t) = (man.config.model.eval_batch, man.config.model.seq_len);
    let mut rng = Rng::new(1);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(512) as i32).collect();
    let mask = vec![1.0f32; b * t];

    // upload cost for the full frozen set (dominates bytes)
    let s = bench("upload/frozen_params(~160K f32)", 1, 10, Duration::from_secs(1), || {
        let snap = fr.snapshot();
        fr.restore(&snap); // mark all dirty
        fr.device_buffers().unwrap();
    });
    println!("{}", s.report());

    // dispatch with everything cached except the batch
    let s = bench("execute/eval_loss(cached params)", 2, 20, Duration::from_secs(2), || {
        let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
        let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tok);
        inputs.push(&msk);
        std::hint::black_box(prog.execute_buffers(&inputs).unwrap());
    });
    println!("{}", s.report());
    Ok(())
}
