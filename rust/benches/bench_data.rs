//! Bench: data substrate throughput — corpus generation, batch assembly,
//! and the threaded prefetch pipeline. The data path must never be the
//! bottleneck next to an optimizer step (DESIGN.md §Perf: L3 overhead <5%).

use std::time::Duration;

use fastforward::data::batcher::Batcher;
use fastforward::data::corpus::make_dataset;
use fastforward::data::pipeline::Pipeline;
use fastforward::util::bench::{bench, throughput};

fn main() {
    for task in ["medical", "instruct", "chat", "pile"] {
        let s = bench(&format!("corpus_gen/{task}/256ex"), 1, 5, Duration::from_millis(500), || {
            make_dataset(task, 512, 64, 256, 0, 0, 42).unwrap();
        });
        println!("{}  ({:.0} examples/s)", s.report(), throughput(&s, 256.0));
    }

    let ds = make_dataset("chat", 512, 64, 2048, 0, 0, 7).unwrap();
    let mut batcher = Batcher::new(&ds.train, 8, 32, 0);
    let s = bench("batcher/global32(micro8)", 2, 50, Duration::from_millis(500), || {
        std::hint::black_box(batcher.next_global());
    });
    println!("{}  ({:.0} batches/s)", s.report(), throughput(&s, 1.0));

    let mut pipe = Pipeline::spawn(ds.train.clone(), 8, 32, 0, 4);
    let s = bench("pipeline/prefetch_depth4", 2, 50, Duration::from_millis(500), || {
        std::hint::black_box(pipe.next());
    });
    println!("{}  ({:.0} batches/s)", s.report(), throughput(&s, 1.0));
}
