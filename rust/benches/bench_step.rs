//! Bench: end-to-end optimizer-step latency (the paper's train-time axis,
//! Fig 3). Measures the fused-vs-accumulated paths and per-micro-batch
//! grad_step latency on the tiny and small models.
//!
//! Run: `cargo bench --offline` (after `make artifacts`).

use std::path::{Path, PathBuf};
use std::time::Duration;

use fastforward::config::{presets, FfConfig};
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::Trainer;
use fastforward::util::bench::bench;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let rt = Runtime::cpu()?;
    let root = artifacts_root();

    for model in ["ff-tiny", "ff-small"] {
        let base = ensure_pretrained(&rt, &root, model, None)?;
        let mut cfg = presets::train_config(&format!("{model}_lora_r8"), "medical", 1)?;
        cfg.train_examples = 512;
        cfg.test_examples = 64;
        cfg.ff = FfConfig { enabled: false, ..FfConfig::default() };
        let mut t = Trainer::new(&rt, &root, cfg.clone(), Some(&base))?;

        let tokens_per_step = (cfg.global_batch * t.art.manifest.config.model.seq_len) as f64;
        let s = bench(
            &format!("sgd_step/{model}/global{}", cfg.global_batch),
            2,
            10,
            Duration::from_secs(3),
            || {
                t.sgd_step().unwrap();
            },
        );
        println!(
            "{}  ({:.0} tokens/s)",
            s.report(),
            tokens_per_step / s.mean_secs()
        );

        // val-set inference = one FF probe's cost
        let s = bench(
            &format!("ff_val_probe/{model}/32ex"),
            2,
            10,
            Duration::from_secs(2),
            || {
                t.eval_val().unwrap();
            },
        );
        println!("{}", s.report());
    }
    Ok(())
}
