//! Bench: end-to-end optimizer-step latency (the paper's train-time axis,
//! Fig 3) plus the host↔device traffic behind it. Measures per-step wall
//! time, uploaded/downloaded **bytes per Adam step** and **per FF probe**,
//! and asserts-by-printing the steady-state transfer contract
//! (docs/transfer-contract.md): param/optimizer upload counters stay flat,
//! and with device-side gradient accumulation the *only* bytes uploaded
//! per Adam step are the batch (tokens/targets/mask) plus the 4-byte step
//! scalar — no O(|trainable|) gradient upload.
//!
//! Run: `cargo bench --offline` (after `make artifacts`).

use std::path::{Path, PathBuf};
use std::time::Duration;

use fastforward::config::{presets, FfConfig};
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::Trainer;
use fastforward::util::bench::bench;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let rt = Runtime::cpu()?;
    let root = artifacts_root();

    for model in ["ff-tiny", "ff-small"] {
        let base = ensure_pretrained(&rt, &root, model, None)?;
        let mut cfg = presets::train_config(&format!("{model}_lora_r8"), "medical", 1)?;
        cfg.train_examples = 512;
        cfg.test_examples = 64;
        cfg.ff = FfConfig { enabled: false, ..FfConfig::default() };
        let mut t = Trainer::new(&rt, &root, cfg.clone(), Some(&base))?;

        let tokens_per_step = (cfg.global_batch * t.art.manifest.config.model.seq_len) as f64;
        // warm the device-resident state before measuring steady state
        t.sgd_step()?;
        let (state_ups_0, _) = t.state_transfer_counts();
        let tr0 = t.transfers();
        let s = bench(
            &format!("sgd_step/{model}/global{}", cfg.global_batch),
            2,
            10,
            Duration::from_secs(3),
            || {
                t.sgd_step().unwrap();
            },
        );
        let per_step = t.transfers().since(&tr0).per_iter(s.iters as u64 + 2);
        let (state_ups_1, state_downs) = t.state_transfer_counts();
        println!(
            "{}  ({:.0} tokens/s)",
            s.report(),
            tokens_per_step / s.mean_secs()
        );
        println!("    transfers/adam_step: {}", per_step.report());
        println!(
            "    state uploads {} → {} across {} steps ({}), state downloads {}",
            state_ups_0,
            state_ups_1,
            s.iters + 2,
            if state_ups_1 == state_ups_0 { "flat: device-resident" } else { "NOT FLAT" },
            state_downs,
        );
        // The transfer contract's acceptance line: with device-side
        // accumulation the per-step upload is the batch plus one 4-byte
        // step scalar — gradients (4·|trainable| bytes) never cross.
        let mc = &t.art.manifest.config.model;
        let n_micro = cfg.global_batch / mc.micro_batch;
        let batch_bytes =
            (n_micro * 3 * mc.micro_batch * mc.seq_len * 4 + 4) as u64;
        let grad_bytes = 4 * t.tr.numel() as u64;
        println!(
            "    upload/adam_step = {} vs batch-only expectation {} ({}); \
             host-path gradient upload would add {}",
            per_step.uploaded_bytes,
            batch_bytes,
            if per_step.uploaded_bytes == batch_bytes {
                "EXACT: batch data only"
            } else {
                "MISMATCH"
            },
            fastforward::runtime::human_bytes(grad_bytes),
        );

        // val-set inference = one FF probe's cost; batch buffers cached
        // after the first call, so steady-state probes upload nothing.
        t.eval_val()?; // builds the EvalCache
        let tr0 = t.transfers();
        let s = bench(
            &format!("ff_val_probe/{model}/32ex"),
            2,
            10,
            Duration::from_secs(2),
            || {
                t.eval_val().unwrap();
            },
        );
        let per_probe = t.transfers().since(&tr0).per_iter(s.iters as u64 + 2);
        println!("{}", s.report());
        println!("    transfers/ff_probe (fixed W): {}", per_probe.report());
    }
    Ok(())
}
