//! Bench: end-to-end optimizer-step latency (the paper's train-time axis,
//! Fig 3) plus the host↔device traffic behind it — in **both** step modes:
//!
//! * `sync`      — drain interval 1: every step blocks on its loss
//!   download (the pre-pipeline behaviour);
//! * `pipelined` — the engine's deferred-readback ring + batch prefetch:
//!   dispatch returns immediately, losses drain every K steps, and the
//!   next batch uploads while the current step executes.
//!
//! The pipelined mode must be no slower per step; the wall-clock delta is
//! the synchronization overhead the stream layer removed. Also measures
//! uploaded/downloaded **bytes per Adam step** and **per FF probe**, and
//! asserts-by-printing the steady-state transfer contract
//! (docs/transfer-contract.md): with device-side gradient accumulation the
//! *only* bytes uploaded per Adam step are the batch (tokens/targets/mask)
//! plus the 4-byte step scalar — prefetch moves the upload one step
//! earlier but does not change the total.
//!
//! Two sections added with the batched-stepping work:
//!
//! * **contraction orders** — per shape, the adapter FLOPs of one
//!   train-program call under the manifest's *recorded* order vs the
//!   rejected alternative (`flops::train_call_flops_for_orders`), so the
//!   emit-time argmin's saving is visible per artifact;
//! * **batched packing** — K independent same-artifact runs executed solo
//!   (K × ~3 dispatches/step: grad + finalize + adam) vs one
//!   `run_batched_group` call (2 dispatches/step for the whole group),
//!   reporting wall clock, dispatch counts, and per-run loss
//!   bit-identity.
//!
//! Results additionally land in `BENCH_step.json` (next to Cargo.toml) so
//! the perf trajectory is tracked across PRs instead of living only in
//! stdout. Run: `cargo bench --offline` (after `make artifacts`).

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastforward::config::{presets, FfConfig, TrainConfig};
use fastforward::flops::FlopsModel;
use fastforward::runtime::manifest::LoraOrder;
use fastforward::runtime::{Runtime, SyncReason};
use fastforward::sched::{ArtifactCache, RunSpec, WorkerPool};
use fastforward::train::engine::required_programs;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::{StopRule, Trainer};
use fastforward::train::{run_batched_group, MemberSpec};
use fastforward::util::bench::bench;
use fastforward::util::json::Json;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

const PIPELINE_DRAIN: usize = 8;

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let mut report = Json::obj();

    for model in ["ff-tiny", "ff-small"] {
        let base = ensure_pretrained(&rt, &root, model, None)?;
        let mut cfg = presets::train_config(&format!("{model}_lora_r8"), "medical", 1)?;
        cfg.train_examples = 512;
        cfg.test_examples = 64;
        cfg.ff = FfConfig { enabled: false, ..FfConfig::default() };
        let mut t = Trainer::new(&rt, &root, cfg.clone(), Some(&base))?;

        let tokens_per_step = (cfg.global_batch * t.art.manifest.config.model.seq_len) as f64;

        // -- sync mode: drain-every-1, the old blocking behaviour --------
        t.set_drain_interval(1);
        // warm the device-resident state before measuring steady state
        t.sgd_step()?;
        let (state_ups_0, _) = t.state_transfer_counts();
        let tr0 = t.transfers();
        let s_sync = bench(
            &format!("sgd_step/sync/{model}/global{}", cfg.global_batch),
            2,
            10,
            Duration::from_secs(3),
            || {
                t.sgd_step().unwrap();
            },
        );
        let per_step = t.transfers().since(&tr0).per_iter(s_sync.iters as u64 + 2);
        let (state_ups_1, state_downs) = t.state_transfer_counts();
        println!(
            "{}  ({:.0} tokens/s)",
            s_sync.report(),
            tokens_per_step / s_sync.mean_secs()
        );
        println!("    transfers/adam_step: {}", per_step.report());
        println!(
            "    state uploads {} → {} across {} steps ({}), state downloads {}",
            state_ups_0,
            state_ups_1,
            s_sync.iters + 2,
            if state_ups_1 == state_ups_0 { "flat: device-resident" } else { "NOT FLAT" },
            state_downs,
        );
        // The transfer contract's acceptance line: with device-side
        // accumulation the per-step upload is the batch plus one 4-byte
        // step scalar — gradients (4·|trainable| bytes) never cross.
        let mc = t.art.manifest.config.model.clone();
        let n_micro = cfg.global_batch / mc.micro_batch;
        let batch_bytes = (n_micro * 3 * mc.micro_batch * mc.seq_len * 4 + 4) as u64;
        let grad_bytes = 4 * t.trainable_numel() as u64;
        let batch_only = per_step.uploaded_bytes == batch_bytes;
        println!(
            "    upload/adam_step = {} vs batch-only expectation {} ({}); \
             host-path gradient upload would add {}",
            per_step.uploaded_bytes,
            batch_bytes,
            if batch_only { "EXACT: batch data only" } else { "MISMATCH" },
            fastforward::runtime::human_bytes(grad_bytes),
        );

        // -- pipelined mode: deferred readback + prefetch ----------------
        // Fresh trainer so the comparison starts from the same state.
        let mut tp = Trainer::new(&rt, &root, cfg.clone(), Some(&base))?;
        tp.set_drain_interval(PIPELINE_DRAIN);
        tp.sgd_step()?; // warm state; also primes the prefetch slot
        let tr0 = tp.transfers();
        let s_pipe = bench(
            &format!("sgd_step/pipelined-K{PIPELINE_DRAIN}/{model}/global{}", cfg.global_batch),
            2,
            10,
            Duration::from_secs(3),
            || {
                tp.dispatch_sgd_step().unwrap();
            },
        );
        // retire in-flight steps outside the timed region, then attribute
        // transfers over the dispatched count
        tp.drain_pending(SyncReason::Shutdown)?;
        let per_step_pipe = tp.transfers().since(&tr0).per_iter(s_pipe.iters as u64 + 2);
        println!(
            "{}  ({:.0} tokens/s)",
            s_pipe.report(),
            tokens_per_step / s_pipe.mean_secs()
        );
        println!("    transfers/adam_step: {}", per_step_pipe.report());
        println!("    stream: {}", tp.stream_stats().report());
        let speedup = s_sync.mean_secs() / s_pipe.mean_secs();
        println!(
            "    pipelined vs sync: {:.2}x per step ({})",
            speedup,
            if speedup >= 1.0 { "no slower: OK" } else { "SLOWER — pipeline regression" },
        );

        // val-set inference = one FF probe's cost; batch buffers cached
        // after the first call, so steady-state probes upload nothing.
        t.eval_val()?; // builds the EvalCache
        let tr0 = t.transfers();
        let s_probe = bench(
            &format!("ff_val_probe/{model}/32ex"),
            2,
            10,
            Duration::from_secs(2),
            || {
                t.eval_val().unwrap();
            },
        );
        let per_probe = t.transfers().since(&tr0).per_iter(s_probe.iters as u64 + 2);
        println!("{}", s_probe.report());
        println!("    transfers/ff_probe (fixed W): {}", per_probe.report());

        // -- contraction-order accounting: recorded vs alternative -------
        // The emit-time argmin picked one order per program; charge one
        // train call under the recorded order and under both pure
        // alternatives so the per-shape saving is visible.
        let fm = FlopsModel::for_manifest(&t.art.manifest);
        let orders = t.art.manifest.programs.get("grad_step").and_then(|p| p.lora_orders);
        let order_saving = orders.map(|rec| {
            let ac = &t.art.manifest.config;
            let chosen = fm.train_call_flops_for_orders(ac, rec.forward, rec.backward);
            let factored =
                fm.train_call_flops_for_orders(ac, LoraOrder::Factored, LoraOrder::Factored);
            let merged = fm.train_call_flops_for_orders(ac, LoraOrder::Merged, LoraOrder::Merged);
            let alt = factored.max(merged);
            println!(
                "    grad_step contraction order fwd={:?} bwd={:?}: adapter {:.3} MFLOP/call \
                 vs {:.3} MFLOP worst pure order ({:.2}x — {})",
                rec.forward,
                rec.backward,
                chosen as f64 / 1e6,
                alt as f64 / 1e6,
                alt as f64 / chosen as f64,
                if chosen <= factored.min(merged) {
                    "recorded order optimal"
                } else {
                    "NOT OPTIMAL — order selection regression"
                },
            );
            (rec, chosen, alt)
        });

        let mut mj = Json::obj()
            .set("tokens_per_step", tokens_per_step)
            .set("sync", s_sync.to_json())
            .set("pipelined", s_pipe.to_json())
            .set("pipelined_drain_interval", PIPELINE_DRAIN)
            .set("pipelined_speedup", speedup)
            .set("transfers_per_step_sync", per_step.to_json())
            .set("transfers_per_step_pipelined", per_step_pipe.to_json())
            .set("batch_bytes_expected", batch_bytes as i64)
            .set("upload_is_batch_only", batch_only)
            .set("state_uploads_flat", state_ups_1 == state_ups_0)
            .set("donations_per_step", per_step.donations as i64)
            .set("ff_probe", s_probe.to_json())
            .set("transfers_per_probe", per_probe.to_json());
        if let Some((rec, chosen, alt)) = order_saving {
            mj = mj
                .set("lora_order_fwd", format!("{:?}", rec.forward))
                .set("lora_order_bwd", format!("{:?}", rec.backward))
                .set("adapter_flops_per_call_recorded", chosen as i64)
                .set("adapter_flops_per_call_worst_order", alt as i64)
                .set("adapter_order_saving", alt as f64 / chosen as f64);
        }
        report = report.set(model, mj);
    }

    // -- batched packing: K solo runs vs one batched group call ----------
    // Same adapters, seeds, data, and step count. Solo issues ~3
    // dispatches per member per step (grad_step, grad_finalize,
    // adam_apply); the chained batched programs issue 2 per step for the
    // whole group, so dispatches/step shrink (3·K)/2-fold while per-run
    // losses stay bit-identical (also asserted in tests/sched_queue.rs and
    // `selftest --queue`).
    let cache = ArtifactCache::new(root.clone());
    let art = cache.load(&rt, "ff-tiny_lora_r8")?;
    let sizes = art.manifest.batched_group_sizes();
    if let Some(&k) = sizes.last() {
        let steps = 12usize;
        let base = Arc::new(ensure_pretrained(&rt, &root, "ff-tiny", None)?);
        let member_cfg = |seed: u64| -> anyhow::Result<TrainConfig> {
            let mut c = presets::train_config("ff-tiny_lora_r8", "medical", 1)?;
            c.train_examples = 256;
            c.test_examples = 32;
            // pack eligibility requires one micro-batch per Adam step
            c.global_batch = art.manifest.config.model.micro_batch;
            c.seed = seed;
            c.ff = FfConfig { enabled: false, ..FfConfig::default() };
            Ok(c)
        };
        // Pre-warm both program sets so neither timed path pays XLA
        // compilation.
        for prog in required_programs(&art.manifest) {
            art.program(prog)?;
        }
        for prog in ["grad_step", "adam_apply", "eval_loss"] {
            art.program(&format!("{prog}_batched{k}"))?;
        }

        let mut solo_specs = Vec::new();
        for i in 0..k {
            solo_specs.push(RunSpec {
                label: format!("solo/{i}"),
                cfg: member_cfg(0xbe7c + i as u64)?,
                stop: StopRule::MaxSteps(steps),
                base: Some(Arc::clone(&base)),
                drain_interval: None,
            });
        }
        let solo = WorkerPool::new(1).run_all(&rt, &cache, solo_specs)?;

        let members = (0..k)
            .map(|i| {
                Ok(MemberSpec {
                    label: format!("packed/{i}"),
                    cfg: member_cfg(0xbe7c + i as u64)?,
                    base: Some(Arc::clone(&base)),
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let t0 = Instant::now();
        let group = run_batched_group(&rt, &art, &members, steps)?;
        let group_wall = t0.elapsed().as_secs_f64();

        let identical = solo.outputs.iter().zip(group.iter()).all(|(s, g)| {
            s.sgd_losses.len() == g.sgd_losses.len()
                && s.sgd_losses
                    .iter()
                    .zip(&g.sgd_losses)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
                && s.summary.final_test_loss.to_bits() == g.summary.final_test_loss.to_bits()
        });
        let group_dispatches = group[0].dispatches;
        let solo_train_dispatches = 3 * steps * k; // grad + finalize + adam per member
        let packed_up: u64 = group.iter().map(|m| m.summary.transfers.uploaded_bytes).sum();
        let speedup = solo.wall_seconds / group_wall.max(1e-9);
        println!(
            "\nbatched packing: {k} runs × {steps} steps on ff-tiny_lora_r8 \
             (global_batch = micro_batch = {})",
            art.manifest.config.model.micro_batch
        );
        println!(
            "  wall: solo {:.2}s vs batched {:.2}s ({speedup:.2}x)",
            solo.wall_seconds, group_wall
        );
        println!(
            "  train dispatches: solo 3/step × {k} runs = {solo_train_dispatches} vs batched \
             2/step for the group = {} ({:.1}x fewer; measured group total incl. eval: {})",
            2 * steps,
            solo_train_dispatches as f64 / (2 * steps) as f64,
            group_dispatches,
        );
        println!(
            "  losses {} | uploaded bytes: solo {} vs batched {} (shared frozen base)",
            if identical { "bit-identical per run: OK" } else { "MISMATCH — batched diverged" },
            solo.transfers.uploaded_bytes,
            packed_up,
        );
        report = report.set(
            "batched_pack",
            Json::obj()
                .set("k", k)
                .set("steps", steps)
                .set("solo_wall_seconds", solo.wall_seconds)
                .set("batched_wall_seconds", group_wall)
                .set("speedup", speedup)
                .set("bit_identical", identical)
                .set("solo_train_dispatches", solo_train_dispatches)
                .set("batched_train_dispatches", 2 * steps)
                .set("batched_group_dispatches_measured", group_dispatches)
                .set("uploaded_bytes_solo", solo.transfers.uploaded_bytes as i64)
                .set("uploaded_bytes_batched", packed_up as i64),
        );
    } else {
        println!(
            "\nbatched packing: ff-tiny_lora_r8 manifest has no *_batched programs — \
             re-run `make artifacts`; section skipped"
        );
    }

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_step.json");
    std::fs::write(&out, report.to_string_pretty())?;
    println!("wrote {}", out.display());
    Ok(())
}
