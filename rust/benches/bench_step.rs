//! Bench: end-to-end optimizer-step latency (the paper's train-time axis,
//! Fig 3) plus the host↔device traffic behind it — in **both** step modes:
//!
//! * `sync`      — drain interval 1: every step blocks on its loss
//!   download (the pre-pipeline behaviour);
//! * `pipelined` — the engine's deferred-readback ring + batch prefetch:
//!   dispatch returns immediately, losses drain every K steps, and the
//!   next batch uploads while the current step executes.
//!
//! The pipelined mode must be no slower per step; the wall-clock delta is
//! the synchronization overhead the stream layer removed. Also measures
//! uploaded/downloaded **bytes per Adam step** and **per FF probe**, and
//! asserts-by-printing the steady-state transfer contract
//! (docs/transfer-contract.md): with device-side gradient accumulation the
//! *only* bytes uploaded per Adam step are the batch (tokens/targets/mask)
//! plus the 4-byte step scalar — prefetch moves the upload one step
//! earlier but does not change the total.
//!
//! Results additionally land in `BENCH_step.json` (next to Cargo.toml) so
//! the perf trajectory is tracked across PRs instead of living only in
//! stdout. Run: `cargo bench --offline` (after `make artifacts`).

use std::path::{Path, PathBuf};
use std::time::Duration;

use fastforward::config::{presets, FfConfig};
use fastforward::runtime::{Runtime, SyncReason};
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::Trainer;
use fastforward::util::bench::bench;
use fastforward::util::json::Json;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

const PIPELINE_DRAIN: usize = 8;

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let mut report = Json::obj();

    for model in ["ff-tiny", "ff-small"] {
        let base = ensure_pretrained(&rt, &root, model, None)?;
        let mut cfg = presets::train_config(&format!("{model}_lora_r8"), "medical", 1)?;
        cfg.train_examples = 512;
        cfg.test_examples = 64;
        cfg.ff = FfConfig { enabled: false, ..FfConfig::default() };
        let mut t = Trainer::new(&rt, &root, cfg.clone(), Some(&base))?;

        let tokens_per_step = (cfg.global_batch * t.art.manifest.config.model.seq_len) as f64;

        // -- sync mode: drain-every-1, the old blocking behaviour --------
        t.set_drain_interval(1);
        // warm the device-resident state before measuring steady state
        t.sgd_step()?;
        let (state_ups_0, _) = t.state_transfer_counts();
        let tr0 = t.transfers();
        let s_sync = bench(
            &format!("sgd_step/sync/{model}/global{}", cfg.global_batch),
            2,
            10,
            Duration::from_secs(3),
            || {
                t.sgd_step().unwrap();
            },
        );
        let per_step = t.transfers().since(&tr0).per_iter(s_sync.iters as u64 + 2);
        let (state_ups_1, state_downs) = t.state_transfer_counts();
        println!(
            "{}  ({:.0} tokens/s)",
            s_sync.report(),
            tokens_per_step / s_sync.mean_secs()
        );
        println!("    transfers/adam_step: {}", per_step.report());
        println!(
            "    state uploads {} → {} across {} steps ({}), state downloads {}",
            state_ups_0,
            state_ups_1,
            s_sync.iters + 2,
            if state_ups_1 == state_ups_0 { "flat: device-resident" } else { "NOT FLAT" },
            state_downs,
        );
        // The transfer contract's acceptance line: with device-side
        // accumulation the per-step upload is the batch plus one 4-byte
        // step scalar — gradients (4·|trainable| bytes) never cross.
        let mc = t.art.manifest.config.model.clone();
        let n_micro = cfg.global_batch / mc.micro_batch;
        let batch_bytes = (n_micro * 3 * mc.micro_batch * mc.seq_len * 4 + 4) as u64;
        let grad_bytes = 4 * t.trainable_numel() as u64;
        let batch_only = per_step.uploaded_bytes == batch_bytes;
        println!(
            "    upload/adam_step = {} vs batch-only expectation {} ({}); \
             host-path gradient upload would add {}",
            per_step.uploaded_bytes,
            batch_bytes,
            if batch_only { "EXACT: batch data only" } else { "MISMATCH" },
            fastforward::runtime::human_bytes(grad_bytes),
        );

        // -- pipelined mode: deferred readback + prefetch ----------------
        // Fresh trainer so the comparison starts from the same state.
        let mut tp = Trainer::new(&rt, &root, cfg.clone(), Some(&base))?;
        tp.set_drain_interval(PIPELINE_DRAIN);
        tp.sgd_step()?; // warm state; also primes the prefetch slot
        let tr0 = tp.transfers();
        let s_pipe = bench(
            &format!("sgd_step/pipelined-K{PIPELINE_DRAIN}/{model}/global{}", cfg.global_batch),
            2,
            10,
            Duration::from_secs(3),
            || {
                tp.dispatch_sgd_step().unwrap();
            },
        );
        // retire in-flight steps outside the timed region, then attribute
        // transfers over the dispatched count
        tp.drain_pending(SyncReason::Shutdown)?;
        let per_step_pipe = tp.transfers().since(&tr0).per_iter(s_pipe.iters as u64 + 2);
        println!(
            "{}  ({:.0} tokens/s)",
            s_pipe.report(),
            tokens_per_step / s_pipe.mean_secs()
        );
        println!("    transfers/adam_step: {}", per_step_pipe.report());
        println!("    stream: {}", tp.stream_stats().report());
        let speedup = s_sync.mean_secs() / s_pipe.mean_secs();
        println!(
            "    pipelined vs sync: {:.2}x per step ({})",
            speedup,
            if speedup >= 1.0 { "no slower: OK" } else { "SLOWER — pipeline regression" },
        );

        // val-set inference = one FF probe's cost; batch buffers cached
        // after the first call, so steady-state probes upload nothing.
        t.eval_val()?; // builds the EvalCache
        let tr0 = t.transfers();
        let s_probe = bench(
            &format!("ff_val_probe/{model}/32ex"),
            2,
            10,
            Duration::from_secs(2),
            || {
                t.eval_val().unwrap();
            },
        );
        let per_probe = t.transfers().since(&tr0).per_iter(s_probe.iters as u64 + 2);
        println!("{}", s_probe.report());
        println!("    transfers/ff_probe (fixed W): {}", per_probe.report());

        report = report.set(
            model,
            Json::obj()
                .set("tokens_per_step", tokens_per_step)
                .set("sync", s_sync.to_json())
                .set("pipelined", s_pipe.to_json())
                .set("pipelined_drain_interval", PIPELINE_DRAIN)
                .set("pipelined_speedup", speedup)
                .set("transfers_per_step_sync", per_step.to_json())
                .set("transfers_per_step_pipelined", per_step_pipe.to_json())
                .set("batch_bytes_expected", batch_bytes as i64)
                .set("upload_is_batch_only", batch_only)
                .set("state_uploads_flat", state_ups_1 == state_ups_0)
                .set("donations_per_step", per_step.donations as i64)
                .set("ff_probe", s_probe.to_json())
                .set("transfers_per_probe", per_probe.to_json()),
        );
    }

    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_step.json");
    std::fs::write(&out, report.to_string_pretty())?;
    println!("wrote {}", out.display());
    Ok(())
}
