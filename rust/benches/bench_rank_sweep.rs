//! Bench: per-step cost vs LoRA rank (the compute axis of paper Fig 7).
//! Confirms the analytic FLOPs model's prediction that adapter rank barely
//! moves the per-step cost while it strongly moves FF's effectiveness.

use std::path::{Path, PathBuf};
use std::time::Duration;

use fastforward::config::{presets, FfConfig};
use fastforward::flops::FlopsModel;
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::Trainer;
use fastforward::util::bench::bench;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", None)?;

    println!("{:>5} {:>14} {:>14} {:>12}", "rank", "mean step", "tokens/s", "fwd GFLOP");
    for rank in [1usize, 8, 64] {
        let mut cfg = presets::train_config(&format!("ff-tiny_lora_r{rank}"), "medical", 1)?;
        cfg.train_examples = 512;
        cfg.test_examples = 64;
        cfg.ff = FfConfig { enabled: false, ..FfConfig::default() };
        let tokens = (cfg.global_batch * 64) as f64;
        let mut t = Trainer::new(&rt, &root, cfg, Some(&base))?;
        let fm = FlopsModel::for_artifact(&t.art.manifest.config);
        let s = bench(&format!("sgd_step/r{rank}"), 1, 8, Duration::from_secs(2), || {
            t.sgd_step().unwrap();
        });
        println!(
            "{:>5} {:>14.3?} {:>14.0} {:>12.3}",
            rank,
            s.mean,
            tokens / s.mean_secs(),
            fm.forward_flops(1) as f64 * tokens / 1e9
        );
    }
    Ok(())
}
