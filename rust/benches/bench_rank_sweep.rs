//! Bench: per-step cost vs LoRA rank (the compute axis of paper Fig 7),
//! plus the **concurrent scheduler scaling** section: the same grid of
//! short independent runs executed at `jobs=1` vs `jobs=N` through
//! `sched::WorkerPool`, reporting the wall-clock speedup and verifying the
//! per-run losses are bit-identical — the paper's sweep protocol is
//! embarrassingly parallel, and this measures how much of that the pool
//! recovers on this host.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use fastforward::config::{presets, FfConfig, TrainConfig};
use fastforward::flops::FlopsModel;
use fastforward::runtime::Runtime;
use fastforward::sched::{default_jobs, threads_enabled, ArtifactCache, RunSpec, WorkerPool};
use fastforward::train::engine::required_programs;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::{StopRule, Trainer};
use fastforward::util::bench::bench;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn short_cfg(rank: usize, seed: u64) -> anyhow::Result<TrainConfig> {
    let mut cfg = presets::train_config(&format!("ff-tiny_lora_r{rank}"), "medical", 1)?;
    cfg.train_examples = 512;
    cfg.test_examples = 64;
    cfg.seed = seed;
    cfg.ff = FfConfig { enabled: false, ..FfConfig::default() };
    Ok(cfg)
}

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", None)?;

    println!("{:>5} {:>14} {:>14} {:>12}", "rank", "mean step", "tokens/s", "fwd GFLOP");
    for rank in [1usize, 8, 64] {
        let cfg = short_cfg(rank, 0x5eed)?;
        let tokens = (cfg.global_batch * 64) as f64;
        let mut t = Trainer::new(&rt, &root, cfg, Some(&base))?;
        let fm = FlopsModel::for_manifest(&t.art.manifest);
        let s = bench(&format!("sgd_step/r{rank}"), 1, 8, Duration::from_secs(2), || {
            t.sgd_step().unwrap();
        });
        println!(
            "{:>5} {:>14.3?} {:>14.0} {:>12.3}",
            rank,
            s.mean,
            tokens / s.mean_secs(),
            fm.forward_flops(1) as f64 * tokens / 1e9
        );
    }

    // -- scheduler scaling: the rank sweep as concurrent runs ------------
    // One short run per (rank, seed) cell — 6 cells, 8 Adam steps each —
    // executed through the worker pool at jobs=1 and jobs=N. XLA:CPU
    // already parallelizes inside a dispatch, so the speedup ceiling is
    // well under N; what the pool recovers is the dispatch/readback/host
    // overhead the per-run hot loop serializes on.
    let steps = 8usize;
    let base = Arc::new(base); // W0 shared read-only across all runs
    let specs = |tag: &str| -> anyhow::Result<Vec<RunSpec>> {
        let mut out = Vec::new();
        for rank in [1usize, 8, 64] {
            for seed in [0x5eedu64, 0x5eee] {
                out.push(RunSpec {
                    label: format!("{tag}/r{rank}/s{seed:x}"),
                    cfg: short_cfg(rank, seed)?,
                    stop: StopRule::MaxSteps(steps),
                    base: Some(Arc::clone(&base)),
                    drain_interval: None,
                });
            }
        }
        Ok(out)
    };
    let cache = ArtifactCache::new(root.clone());
    // Pre-warm the shared program cache so neither timed batch pays XLA
    // compilation: the first batch would otherwise compile every program
    // inside its timed window and inflate the reported speedup.
    for rank in [1usize, 8, 64] {
        let art = cache.load(&rt, &format!("ff-tiny_lora_r{rank}"))?;
        for prog in required_programs(&art.manifest) {
            art.program(prog)?;
        }
    }
    let jobs = default_jobs().min(4);
    println!("\nscheduler scaling: 6 runs × {steps} steps (ranks 1/8/64 × 2 seeds)");
    if !threads_enabled() {
        println!(
            "  NOTE: built without --features xla-shared-client — the pool runs \
             sequentially (expect speedup ~1.0x); see rust/XLA_AUDIT"
        );
    }
    let seq = WorkerPool::new(1).run_all(&rt, &cache, specs("seq")?)?;
    let par = WorkerPool::new(jobs).run_all(&rt, &cache, specs("par")?)?;
    let identical = seq
        .outputs
        .iter()
        .zip(par.outputs.iter())
        .all(|(a, b)| a.bit_identical(b));
    let speedup = seq.wall_seconds / par.wall_seconds.max(1e-9);
    println!(
        "  jobs=1: {:>6.2}s wall   jobs={jobs}: {:>6.2}s wall   speedup {speedup:.2}x",
        seq.wall_seconds, par.wall_seconds
    );
    println!(
        "  losses {} | aggregate transfers jobs=1 [{}] vs jobs={jobs} [{}]",
        if identical { "bit-identical across jobs levels: OK" } else { "MISMATCH — scheduler broke determinism" },
        seq.transfers.report(),
        par.transfers.report()
    );
    Ok(())
}
