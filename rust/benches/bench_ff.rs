//! Bench: Fast Forward stage economics (paper Fig 2's mechanism). Compares
//! the cost of one SGD step against one FF simulated step (host axpy + val
//! forward) and reports the break-even τ — how few simulated steps already
//! beat an SGD step on wall-clock.

use std::path::{Path, PathBuf};
use std::time::Duration;

use fastforward::config::{presets, FfConfig};
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::Trainer;
use fastforward::util::bench::bench;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() -> anyhow::Result<()> {
    fastforward::util::logging::init();
    let rt = Runtime::cpu()?;
    let root = artifacts_root();
    let model = "ff-tiny";
    let base = ensure_pretrained(&rt, &root, model, None)?;
    let mut cfg = presets::train_config(&format!("{model}_lora_r8"), "medical", 1)?;
    cfg.train_examples = 512;
    cfg.test_examples = 64;
    cfg.ff = FfConfig { warmup_steps: 2, t_interval: 2, ..FfConfig::default() };
    let mut t = Trainer::new(&rt, &root, cfg, Some(&base))?;
    for _ in 0..4 {
        t.sgd_step()?;
    }

    let sgd = bench("sgd_step", 1, 8, Duration::from_secs(3), || {
        t.sgd_step().unwrap();
    });
    println!("{}", sgd.report());

    // One simulated step = host axpy over trainables + 32-example forward.
    // The probe direction only needs Δ_W's *geometry*: build it from the
    // sync-free shapes API instead of forcing a device→host snapshot of
    // the live weights every iteration.
    let delta: Vec<fastforward::model::tensor::Tensor> = t
        .trainable_shapes()
        .iter()
        .map(|s| fastforward::model::tensor::Tensor::ones(s))
        .collect();
    let sim = bench("ff_simulated_step(axpy+val_fwd)", 1, 8, Duration::from_secs(2), || {
        t.tr_axpy_for_bench(&delta, 1e-9).unwrap();
        t.eval_val().unwrap();
    });
    println!("{}", sim.report());

    let ratio = sgd.mean_secs() / sim.mean_secs();
    println!(
        "\none SGD step costs {ratio:.1}× a simulated step → any FF stage with τ* ≥ {} \
         already saves wall-clock (paper finds τ* up to dozens early in training)",
        (1.0 / ratio).ceil().max(1.0) as usize
    );

    // full FF stage (line search) timing
    let stage = bench("ff_stage(full_line_search)", 0, 4, Duration::from_secs(2), || {
        t.sgd_step().unwrap();
        t.ff_stage().unwrap();
    });
    println!("{}", stage.report());
    Ok(())
}
