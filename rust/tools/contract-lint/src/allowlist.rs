//! The committed allowlist ratchet (`rust/CONTRACT_ALLOW`).
//!
//! Format: one entry per line, `rule|file|token|count|reason`, `#`
//! comments and blank lines ignored. An entry suppresses exactly `count`
//! findings of `rule` in `file` carrying `token` — a *ratchet* in both
//! directions: more findings than the allowed count fails (a regression
//! landed), fewer also fails (the code improved; shrink the entry so the
//! better state is locked in). An entry matching nothing at all is stale
//! and fails too.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::rules::Finding;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Entry {
    pub rule: String,
    pub file: String,
    pub token: String,
    pub count: usize,
    pub reason: String,
}

pub fn parse(text: &str) -> Result<Vec<Entry>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.splitn(5, '|').collect();
        if parts.len() != 5 {
            return Err(format!(
                "CONTRACT_ALLOW:{}: expected `rule|file|token|count|reason`, got: {line}",
                i + 1
            ));
        }
        let count: usize = parts[3]
            .trim()
            .parse()
            .map_err(|_| format!("CONTRACT_ALLOW:{}: bad count '{}'", i + 1, parts[3]))?;
        out.push(Entry {
            rule: parts[0].trim().to_string(),
            file: parts[1].trim().to_string(),
            token: parts[2].trim().to_string(),
            count,
            reason: parts[4].trim().to_string(),
        });
    }
    Ok(out)
}

/// Apply the allowlist to raw findings. Returns the human-readable
/// errors that survive: unallowed findings, count mismatches (either
/// direction), and stale entries.
pub fn apply(findings: &[Finding], allow: &[Entry]) -> Vec<String> {
    let mut grouped: BTreeMap<(String, String, String), Vec<&Finding>> = BTreeMap::new();
    for f in findings {
        grouped
            .entry((f.rule.to_string(), f.file.clone(), f.token.clone()))
            .or_default()
            .push(f);
    }
    let mut errors = Vec::new();
    let mut used = vec![false; allow.len()];
    for ((rule, file, token), group) in &grouped {
        let entry = allow
            .iter()
            .position(|e| &e.rule == rule && &e.file == file && &e.token == token);
        match entry {
            Some(i) => {
                used[i] = true;
                let want = allow[i].count;
                if group.len() != want {
                    let mut msg = format!(
                        "[{rule}] {file}: {} site(s) of `{token}`, allowlist ratchet says {want} — \
                         a change in either direction needs a CONTRACT_ALLOW update:",
                        group.len()
                    );
                    for f in group {
                        let _ = write!(msg, "\n    {}:{}: {}", f.file, f.line, f.msg);
                    }
                    errors.push(msg);
                }
            }
            None => {
                for f in group {
                    errors.push(format!(
                        "[{rule}] {}:{}: {} (no CONTRACT_ALLOW entry)",
                        f.file, f.line, f.msg
                    ));
                }
            }
        }
    }
    for (i, e) in allow.iter().enumerate() {
        if !used[i] {
            errors.push(format!(
                "[stale-allowlist] CONTRACT_ALLOW entry `{}|{}|{}|{}` matches nothing — \
                 the code no longer has these sites; remove the entry to ratchet down",
                e.rule, e.file, e.token, e.count
            ));
        }
    }
    errors
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::Finding;

    fn f(rule: &'static str, file: &str, token: &str) -> Finding {
        Finding {
            rule,
            file: file.into(),
            line: 1,
            token: token.into(),
            msg: "m".into(),
        }
    }

    #[test]
    fn exact_count_suppresses() {
        let allow = parse("meter-bypass|a.rs|.execute_raw(|2|ok\n").unwrap();
        let fs = vec![f("meter-bypass", "a.rs", ".execute_raw("), f("meter-bypass", "a.rs", ".execute_raw(")];
        assert!(apply(&fs, &allow).is_empty());
    }

    #[test]
    fn ratchet_fires_in_both_directions_and_on_stale() {
        let allow = parse("meter-bypass|a.rs|.execute_raw(|2|ok\n").unwrap();
        // one too many
        let many = vec![
            f("meter-bypass", "a.rs", ".execute_raw("),
            f("meter-bypass", "a.rs", ".execute_raw("),
            f("meter-bypass", "a.rs", ".execute_raw("),
        ];
        assert_eq!(apply(&many, &allow).len(), 1);
        // one too few (improvement must be locked in)
        let few = vec![f("meter-bypass", "a.rs", ".execute_raw(")];
        assert_eq!(apply(&few, &allow).len(), 1);
        // entry with no findings at all is stale
        assert!(apply(&[], &allow)[0].contains("stale-allowlist"));
    }

    #[test]
    fn unlisted_findings_error() {
        let errs = apply(&[f("lock-order", "b.rs", "queue.state")], &[]);
        assert_eq!(errs.len(), 1);
        assert!(errs[0].contains("no CONTRACT_ALLOW entry"));
    }

    #[test]
    fn bad_lines_are_rejected() {
        assert!(parse("only|three|fields\n").is_err());
        assert!(parse("r|f|t|notanumber|why\n").is_err());
        assert!(parse("# comment\n\nr|f|t|1|why\n").unwrap().len() == 1);
    }
}
