//! contract-lint — the repo's mechanized invariants (docs/static-analysis.md).
//!
//! Subcommands:
//!   check          lint rules against rust/src + allowlist ratchet +
//!                  unsafe-ledger drift (the default)
//!   unsafe-ledger  print the generated ledger; `--write` rewrites
//!                  rust/UNSAFE_LEDGER in place
//!   docs           documentation presence/reference gate
//!   xla-gate       the xla thread-safety audit gate (check_xla_audit.sh
//!                  is a thin wrapper around this)
//!   all            check + docs + xla-gate
//!
//! Options: `--root <dir>` (default: walk up from cwd to the first
//! directory containing rust/src). Exit codes: 0 clean, 1 findings,
//! 2 usage or I/O failure.
//!
//! Zero dependencies by design: this binary must build and run in
//! toolchain-only CI, with no network and no PJRT.

mod allowlist;
mod gates;
mod rules;
mod scan;

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut args = std::env::args().skip(1);
    let mut cmd: Option<String> = None;
    let mut root: Option<PathBuf> = None;
    let mut write = false;
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root = Some(PathBuf::from(p)),
                None => return ExitCode::from(usage("--root needs a path")),
            },
            "--write" => write = true,
            "-h" | "--help" => {
                eprintln!("usage: {HELP}");
                return ExitCode::SUCCESS;
            }
            other if cmd.is_none() && !other.starts_with('-') => cmd = Some(other.to_string()),
            other => return ExitCode::from(usage(&format!("unknown argument '{other}'"))),
        }
    }
    let root = match root.or_else(discover_root) {
        Some(r) => r,
        None => {
            eprintln!(
                "contract-lint: cannot find the repo root (no rust/src above cwd); use --root"
            );
            return ExitCode::from(2);
        }
    };
    let cmd = cmd.unwrap_or_else(|| "check".to_string());
    let code = match cmd.as_str() {
        "check" => run_check(&root),
        "unsafe-ledger" => run_ledger(&root, write),
        "docs" => report("docs gate", gates::docs(&root), &[]),
        "xla-gate" => {
            let (errs, info) = gates::xla_gate(&root);
            report("xla gate", errs, &info)
        }
        "all" => {
            let check = run_check(&root);
            let docs = report("docs gate", gates::docs(&root), &[]);
            let gate = {
                let (errs, info) = gates::xla_gate(&root);
                report("xla gate", errs, &info)
            };
            check.max(docs).max(gate)
        }
        other => usage(&format!("unknown subcommand '{other}'")),
    };
    ExitCode::from(code)
}

const HELP: &str = "contract-lint [check|unsafe-ledger [--write]|docs|xla-gate|all] [--root DIR]";

fn usage(msg: &str) -> u8 {
    eprintln!("contract-lint: {msg}\nusage: {HELP}");
    2
}

/// Walk up from cwd to the first directory containing `rust/src`.
fn discover_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn load_sources(root: &Path) -> Result<Vec<scan::SourceFile>, u8> {
    scan::load_tree(root, "rust/src").map_err(|e| {
        eprintln!("contract-lint: cannot read rust/src: {e}");
        2
    })
}

fn run_check(root: &Path) -> u8 {
    let files = match load_sources(root) {
        Ok(f) => f,
        Err(code) => return code,
    };

    let mut findings = rules::meter_bypass(&files);
    findings.extend(rules::unsafe_safety(&files));
    findings.extend(rules::lock_order(&files));
    match fs::read_to_string(root.join("python/compile/model.py")) {
        Ok(model_py) => {
            let donating = rules::donating_programs(&model_py);
            findings.extend(rules::donation(&files, &donating));
        }
        Err(e) => {
            // The donation rule cross-checks compile metadata; a missing
            // source of truth is a failure, not a silent skip.
            eprintln!("contract-lint: cannot read python/compile/model.py: {e}");
            return 2;
        }
    }

    let allow_text = fs::read_to_string(root.join("rust/CONTRACT_ALLOW")).unwrap_or_default();
    let allow = match allowlist::parse(&allow_text) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("contract-lint: {e}");
            return 2;
        }
    };
    let mut errors = allowlist::apply(&findings, &allow);

    let committed = fs::read_to_string(root.join("rust/UNSAFE_LEDGER")).ok();
    errors.extend(rules::check_ledger(&files, committed.as_deref()));

    if errors.is_empty() {
        println!(
            "contract-lint: OK — {} files, {} finding(s) all covered by {} allowlist entries; \
             unsafe ledger in sync ({} unsafe items)",
            files.len(),
            findings.len(),
            allow.len(),
            rules::unsafe_sites(&files).len()
        );
        0
    } else {
        for e in &errors {
            eprintln!("contract-lint: {e}");
        }
        eprintln!("contract-lint: FAIL — {} error(s)", errors.len());
        1
    }
}

fn run_ledger(root: &Path, write: bool) -> u8 {
    let files = match load_sources(root) {
        Ok(f) => f,
        Err(code) => return code,
    };
    // SAFETY-comment presence is part of the ledger contract: refuse to
    // generate a ledger with rationale-free entries.
    let missing = rules::unsafe_safety(&files);
    if !missing.is_empty() {
        for f in &missing {
            eprintln!("contract-lint: [{}] {}:{}: {}", f.rule, f.file, f.line, f.msg);
        }
        return 1;
    }
    let generated = rules::generate_ledger(&files);
    if write {
        if let Err(e) = fs::write(root.join("rust/UNSAFE_LEDGER"), &generated) {
            eprintln!("contract-lint: cannot write rust/UNSAFE_LEDGER: {e}");
            return 2;
        }
        println!(
            "contract-lint: wrote rust/UNSAFE_LEDGER ({} entries)",
            generated.lines().filter(|l| !l.starts_with('#')).count()
        );
        0
    } else {
        print!("{generated}");
        let committed = fs::read_to_string(root.join("rust/UNSAFE_LEDGER")).ok();
        report(
            "unsafe ledger",
            rules::check_ledger(&files, committed.as_deref()),
            &[],
        )
    }
}

fn report(what: &str, errors: Vec<String>, info: &[String]) -> u8 {
    for l in info {
        println!("contract-lint: {l}");
    }
    if errors.is_empty() {
        println!("contract-lint: {what}: OK");
        0
    } else {
        for e in &errors {
            eprintln!("contract-lint: {e}");
        }
        1
    }
}
