//! Source model for the lint rules: load `.rs` files, blank out comments
//! and string/char literal *contents* (so token searches never match
//! inside either), and mark `#[cfg(test)]` regions (contract rules apply
//! to shipping code; tests may poke raw APIs on purpose).
//!
//! This is a line-oriented lexer, not a parser — rules that need more
//! structure (receiver paths, guard bindings) build it locally from the
//! blanked lines. Precision target: zero false positives on this repo's
//! rustfmt-formatted sources, loud errors anywhere the heuristics lose
//! track (unknown lock names, unledgered unsafe), never silent skips.

use std::fs;
use std::io;
use std::path::Path;

pub struct SourceFile {
    /// Path relative to the repo root, forward slashes.
    pub rel: String,
    /// Raw lines (SAFETY comments are read from these).
    pub raw: Vec<String>,
    /// Lines with comments and literal contents blanked to spaces.
    pub code: Vec<String>,
    /// `true` for lines inside a `#[cfg(test)]` item.
    pub test: Vec<bool>,
}

impl SourceFile {
    pub fn parse(rel: &str, content: &str) -> SourceFile {
        let raw: Vec<String> = content.lines().map(str::to_string).collect();
        let code = blank_noncode(content);
        debug_assert_eq!(raw.len(), code.len());
        let test = test_mask(&code);
        SourceFile { rel: rel.to_string(), raw, code, test }
    }
}

/// Load every `.rs` file under `root/sub`, sorted by relative path (the
/// scan order is part of the deterministic output contract).
pub fn load_tree(root: &Path, sub: &str) -> io::Result<Vec<SourceFile>> {
    let mut rels = Vec::new();
    collect_rs(&root.join(sub), Path::new(sub), &mut rels)?;
    rels.sort();
    let mut out = Vec::with_capacity(rels.len());
    for rel in rels {
        let content = fs::read_to_string(root.join(&rel))?;
        out.push(SourceFile::parse(&rel, &content));
    }
    Ok(out)
}

fn collect_rs(dir: &Path, rel: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let path = entry.path();
        let rel = rel.join(&name);
        if path.is_dir() {
            collect_rs(&path, &rel, out)?;
        } else if name.ends_with(".rs") {
            out.push(rel.to_string_lossy().replace('\\', "/"));
        }
    }
    Ok(())
}

/// Blank comments and the *contents* of string/char literals to spaces,
/// preserving line structure and the delimiter characters themselves.
/// Handles line comments, nested block comments, regular/byte strings
/// with escapes, raw strings (`r"…"`, `r#"…"#`), char literals, and
/// lifetimes (`'a` stays code).
fn blank_noncode(content: &str) -> Vec<String> {
    #[derive(PartialEq)]
    enum St {
        Code,
        Line,
        Block(u32),
        Str,
        RawStr(u32),
    }
    let bytes: Vec<char> = content.chars().collect();
    let mut out = String::with_capacity(content.len());
    let mut st = St::Code;
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i];
        let next = bytes.get(i + 1).copied();
        match st {
            St::Code => match c {
                '/' if next == Some('/') => {
                    st = St::Line;
                    out.push_str("  ");
                    i += 2;
                }
                '/' if next == Some('*') => {
                    st = St::Block(1);
                    out.push_str("  ");
                    i += 2;
                }
                '"' => {
                    st = St::Str;
                    out.push('"');
                    i += 1;
                }
                'r' | 'b'
                    if is_raw_string_start(&bytes, i) =>
                {
                    // r"…", r#"…"#, br"…" etc.: count the hashes.
                    let mut j = i + 1;
                    if bytes.get(j) == Some(&'r') {
                        j += 1; // the `br` case
                    }
                    let mut hashes = 0u32;
                    while bytes.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    for k in i..=j {
                        out.push(bytes[k]); // r, hashes, opening quote
                    }
                    st = St::RawStr(hashes);
                    i = j + 1;
                }
                '\'' => {
                    // char literal vs lifetime
                    if next == Some('\\') {
                        // '\n', '\'', '\u{…}': blank to the closing quote
                        out.push('\'');
                        i += 1;
                        while i < bytes.len() && bytes[i] != '\'' {
                            if bytes[i] == '\\' && i + 1 < bytes.len() {
                                out.push_str("  ");
                                i += 2;
                            } else {
                                out.push(if bytes[i] == '\n' { '\n' } else { ' ' });
                                i += 1;
                            }
                        }
                        if i < bytes.len() {
                            out.push('\'');
                            i += 1;
                        }
                    } else if bytes.get(i + 2) == Some(&'\'') && next.is_some() {
                        out.push('\'');
                        out.push(if next == Some('\n') { '\n' } else { ' ' });
                        out.push('\'');
                        i += 3;
                    } else {
                        out.push('\''); // lifetime
                        i += 1;
                    }
                }
                _ => {
                    out.push(c);
                    i += 1;
                }
            },
            St::Line => {
                if c == '\n' {
                    st = St::Code;
                    out.push('\n');
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            St::Block(d) => {
                if c == '/' && next == Some('*') {
                    st = St::Block(d + 1);
                    out.push_str("  ");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    st = if d == 1 { St::Code } else { St::Block(d - 1) };
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    // keep line structure across `\<newline>` continuations
                    out.push(' ');
                    if let Some(n) = next {
                        out.push(if n == '\n' { '\n' } else { ' ' });
                    }
                    i += 2;
                } else if c == '"' {
                    st = St::Code;
                    out.push('"');
                    i += 1;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && raw_string_closes(&bytes, i, h) {
                    for k in 0..=(h as usize) {
                        out.push(bytes[i + k]);
                    }
                    st = St::Code;
                    i += h as usize + 1;
                } else {
                    out.push(if c == '\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
        }
    }
    out.lines().map(str::to_string).collect()
}

fn is_raw_string_start(bytes: &[char], i: usize) -> bool {
    // must not be the tail of an identifier (`for r in …` vs `regr"x"`)
    if i > 0 && (bytes[i - 1].is_alphanumeric() || bytes[i - 1] == '_') {
        return false;
    }
    let mut j = i + 1;
    if bytes.get(i) == Some(&'b') && bytes.get(j) == Some(&'r') {
        j += 1;
    } else if bytes.get(i) == Some(&'b') {
        return false; // b"…" is handled by the plain-string arm upstream?
    }
    while bytes.get(j) == Some(&'#') {
        j += 1;
    }
    bytes.get(j) == Some(&'"') && (bytes.get(i) == Some(&'r') || bytes.get(i) == Some(&'b'))
}

fn raw_string_closes(bytes: &[char], i: usize, hashes: u32) -> bool {
    (1..=hashes as usize).all(|k| bytes.get(i + k) == Some(&'#'))
}

/// Mark lines belonging to `#[cfg(test)]` items: from the attribute,
/// through the item's balanced braces (or through the terminating `;`
/// for brace-less items).
fn test_mask(code: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    let mut i = 0;
    while i < code.len() {
        if code[i].trim_start().starts_with("#[cfg(test)]") {
            let mut depth: i32 = 0;
            let mut opened = false;
            let mut j = i;
            while j < code.len() {
                mask[j] = true;
                for c in code[j].chars() {
                    match c {
                        '{' => {
                            depth += 1;
                            opened = true;
                        }
                        '}' => depth -= 1,
                        ';' if !opened && depth == 0 => {
                            // `#[cfg(test)] use …;`
                            mask[j] = true;
                            depth = -1; // force exit
                        }
                        _ => {}
                    }
                }
                if opened && depth <= 0 {
                    break;
                }
                if depth < 0 {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    mask
}

/// Find every occurrence of `token` in `line` that starts at a token
/// boundary. Tokens beginning with `.` (method-call probes like
/// `.execute_raw(`) are self-delimiting — the dot is the boundary, and
/// the trailing `(` keeps `.execute_raw(` from matching inside
/// `.execute_raw_donated(`. Bare tokens (`lock(`, `fn `, `unsafe`) must
/// not be preceded by an identifier character *or* a dot, so `m.lock(`
/// and `unlock(` never match `lock(`.
pub fn token_hits(line: &str, token: &str) -> Vec<usize> {
    let self_delimiting = token.starts_with('.');
    let mut hits = Vec::new();
    let mut from = 0;
    while let Some(pos) = line[from..].find(token) {
        let at = from + pos;
        let pre = line[..at].chars().next_back();
        let standalone = self_delimiting
            || match pre {
                Some(c) => !(c.is_alphanumeric() || c == '_' || c == '.'),
                None => true,
            };
        if standalone {
            hits.push(at);
        }
        from = at + token.len();
    }
    hits
}

/// The dotted receiver path ending just before byte offset `at` (which
/// points at the `.` of a `.method(` token): e.g. `self.rt` for
/// `self.rt.upload_f32(`. Empty when the receiver is not a plain path
/// (a call chain, an index, a closing paren).
pub fn receiver_path(line: &str, at: usize) -> String {
    let head = &line[..at];
    let start = head
        .rfind(|c: char| !(c.is_alphanumeric() || c == '_' || c == '.'))
        .map(|p| p + 1)
        .unwrap_or(0);
    head[start..].trim_matches('.').to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let f = SourceFile::parse(
            "x.rs",
            "let a = \"execute_b(\"; // execute_b(\nlet b = 1; /* execute_b( */ let c = 2;\n",
        );
        assert!(!f.code[0].contains("execute_b("));
        assert!(!f.code[1].contains("execute_b("));
        assert!(f.code[1].contains("let c = 2;"));
        // delimiters survive so column math stays aligned
        assert_eq!(f.code[0].len(), f.raw[0].len());
    }

    #[test]
    fn raw_strings_and_lifetimes_survive() {
        let f = SourceFile::parse(
            "x.rs",
            "fn f<'a>(x: &'a str) {}\nlet s = r#\"lock(&x)\"#;\nlet c = '\"';\nlet d = lock(&y);\n",
        );
        assert!(f.code[0].contains("<'a>"), "lifetime kept: {}", f.code[0]);
        assert!(!f.code[1].contains("lock(&x)"));
        assert!(!f.code[2].contains('"'), "quote char blanked: {}", f.code[2]);
        assert!(f.code[3].contains("lock(&y)"));
    }

    #[test]
    fn cfg_test_items_are_masked() {
        let src = "fn real() {\n    work();\n}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let f = SourceFile::parse("x.rs", src);
        assert!(!f.test[0] && !f.test[1]);
        assert!(f.test[3] && f.test[4] && f.test[5] && f.test[6]);
        assert!(!f.test[7]);
    }

    #[test]
    fn token_hits_do_not_match_identifier_tails() {
        assert_eq!(token_hits("x.execute_raw_donated(y)", ".execute_raw("), Vec::<usize>::new());
        assert_eq!(token_hits("x.execute_raw(y)", ".execute_raw("), vec![1]);
        assert_eq!(token_hits("m.lock()", "lock("), Vec::<usize>::new());
        assert_eq!(token_hits("let g = lock(&a);", "lock("), vec![8]);
    }

    #[test]
    fn receiver_paths() {
        let line = "        let v = self.rt.upload_f32(&x, &s)?;";
        let at = line.find(".upload_f32(").unwrap();
        assert_eq!(receiver_path(line, at), "self.rt");
        let line2 = "foo(rt.download_f32(&b)?);";
        let at2 = line2.find(".download_f32(").unwrap();
        assert_eq!(receiver_path(line2, at2), "rt");
    }
}
