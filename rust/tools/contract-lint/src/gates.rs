//! Repo-level gates folded in from CI shell: `contract-lint docs` (the
//! doc-presence greps that used to live inline in ci.yml tier-1) and
//! `contract-lint xla-gate` (the full logic of ci/check_xla_audit.sh —
//! that script is now a thin wrapper exec'ing this subcommand).

use std::fs;
use std::path::Path;

// ----------------------------------------------------------------- docs

/// Documentation presence gate: the contract docs must exist, be
/// non-empty, and be referenced from the README/ROADMAP so they stay
/// discoverable. Returns human-readable errors (empty = pass).
pub fn docs(root: &Path) -> Vec<String> {
    let mut errs = Vec::new();
    let nonempty = [
        "README.md",
        "docs/transfer-contract.md",
        "docs/queue-serving.md",
        "docs/artifact-store.md",
        "docs/static-analysis.md",
    ];
    for rel in nonempty {
        match fs::read_to_string(root.join(rel)) {
            Ok(s) if !s.trim().is_empty() => {}
            Ok(_) => errs.push(format!("docs gate: {rel} exists but is empty")),
            Err(_) => errs.push(format!("docs gate: {rel} is missing")),
        }
    }
    let refs: &[(&str, &[&str])] = &[
        (
            "README.md",
            &["transfer-contract", "queue-serving", "artifact-store", "static-analysis"],
        ),
        ("ROADMAP.md", &["transfer-contract"]),
    ];
    for (file, needles) in refs {
        let text = fs::read_to_string(root.join(file)).unwrap_or_default();
        for needle in *needles {
            if !text.contains(needle) {
                errs.push(format!("docs gate: {file} does not reference \"{needle}\""));
            }
        }
    }
    errs
}

// ------------------------------------------------------------- xla-gate

const FEATURE: &str = "xla-shared-client";

/// Audited `thread::spawn`/`thread::scope` line counts per scheduler
/// file — the same ratchet check_xla_audit.sh carried: a new spawn site
/// fails until a human verifies it is cfg-gated and bumps the count.
///   sched/mod.rs   1 — WorkerPool::scatter's thread::scope (cfg-gated)
///   sched/queue.rs 2 — RunQueue worker spawn + the gated-only
///                      concurrent-submitters test's scope
const SPAWN_RATCHET: &[(&str, usize)] =
    &[("rust/src/sched/mod.rs", 1), ("rust/src/sched/queue.rs", 2)];

/// The xla thread-safety audit gate. Returns `(errors, info)`: empty
/// errors = pass; info lines narrate the verdict like the shell did.
pub fn xla_gate(root: &Path) -> (Vec<String>, Vec<String>) {
    let mut errs = Vec::new();
    let mut info = Vec::new();

    let cargo_toml = match fs::read_to_string(root.join("rust/Cargo.toml")) {
        Ok(s) => s,
        Err(_) => return (vec!["xla gate: missing rust/Cargo.toml".into()], info),
    };
    let audit = match fs::read_to_string(root.join("rust/XLA_AUDIT")) {
        Ok(s) => s,
        Err(_) => {
            return (
                vec!["xla gate: missing rust/XLA_AUDIT (see rust/Cargo.toml, thread-safety gate)"
                    .into()],
                info,
            )
        }
    };

    // 1. The feature must be strictly opt-in: never a default feature.
    if features_section(&cargo_toml)
        .iter()
        .any(|l| l.trim_start().starts_with("default") && l.contains('=') && l.contains(FEATURE))
    {
        errs.push(format!(
            "xla gate: {FEATURE} is in the crate's default features; it must stay opt-in"
        ));
    }

    // 2. Spawn-site ratchet + cfg-gate presence in the scheduler files.
    for &(rel, want) in SPAWN_RATCHET {
        match fs::read_to_string(root.join(rel)) {
            Err(_) => errs.push(format!("xla gate: probe list out of date: missing {rel}")),
            Ok(text) => {
                let got = text
                    .lines()
                    .filter(|l| l.contains("thread::spawn") || l.contains("thread::scope"))
                    .count();
                if got != want {
                    errs.push(format!(
                        "xla gate: {rel} has {got} thread entry points, audited count is {want} \
                         — new spawn sites must be cfg-gated on {FEATURE} and the audited count \
                         updated in contract-lint's SPAWN_RATCHET"
                    ));
                }
                if !text.contains(&format!("feature = \"{FEATURE}\"")) {
                    errs.push(format!(
                        "xla gate: {rel} spawns threads but carries no {FEATURE} cfg-gate"
                    ));
                }
            }
        }
    }

    // 3. Does anything under CI control enable the feature? Compile-only
    // `cargo check` lines are exempt: type-checking runs nothing, so it
    // is sound against any xla revision.
    let mut ci_files: Vec<String> = Vec::new();
    if let Ok(rd) = fs::read_dir(root.join(".github/workflows")) {
        for e in rd.flatten() {
            let n = e.file_name().to_string_lossy().into_owned();
            if n.ends_with(".yml") || n.ends_with(".yaml") {
                ci_files.push(format!(".github/workflows/{n}"));
            }
        }
    }
    ci_files.push("Makefile".into());
    ci_files.push("rust/Makefile".into());
    if let Ok(rd) = fs::read_dir(root.join("ci")) {
        for e in rd.flatten() {
            let n = e.file_name().to_string_lossy().into_owned();
            if n.ends_with(".sh") && n != "check_xla_audit.sh" {
                ci_files.push(format!("ci/{n}"));
            }
        }
    }
    ci_files.sort();
    let mut enabled_by = None;
    'scan: for rel in &ci_files {
        let Ok(text) = fs::read_to_string(root.join(rel)) else { continue };
        for line in text.lines() {
            if line_enables_feature(line) && !is_cargo_check_line(line) {
                enabled_by = Some(rel.clone());
                break 'scan;
            }
        }
    }

    let Some(enabled_by) = enabled_by else {
        info.push(format!(
            "xla gate: OK — {FEATURE} not enabled anywhere in CI; default builds compile the \
             scheduler without thread fan-out (sound against any xla revision)."
        ));
        return (errs, info);
    };
    info.push(format!(
        "xla gate: {enabled_by} builds with {FEATURE} — verifying the audit trail"
    ));

    // 3a. Cargo.toml must pin a rev (a floating branch cannot be audited).
    let pinned = pinned_xla_rev(&cargo_toml);
    let Some(pinned) = pinned else {
        errs.push(format!(
            "xla gate: {enabled_by} enables {FEATURE} but rust/Cargo.toml does not pin xla to a \
             rev (still floating on a branch)"
        ));
        return (errs, info);
    };

    // 3b. The pinned rev must be the audited one.
    let audited = audit
        .lines()
        .map(str::trim)
        .find(|l| !l.is_empty() && !l.starts_with('#'))
        .unwrap_or("")
        .to_string();
    if audited.is_empty() || audited == "none" {
        errs.push(format!(
            "xla gate: {enabled_by} enables {FEATURE} but rust/XLA_AUDIT records no audited rev"
        ));
        return (errs, info);
    }
    if pinned != audited {
        errs.push(format!(
            "xla gate: pinned xla rev ({pinned}) != audited rev ({audited}) in rust/XLA_AUDIT"
        ));
    }

    // 3c. A checked-in lockfile must resolve xla to the audited rev.
    for lock in ["rust/Cargo.lock", "Cargo.lock"] {
        let Ok(text) = fs::read_to_string(root.join(lock)) else { continue };
        let lines: Vec<&str> = text.lines().collect();
        for (i, l) in lines.iter().enumerate() {
            if l.trim() == "name = \"xla\"" {
                let window = lines[i..lines.len().min(i + 3)].join("\n");
                if !window.contains(&audited) {
                    errs.push(format!(
                        "xla gate: {lock} resolves xla to a different rev than the audited \
                         {audited}"
                    ));
                }
            }
        }
    }
    if errs.is_empty() {
        info.push(format!("xla gate: OK — {FEATURE} is backed by audited rev {audited}"));
    }
    (errs, info)
}

/// Lines of the `[features]` table (up to the next `[section]`).
fn features_section(cargo_toml: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut inside = false;
    for line in cargo_toml.lines() {
        let t = line.trim();
        if t == "[features]" {
            inside = true;
            continue;
        }
        if inside {
            if t.starts_with('[') {
                break;
            }
            out.push(line);
        }
    }
    out
}

/// Mirrors the shell's enable-detection regex:
/// `--all-features|(--features|[[:space:]'"]-F)[= ]?[^#]*FEATURE`.
fn line_enables_feature(line: &str) -> bool {
    if line.contains("--all-features") {
        return true;
    }
    let mut starts = Vec::new();
    let mut from = 0;
    while let Some(p) = line[from..].find("--features") {
        starts.push(from + p + "--features".len());
        from += p + 1;
    }
    from = 0;
    while let Some(p) = line[from..].find("-F") {
        let at = from + p;
        let pre = line[..at].chars().next_back();
        if matches!(pre, Some(c) if c.is_whitespace() || c == '\'' || c == '"') {
            starts.push(at + 2);
        }
        from = at + 1;
    }
    for s in starts {
        let rest = &line[s..];
        let rest = rest.split('#').next().unwrap_or("");
        if rest.contains(FEATURE) {
            return true;
        }
    }
    false
}

fn is_cargo_check_line(line: &str) -> bool {
    let toks: Vec<&str> = line.split_whitespace().collect();
    toks.windows(2).any(|w| w[0] == "cargo" && w[1] == "check")
}

/// The `rev = "<sha>"` pin on the `xla = …` dependency line, if any.
fn pinned_xla_rev(cargo_toml: &str) -> Option<String> {
    for line in cargo_toml.lines() {
        let t = line.trim_start();
        if !(t.starts_with("xla ") || t.starts_with("xla=")) {
            continue;
        }
        let Some(rev_at) = t.find("rev") else { continue };
        let rest = &t[rev_at + 3..];
        let rest = rest.trim_start().strip_prefix('=')?.trim_start();
        let rest = rest.strip_prefix('"')?;
        let sha: String = rest.chars().take_while(|c| *c != '"').collect();
        if (7..=40).contains(&sha.len()) && sha.chars().all(|c| c.is_ascii_hexdigit()) {
            return Some(sha);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static DIR_SEQ: AtomicUsize = AtomicUsize::new(0);

    /// A scratch repo tree under the system temp dir; cleaned on drop.
    struct Tree {
        root: PathBuf,
    }
    impl Tree {
        fn new() -> Tree {
            let root = std::env::temp_dir().join(format!(
                "contract-lint-test-{}-{}",
                std::process::id(),
                DIR_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            fs::create_dir_all(&root).unwrap();
            Tree { root }
        }
        fn file(&self, rel: &str, content: &str) -> &Tree {
            let p = self.root.join(rel);
            fs::create_dir_all(p.parent().unwrap()).unwrap();
            fs::write(p, content).unwrap();
            self
        }
    }
    impl Drop for Tree {
        fn drop(&mut self) {
            let _ = fs::remove_dir_all(&self.root);
        }
    }

    fn docs_tree() -> Tree {
        let t = Tree::new();
        t.file("README.md", "transfer-contract queue-serving artifact-store static-analysis")
            .file("ROADMAP.md", "transfer-contract")
            .file("docs/transfer-contract.md", "x")
            .file("docs/queue-serving.md", "x")
            .file("docs/artifact-store.md", "x")
            .file("docs/static-analysis.md", "x");
        t
    }

    #[test]
    fn docs_gate_passes_then_fails_on_missing_and_unreferenced() {
        let t = docs_tree();
        assert!(docs(&t.root).is_empty());
        t.file("docs/static-analysis.md", "  \n");
        assert!(docs(&t.root).iter().any(|e| e.contains("empty")));
        t.file("README.md", "transfer-contract queue-serving artifact-store");
        let errs = docs(&t.root);
        assert!(errs.iter().any(|e| e.contains("static-analysis")), "{errs:?}");
    }

    const SCHED_MOD: &str = "#[cfg(feature = \"xla-shared-client\")]\nthread::scope(|s| {});\n";
    const SCHED_QUEUE: &str = "#[cfg(feature = \"xla-shared-client\")]\n\
        thread::spawn(|| {});\nthread::scope(|s| {});\n";

    fn gate_tree() -> Tree {
        let t = Tree::new();
        t.file(
            "rust/Cargo.toml",
            "[package]\nname = \"x\"\n[features]\ndefault = []\nxla-shared-client = []\n",
        )
        .file("rust/XLA_AUDIT", "# audited rev\nnone\n")
        .file("rust/src/sched/mod.rs", SCHED_MOD)
        .file("rust/src/sched/queue.rs", SCHED_QUEUE);
        t
    }

    #[test]
    fn xla_gate_passes_when_feature_is_off_everywhere() {
        let t = gate_tree();
        let (errs, info) = xla_gate(&t.root);
        assert!(errs.is_empty(), "{errs:?}");
        assert!(info[0].contains("not enabled anywhere"));
    }

    #[test]
    fn xla_gate_fails_on_default_feature_and_spawn_ratchet_drift() {
        let t = gate_tree();
        t.file(
            "rust/Cargo.toml",
            "[features]\ndefault = [\"xla-shared-client\"]\nxla-shared-client = []\n",
        );
        let (errs, _) = xla_gate(&t.root);
        assert!(errs.iter().any(|e| e.contains("default features")), "{errs:?}");

        let t2 = gate_tree();
        t2.file("rust/src/sched/queue.rs", SCHED_QUEUE.repeat(2).as_str());
        let (errs2, _) = xla_gate(&t2.root);
        assert!(errs2.iter().any(|e| e.contains("audited count is 2")), "{errs2:?}");
    }

    #[test]
    fn xla_gate_requires_audited_pin_when_ci_enables_the_feature() {
        let t = gate_tree();
        t.file(
            ".github/workflows/ci.yml",
            "run: cargo test --features xla-shared-client\n",
        );
        // enabled but unpinned → fail
        let (errs, _) = xla_gate(&t.root);
        assert!(errs.iter().any(|e| e.contains("does not pin xla")), "{errs:?}");
        // pinned but audit says "none" → fail
        t.file(
            "rust/Cargo.toml",
            "xla = { git = \"x\", rev = \"abc123def456\" }\n[features]\ndefault = []\n",
        );
        let (errs2, _) = xla_gate(&t.root);
        assert!(errs2.iter().any(|e| e.contains("no audited rev")), "{errs2:?}");
        // audited == pinned, lockfile agrees → pass
        t.file("rust/XLA_AUDIT", "abc123def456\n");
        t.file(
            "rust/Cargo.lock",
            "[[package]]\nname = \"xla\"\nversion = \"0.1.0\"\nsource = \"git+x?rev=abc123def456#abc123def456\"\n",
        );
        let (errs3, info3) = xla_gate(&t.root);
        assert!(errs3.is_empty(), "{errs3:?}");
        assert!(info3.iter().any(|l| l.contains("backed by audited rev")));
        // lockfile drift → fail
        t.file(
            "rust/Cargo.lock",
            "[[package]]\nname = \"xla\"\nversion = \"0.1.0\"\nsource = \"git+x?rev=0000000#0000000\"\n",
        );
        let (errs4, _) = xla_gate(&t.root);
        assert!(errs4.iter().any(|e| e.contains("different rev")), "{errs4:?}");
    }

    #[test]
    fn cargo_check_lines_are_exempt_and_dash_f_spellings_match() {
        assert!(line_enables_feature("cargo test --features xla-shared-client"));
        assert!(line_enables_feature("cargo build -F xla-shared-client"));
        assert!(line_enables_feature("cargo test --all-features"));
        assert!(!line_enables_feature("cargo test --features other-feature"));
        assert!(!line_enables_feature("RUSTFLAGS=-Ffoo cargo test"));
        assert!(is_cargo_check_line("run: cargo check --features xla-shared-client"));
        assert!(!is_cargo_check_line("run: cargo test --features xla-shared-client"));
    }
}
