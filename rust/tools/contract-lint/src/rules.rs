//! The four contract rules (`docs/static-analysis.md` maps each to the
//! prose contract it mechanizes):
//!
//! * `meter-bypass` — raw transfer primitives outside the metered
//!   wrapper section of `runtime/mod.rs` (transfer contract §5).
//! * `unsafe-safety` / the UNSAFE_LEDGER — every `unsafe` item carries a
//!   `SAFETY:` comment and a ledger entry with a content hash.
//! * `donation` — programs whose compile-layer metadata donates inputs
//!   may only run through the `_donated` execution APIs.
//! * `lock-order` — the declared acquisition order for the scheduler's
//!   and artifact cache's lock hierarchy.

use std::fmt::Write as _;

use crate::scan::{receiver_path, token_hits, SourceFile};

#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    /// Grouping key for the allowlist (the matched call token, or a
    /// rule-specific stand-in).
    pub token: String,
    pub msg: String,
}

// ---------------------------------------------------------------- meter

/// PJRT client primitives: the metered wrappers in `runtime/mod.rs` are
/// the only code allowed to touch these (transfer contract §5 — every
/// host↔device crossing records bytes before anything else sees them).
const CLIENT_PRIMS: &[&str] = &[".execute_b(", ".to_literal_sync(", ".buffer_from_host_buffer("];

/// Globally-metered wrappers whose per-run-meter twins end in
/// `_metered`: outside `runtime/mod.rs` these bypass per-run accounting,
/// so each use needs an allowlist entry explaining where the bytes land.
const WRAPPER_RAWS: &[&str] =
    &[".execute_raw(", ".execute_raw_donated(", ".execute_buffers(", ".download_output("];

/// Runtime upload/download helpers: raw when called on a `Runtime`
/// receiver (`rt` / `self.rt` / `runtime`); the same method names on a
/// `TransferMeter` receiver are the metered path and are fine.
const RT_HELPERS: &[&str] =
    &[".upload_f32(", ".upload_i32(", ".upload_scalar(", ".upload_tensor(", ".download_f32("];

const METER_EXEMPT_FILE: &str = "rust/src/runtime/mod.rs";

pub fn meter_bypass(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        if f.rel == METER_EXEMPT_FILE {
            continue; // the metered-wrapper section itself
        }
        for (i, line) in f.code.iter().enumerate() {
            if f.test[i] {
                continue;
            }
            for &tok in CLIENT_PRIMS {
                for _ in token_hits(line, tok) {
                    out.push(Finding {
                        rule: "meter-bypass",
                        file: f.rel.clone(),
                        line: i + 1,
                        token: tok.to_string(),
                        msg: format!(
                            "PJRT client primitive `{tok})` outside runtime/mod.rs — every \
                             host<->device crossing must go through the metered wrappers"
                        ),
                    });
                }
            }
            for &tok in WRAPPER_RAWS {
                for _ in token_hits(line, tok) {
                    out.push(Finding {
                        rule: "meter-bypass",
                        file: f.rel.clone(),
                        line: i + 1,
                        token: tok.to_string(),
                        msg: format!(
                            "`{tok})` records global stats only — per-run accounting needs the \
                             `_metered` variant (or an allowlist entry saying where bytes land)"
                        ),
                    });
                }
            }
            for &tok in RT_HELPERS {
                for at in token_hits(line, tok) {
                    let recv = receiver_path(line, at);
                    let last = recv.rsplit('.').next().unwrap_or("");
                    if last == "rt" || last == "runtime" {
                        out.push(Finding {
                            rule: "meter-bypass",
                            file: f.rel.clone(),
                            line: i + 1,
                            token: tok.to_string(),
                            msg: format!(
                                "`{recv}{tok})` is the unmetered Runtime helper — route through \
                                 a TransferMeter (or allowlist with the accounting story)"
                            ),
                        });
                    }
                }
            }
        }
    }
    out
}

// --------------------------------------------------------------- unsafe

/// One `unsafe` item: its location, the raw context block (contiguous
/// comment/attribute lines directly above plus the item line), whether a
/// `SAFETY:` marker is present, and the extracted rationale.
pub struct UnsafeSite {
    pub file: String,
    pub line: usize,
    pub has_safety: bool,
    pub rationale: String,
    pub hash: u64,
}

fn is_unsafe_item(code_line: &str) -> bool {
    for at in token_hits(code_line, "unsafe") {
        let rest = &code_line[at + "unsafe".len()..];
        let rest = rest.trim_start();
        if rest.starts_with("impl")
            || rest.starts_with("fn")
            || rest.starts_with("trait")
            || rest.starts_with('{')
            || rest.is_empty()
        {
            return true;
        }
    }
    false
}

pub fn unsafe_sites(files: &[SourceFile]) -> Vec<UnsafeSite> {
    let mut out = Vec::new();
    for f in files {
        for (i, code) in f.code.iter().enumerate() {
            if !is_unsafe_item(code) {
                continue;
            }
            // context: contiguous comment/attribute lines directly above
            let mut start = i;
            while start > 0 {
                let t = f.raw[start - 1].trim_start();
                if t.starts_with("//") || t.starts_with("#[") {
                    start -= 1;
                } else {
                    break;
                }
            }
            let ctx: Vec<&str> = f.raw[start..=i].iter().map(|l| l.trim()).collect();
            let safety_line = ctx.iter().find(|l| l.contains("SAFETY:"));
            let rationale = safety_line
                .map(|l| {
                    let after = &l[l.find("SAFETY:").unwrap() + "SAFETY:".len()..];
                    let mut r = after.trim().to_string();
                    if r.len() > 160 {
                        r.truncate(157);
                        r.push_str("...");
                    }
                    if r.is_empty() {
                        "(see comment)".to_string()
                    } else {
                        r
                    }
                })
                .unwrap_or_default();
            out.push(UnsafeSite {
                file: f.rel.clone(),
                line: i + 1,
                has_safety: safety_line.is_some(),
                rationale,
                hash: fnv1a64(&ctx.join("\n")),
            });
        }
    }
    out
}

pub fn fnv1a64(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

pub fn unsafe_safety(files: &[SourceFile]) -> Vec<Finding> {
    unsafe_sites(files)
        .into_iter()
        .filter(|s| !s.has_safety)
        .map(|s| Finding {
            rule: "unsafe-safety",
            file: s.file,
            line: s.line,
            token: "unsafe".to_string(),
            msg: "`unsafe` item without a `// SAFETY:` comment directly above it".to_string(),
        })
        .collect()
}

pub const LEDGER_HEADER: &str = "\
# UNSAFE_LEDGER — generated by `contract-lint unsafe-ledger --write`. Do not edit by hand.
# One entry per `unsafe` item in rust/src: file:line|fnv1a64(comment+attrs+item)|rationale.
# CI regenerates this file and fails on any diff, so moving, adding, or rewording an
# unsafe item is always a reviewed change (docs/static-analysis.md, unsafe ledger).
";

pub fn generate_ledger(files: &[SourceFile]) -> String {
    let mut out = String::from(LEDGER_HEADER);
    for s in unsafe_sites(files) {
        let _ = writeln!(out, "{}:{}|{:016x}|{}", s.file, s.line, s.hash, s.rationale);
    }
    out
}

/// Compare the committed ledger against the generated one; precise
/// per-line drift messages.
pub fn check_ledger(files: &[SourceFile], committed: Option<&str>) -> Vec<String> {
    let generated = generate_ledger(files);
    let committed = match committed {
        Some(c) => c,
        None => {
            return vec![
                "rust/UNSAFE_LEDGER is missing — run `contract-lint unsafe-ledger --write` \
                 and commit it"
                    .to_string(),
            ]
        }
    };
    if committed == generated {
        return Vec::new();
    }
    let mut errs = Vec::new();
    let gen_lines: Vec<&str> = generated.lines().collect();
    let com_lines: Vec<&str> = committed.lines().collect();
    for i in 0..gen_lines.len().max(com_lines.len()) {
        let g = gen_lines.get(i).copied();
        let c = com_lines.get(i).copied();
        if g != c {
            errs.push(format!(
                "UNSAFE_LEDGER drift at line {}: committed {:?}, generated {:?} — regenerate \
                 with `contract-lint unsafe-ledger --write` (an unledgered or moved unsafe \
                 item is a reviewed change)",
                i + 1,
                c.unwrap_or("<missing>"),
                g.unwrap_or("<missing>")
            ));
            break; // first drift is enough; the fix regenerates everything
        }
    }
    errs
}

// ------------------------------------------------------------- donation

/// Program names that donate inputs, derived from the compile layer's
/// source of truth (`python/compile/model.py`): `PROGRAM_DONATE` keys
/// verbatim, `BATCHED_DONATE` keys with the `_batched` suffix the AOT
/// emitter appends (`adam_apply_batched{R}` → base `adam_apply_batched`).
pub fn donating_programs(model_py: &str) -> Vec<String> {
    let mut out = Vec::new();
    for (dict, suffix) in [("PROGRAM_DONATE", ""), ("BATCHED_DONATE", "_batched")] {
        let mut inside = false;
        for line in model_py.lines() {
            let t = line.trim();
            if t.starts_with(dict) && t.contains('{') {
                inside = true;
                continue;
            }
            if inside {
                if t.starts_with('}') {
                    inside = false;
                    continue;
                }
                if let Some(open) = t.find('"') {
                    if let Some(close) = t[open + 1..].find('"') {
                        out.push(format!("{}{}", &t[open + 1..open + 1 + close], suffix));
                    }
                }
            }
        }
    }
    out.sort();
    out.dedup();
    out
}

/// Execution APIs that are wrong on a donating program (they either
/// refuse at runtime or silently invalidate borrowed buffers on older
/// layers — the lint makes it a compile-time-shaped failure).
const NONDONATED_EXEC: &[&str] = &[".execute_raw(", ".execute_buffers(", ".execute_buffers_metered("];

pub fn donation(files: &[SourceFile], donating: &[String]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        // ident -> donating program name, from `let X = …program("N")` /
        // `field: …program("N")` association lines.
        let mut assoc: Vec<(String, String)> = Vec::new();
        for (i, code) in f.code.iter().enumerate() {
            if f.test[i] {
                continue;
            }
            for at in token_hits(code, ".program(") {
                // read the name from the raw line (string contents are
                // blanked in `code`); columns align by construction.
                let raw_tail = &f.raw[i][at + ".program(".len()..];
                let Some(q0) = raw_tail.find('"') else { continue };
                let Some(q1) = raw_tail[q0 + 1..].find('"') else { continue };
                let name = raw_tail[q0 + 1..q0 + 1 + q1]
                    .split('{')
                    .next()
                    .unwrap_or("")
                    .to_string();
                if !donating.iter().any(|d| d == &name) {
                    continue;
                }
                for ident in binding_idents(code) {
                    assoc.push((ident, name.clone()));
                }
            }
        }
        if assoc.is_empty() {
            continue;
        }
        for (i, code) in f.code.iter().enumerate() {
            if f.test[i] {
                continue;
            }
            for &tok in NONDONATED_EXEC {
                for at in token_hits(code, tok) {
                    let recv = receiver_path(code, at);
                    let last = recv.rsplit('.').next().unwrap_or("").to_string();
                    if let Some((_, prog)) = assoc.iter().find(|(id, _)| *id == last) {
                        out.push(Finding {
                            rule: "donation",
                            file: f.rel.clone(),
                            line: i + 1,
                            token: tok.to_string(),
                            msg: format!(
                                "`{recv}` is program '{prog}', which donates inputs \
                                 (python/compile metadata) — use execute_raw_donated / \
                                 execute_raw_donated_metered"
                            ),
                        });
                    }
                }
            }
        }
    }
    out
}

/// Idents bound on an association line: `let a = …` / `let (a, b) = …` /
/// a struct-field init `name: …`.
fn binding_idents(code: &str) -> Vec<String> {
    let t = code.trim_start();
    if let Some(rest) = t.strip_prefix("let ") {
        if let Some(eq) = rest.find('=') {
            return rest[..eq]
                .split(|c: char| !(c.is_alphanumeric() || c == '_'))
                .filter(|w| !w.is_empty() && *w != "mut" && *w != "ref")
                .map(str::to_string)
                .collect();
        }
    }
    if let Some(colon) = t.find(':') {
        let head = &t[..colon];
        if !head.is_empty() && head.chars().all(|c| c.is_alphanumeric() || c == '_') {
            return vec![head.to_string()];
        }
    }
    Vec::new()
}

// ----------------------------------------------------------- lock order

/// Declared acquisition order for the two lock hierarchies under
/// `rust/src/sched/`. While holding a lock of level `L`, only locks with
/// a level **strictly greater** than `L` may be acquired. Levels mirror
/// the prose contracts: a pack leader locks the pool, then a mate's
/// handle state, then its data slot; `take_next` runs under the queue
/// state lock and may touch tenants and handle states; the ArtifactCache
/// *releases* its map lock before any slot lock (so `cache.map` sits
/// above everything it must never be held across).
fn lock_name(rel: &str, expr: &str) -> Option<(&'static str, u8)> {
    let cleaned = expr.trim().trim_start_matches('&').trim_start_matches("mut ").trim();
    let cleaned = cleaned.strip_prefix("self.").unwrap_or(cleaned);
    let segs: Vec<&str> = cleaned.split('.').collect();
    let last = *segs.last()?;
    if rel.ends_with("sched/queue.rs") {
        return match last {
            "state" => {
                if segs.len() >= 2 && segs[segs.len() - 2] == "shared" {
                    Some(("queue.state", 20))
                } else {
                    Some(("handle.state", 35))
                }
            }
            "pack_pool" => Some(("queue.pack_pool", 10)),
            "tenants" => Some(("queue.tenants", 30)),
            "running" => Some(("queue.running", 32)),
            "feed" => Some(("stream.feed", 33)),
            "streams" => Some(("queue.streams", 34)),
            "data" | "slot" => Some(("queue.pack_data", 38)),
            "windows" => Some(("queue.windows", 41)),
            "quotas" => Some(("queue.quotas", 42)),
            "quantum" => Some(("queue.quantum", 43)),
            "park_file" => Some(("queue.park_file", 50)),
            _ => None,
        };
    }
    if rel.ends_with("sched/mod.rs") {
        return match last {
            "cached" => Some(("cache.map", 60)),
            "slot" => Some(("cache.slot", 45)),
            "pins" => Some(("cache.pins", 55)),
            "queue" => Some(("pool.queue", 70)),
            "slots" => Some(("pool.slots", 71)),
            _ => None,
        };
    }
    None
}

fn registry_level(name: &str) -> Option<u8> {
    // the union of both file registries, for `holds` directives
    for (n, l) in [
        ("queue.pack_pool", 10),
        ("queue.state", 20),
        ("queue.tenants", 30),
        ("queue.running", 32),
        ("stream.feed", 33),
        ("queue.streams", 34),
        ("handle.state", 35),
        ("queue.pack_data", 38),
        ("queue.windows", 41),
        ("queue.quotas", 42),
        ("queue.quantum", 43),
        ("queue.park_file", 50),
        ("cache.slot", 45),
        ("cache.pins", 55),
        ("cache.map", 60),
        ("pool.queue", 70),
        ("pool.slots", 71),
    ] {
        if n == name {
            return Some(l);
        }
    }
    None
}

struct Held {
    name: &'static str,
    level: u8,
    /// Brace depth at acquisition; the guard dies when depth drops below.
    depth: i32,
    /// Binding ident (`let g = lock(…);`), for `drop(g)` release. `None`
    /// for a `holds` directive (lives for the whole function).
    ident: Option<String>,
}

pub fn lock_order(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        if !f.rel.contains("/sched/") {
            continue;
        }
        let mut held: Vec<Held> = Vec::new();
        let mut depth: i32 = 0;
        for (i, code) in f.code.iter().enumerate() {
            if f.test[i] {
                // keep brace tracking honest across masked regions
                depth += brace_delta(code);
                held.retain(|h| h.depth <= depth);
                continue;
            }
            // function start: reset held to the declared directives
            if !token_hits(code, "fn ").is_empty() && code.contains('(') {
                held.clear();
                let mut j = i;
                while j > 0 {
                    let t = f.raw[j - 1].trim_start();
                    if t.starts_with("//") || t.starts_with("#[") {
                        if let Some(pos) = t.find("contract-lint: holds ") {
                            let name_part =
                                t[pos + "contract-lint: holds ".len()..].split_whitespace().next();
                            if let Some(name) = name_part {
                                match registry_level(name) {
                                    Some(level) => {
                                        // leak a 'static name via the registry
                                        let name = registry_static(name);
                                        held.push(Held { name, level, depth: depth + 1, ident: None });
                                    }
                                    None => out.push(Finding {
                                        rule: "lock-order",
                                        file: f.rel.clone(),
                                        line: j,
                                        token: "holds-directive".to_string(),
                                        msg: format!(
                                            "`contract-lint: holds {name}` names an unregistered \
                                             lock"
                                        ),
                                    }),
                                }
                            }
                        }
                        j -= 1;
                    } else {
                        break;
                    }
                }
            }
            // releases via drop(ident)
            for at in token_hits(code, "drop(") {
                let arg = paren_arg(code, at + "drop(".len());
                held.retain(|h| h.ident.as_deref() != Some(arg.trim()));
            }
            // acquisitions
            for at in token_hits(code, "lock(") {
                let arg = paren_arg(code, at + "lock(".len());
                match lock_name(&f.rel, &arg) {
                    None => out.push(Finding {
                        rule: "lock-order",
                        file: f.rel.clone(),
                        line: i + 1,
                        token: "unregistered".to_string(),
                        msg: format!(
                            "lock(&{}) is not in the lock-order registry — add it to \
                             contract-lint's registry with a level (docs/static-analysis.md)",
                            arg.trim()
                        ),
                    }),
                    Some((name, level)) => {
                        for h in &held {
                            if level <= h.level {
                                out.push(Finding {
                                    rule: "lock-order",
                                    file: f.rel.clone(),
                                    line: i + 1,
                                    token: name.to_string(),
                                    msg: format!(
                                        "acquires `{name}` (level {level}) while holding \
                                         `{}` (level {}) — violates the declared order",
                                        h.name, h.level
                                    ),
                                });
                            }
                        }
                        // pure binding (`let g = lock(…);`) → guard persists
                        let head = code[..at].trim_start();
                        let tail_ok = {
                            let after = at + "lock(".len() + arg.len() + 1;
                            code.get(after..).map(|t| t.trim() == ";").unwrap_or(false)
                        };
                        if tail_ok {
                            if let Some(ident) = pure_binding_ident(head) {
                                // the guard lives at the depth in effect
                                // *at the hit* (braces earlier on this
                                // line included), dying when its block
                                // closes
                                held.push(Held {
                                    name,
                                    level,
                                    depth: depth + brace_delta(&code[..at]),
                                    ident: Some(ident),
                                });
                            }
                        }
                    }
                }
            }
            depth += brace_delta(code);
            held.retain(|h| h.depth <= depth);
        }
    }
    out
}

fn registry_static(name: &str) -> &'static str {
    match name {
        "queue.pack_pool" => "queue.pack_pool",
        "queue.state" => "queue.state",
        "queue.tenants" => "queue.tenants",
        "queue.running" => "queue.running",
        "stream.feed" => "stream.feed",
        "queue.streams" => "queue.streams",
        "handle.state" => "handle.state",
        "queue.pack_data" => "queue.pack_data",
        "queue.windows" => "queue.windows",
        "queue.quotas" => "queue.quotas",
        "queue.quantum" => "queue.quantum",
        "queue.park_file" => "queue.park_file",
        "cache.slot" => "cache.slot",
        "cache.pins" => "cache.pins",
        "cache.map" => "cache.map",
        "pool.queue" => "pool.queue",
        "pool.slots" => "pool.slots",
        _ => "unknown",
    }
}

fn brace_delta(code: &str) -> i32 {
    let mut d = 0;
    for c in code.chars() {
        match c {
            '{' => d += 1,
            '}' => d -= 1,
            _ => {}
        }
    }
    d
}

/// The balanced-paren argument starting at `from` (just past the opening
/// paren of a call); best-effort on a single line.
fn paren_arg(code: &str, from: usize) -> String {
    let mut depth = 1;
    let mut end = from;
    for (off, c) in code[from..].char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    end = from + off;
                    break;
                }
            }
            _ => {}
        }
    }
    code[from..end].to_string()
}

/// `let g = ` / `let mut g = ` prefix (already trimmed) → `g`.
fn pure_binding_ident(head: &str) -> Option<String> {
    let rest = head.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let eq = rest.find('=')?;
    let ident = rest[..eq].trim();
    if !ident.is_empty() && ident.chars().all(|c| c.is_alphanumeric() || c == '_') {
        Some(ident.to_string())
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::SourceFile;

    fn sf(rel: &str, src: &str) -> SourceFile {
        SourceFile::parse(rel, src)
    }

    // ---- meter-bypass fixtures

    #[test]
    fn meter_bypass_fires_on_client_prims_and_raw_wrappers() {
        let bad = sf(
            "rust/src/train/x.rs",
            "fn f(c: &C, p: &P, rt: &R) {\n    c.buffer_from_host_buffer(d, s, None);\n    \
             p.execute_raw(&i);\n    rt.upload_f32(&d, &s);\n}\n",
        );
        let fs = meter_bypass(&[bad]);
        let toks: Vec<&str> = fs.iter().map(|f| f.token.as_str()).collect();
        assert!(toks.contains(&".buffer_from_host_buffer("));
        assert!(toks.contains(&".execute_raw("));
        assert!(toks.contains(&".upload_f32("));
        assert_eq!(fs.len(), 3);
    }

    #[test]
    fn meter_bypass_passes_metered_calls_tests_and_runtime_itself() {
        let good = sf(
            "rust/src/train/x.rs",
            "fn f(p: &P, m: &M, rt: &R) {\n    p.execute_raw_donated_metered(i, Some(m));\n    \
             p.execute_buffers_metered(&i, None);\n    m.upload_f32(rt, &d, &s);\n}\n\
             #[cfg(test)]\nmod tests {\n    fn t(rt: &R) { rt.upload_f32(&d, &s); }\n}\n",
        );
        assert!(meter_bypass(&[good]).is_empty());
        let runtime = sf("rust/src/runtime/mod.rs", "fn f(c: &C) { c.execute_b(&i); }\n");
        assert!(meter_bypass(&[runtime]).is_empty());
    }

    // ---- unsafe ledger fixtures

    #[test]
    fn unsafe_without_safety_comment_fires() {
        let bad = sf("rust/src/x.rs", "unsafe impl Send for T {}\n");
        let fs = unsafe_safety(&[bad]);
        assert_eq!(fs.len(), 1);
        assert_eq!(fs[0].rule, "unsafe-safety");
    }

    #[test]
    fn safety_comment_above_attrs_passes_and_lands_in_ledger() {
        let good = sf(
            "rust/src/x.rs",
            "// SAFETY: T is immutable after construction.\n#[cfg(feature = \"x\")]\n\
             unsafe impl Send for T {}\n",
        );
        assert!(unsafe_safety(std::slice::from_ref(&good)).is_empty());
        let ledger = generate_ledger(&[good]);
        assert!(ledger.contains("rust/src/x.rs:3|"));
        assert!(ledger.contains("|T is immutable after construction."));
    }

    #[test]
    fn ledger_drift_is_reported_and_regeneration_is_stable() {
        let f = sf(
            "rust/src/x.rs",
            "// SAFETY: fine.\nunsafe impl Send for T {}\n",
        );
        let committed = generate_ledger(std::slice::from_ref(&f));
        assert!(check_ledger(std::slice::from_ref(&f), Some(&committed)).is_empty());
        // moving the item one line (drift) must fail against the old ledger
        let moved = sf(
            "rust/src/x.rs",
            "\n// SAFETY: fine.\nunsafe impl Send for T {}\n",
        );
        let errs = check_ledger(std::slice::from_ref(&moved), Some(&committed));
        assert_eq!(errs.len(), 1);
        assert!(errs[0].contains("drift"));
        assert!(check_ledger(std::slice::from_ref(&f), None)[0].contains("missing"));
    }

    #[test]
    fn unsafe_in_comments_and_strings_is_ignored() {
        let f = sf(
            "rust/src/x.rs",
            "// unsafe impl Send would be wrong here\nlet s = \"unsafe { }\";\n",
        );
        assert!(unsafe_sites(&[f]).is_empty());
    }

    // ---- donation fixtures

    const MODEL_PY: &str = "\
PROGRAM_DONATE = {
    \"grad_accum\": (0,),
    \"adam_apply\": (0, 1, 2, 4),
}
BATCHED_DONATE = {
    \"adam_apply\": (0, 1, 2, 4),
}
";

    #[test]
    fn donating_program_names_include_batched_suffix() {
        let names = donating_programs(MODEL_PY);
        assert_eq!(names, vec!["adam_apply", "adam_apply_batched", "grad_accum"]);
    }

    #[test]
    fn donation_fires_on_nondonated_api_and_passes_donated() {
        let donating = donating_programs(MODEL_PY);
        let bad = sf(
            "rust/src/train/x.rs",
            "let adam_prog = art.program(\"adam_apply\")?;\nlet o = adam_prog.execute_raw(&i)?;\n",
        );
        let fs = donation(&[bad], &donating);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("adam_apply"));
        let good = sf(
            "rust/src/train/x.rs",
            "let adam_prog = art.program(\"adam_apply\")?;\n\
             let grad_prog = art.program(\"grad_step\")?;\n\
             let o = adam_prog.execute_raw_donated(i)?;\n\
             let g = grad_prog.execute_raw(&i)?;\n",
        );
        assert!(donation(&[good], &donating).is_empty());
    }

    #[test]
    fn donation_tracks_format_batched_names() {
        let donating = donating_programs(MODEL_PY);
        let bad = sf(
            "rust/src/train/x.rs",
            "let adam_prog = art.program(&format!(\"adam_apply_batched{runs}\"))?;\n\
             let o = adam_prog.execute_buffers(&i)?;\n",
        );
        let fs = donation(&[bad], &donating);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].msg.contains("adam_apply_batched"));
    }

    // ---- lock-order fixtures

    #[test]
    fn lock_order_passes_declared_order_and_fires_on_inversion() {
        let good = sf(
            "rust/src/sched/queue.rs",
            "fn f(shared: &S) {\n    let mut pool = lock(&shared.pack_pool);\n    \
             let mut st = lock(&mate.handle.state);\n    lock(&mate.data).take();\n}\n",
        );
        assert!(lock_order(&[good]).is_empty());
        let bad = sf(
            "rust/src/sched/queue.rs",
            "fn f(shared: &S) {\n    let mut st = lock(&handle.state);\n    \
             lock(&shared.pack_pool).clear();\n}\n",
        );
        let fs = lock_order(&[bad]);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("queue.pack_pool"));
        assert!(fs[0].msg.contains("handle.state"));
    }

    #[test]
    fn guards_die_at_scope_end_or_drop() {
        let scoped = sf(
            "rust/src/sched/queue.rs",
            "fn f(shared: &S) {\n    {\n        let mut st = lock(&shared.state);\n    }\n    \
             lock(&shared.pack_pool).clear();\n}\n",
        );
        assert!(lock_order(&[scoped]).is_empty());
        let dropped = sf(
            "rust/src/sched/queue.rs",
            "fn f(shared: &S) {\n    let mut st = lock(&shared.state);\n    drop(st);\n    \
             lock(&shared.pack_pool).clear();\n}\n",
        );
        assert!(lock_order(&[dropped]).is_empty());
        let held = sf(
            "rust/src/sched/queue.rs",
            "fn f(shared: &S) {\n    let mut st = lock(&shared.state);\n    \
             lock(&shared.pack_pool).clear();\n}\n",
        );
        assert_eq!(lock_order(&[held]).len(), 1);
    }

    #[test]
    fn holds_directive_seeds_the_function() {
        let f = sf(
            "rust/src/sched/queue.rs",
            "// contract-lint: holds queue.state\nfn take(shared: &S) {\n    \
             let t = lock(&shared.tenants);\n}\n",
        );
        assert!(lock_order(&[f]).is_empty());
        let bad = sf(
            "rust/src/sched/queue.rs",
            "// contract-lint: holds queue.tenants\nfn take(shared: &S) {\n    \
             let t = lock(&shared.state);\n}\n",
        );
        assert_eq!(lock_order(&[bad]).len(), 1);
    }

    #[test]
    fn unregistered_locks_are_loud() {
        let f = sf(
            "rust/src/sched/queue.rs",
            "fn f() {\n    let g = lock(&self.mystery);\n}\n",
        );
        let fs = lock_order(&[f]);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("not in the lock-order registry"));
    }

    #[test]
    fn statement_temporaries_do_not_persist() {
        let f = sf(
            "rust/src/sched/queue.rs",
            "fn f(shared: &S, handle: &H) {\n    lock(&handle.state).finish(o);\n    \
             let mut st = lock(&shared.state);\n}\n",
        );
        // handle.state (35) is a temporary; acquiring queue.state (20)
        // afterwards is sequential, not nested.
        assert!(lock_order(&[f]).is_empty());
    }
}
