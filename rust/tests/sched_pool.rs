//! Scheduler integration (requires `make artifacts`): whole training runs
//! fanned out over the worker pool must be *bit-identical* to running them
//! sequentially — the shared runtime/program/W0 state is read-only, every
//! run owns its own engine and stream, the shared transfer meters are
//! atomic (totals exact, not approximate, under concurrency), and each
//! run's `RunSummary::transfers` comes from its engine's own
//! `TransferMeter`, so per-run byte totals are exact at any jobs level.
//!
//! In the default build (no `xla-shared-client` feature) the pool clamps
//! to one inline worker — `run_batch(4)` then exercises the sequential
//! fallback and every assertion here still holds; with the feature (and
//! an audited xla rev, see `rust/XLA_AUDIT`) the same assertions cover
//! real cross-thread execution.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use fastforward::config::{presets, FfConfig, TrainConfig};
use fastforward::runtime::Runtime;
use fastforward::sched::{ArtifactCache, PoolRun, RunSpec, WorkerPool};
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::StopRule;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn cfg(seed: u64, ff_enabled: bool) -> TrainConfig {
    let mut cfg = presets::train_config("ff-tiny_lora_r8", "medical", 1).unwrap();
    cfg.train_examples = 256;
    cfg.test_examples = 32;
    cfg.seed = seed;
    cfg.ff = FfConfig {
        enabled: ff_enabled,
        warmup_steps: 3,
        t_interval: 3,
        ..FfConfig::default()
    };
    cfg
}

/// 2 seeds × (FF off, FF on) = 4 independent runs, 8 Adam steps each.
fn specs(base: &Arc<std::collections::BTreeMap<String, fastforward::model::tensor::Tensor>>) -> Vec<RunSpec> {
    let mut out = Vec::new();
    for seed in [11u64, 12] {
        for ff in [false, true] {
            out.push(RunSpec {
                label: format!("seed{seed}/ff={ff}"),
                cfg: cfg(seed, ff),
                stop: StopRule::MaxSteps(8),
                base: Some(Arc::clone(base)),
                drain_interval: None,
            });
        }
    }
    out
}

fn run_batch(jobs: usize) -> PoolRun {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = Arc::new(ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap());
    let cache = ArtifactCache::new(root);
    WorkerPool::new(jobs).run_all(&rt, &cache, specs(&base)).unwrap()
}

#[test]
fn pool_is_bit_identical_and_meters_exactly_across_jobs_levels() {
    // One seq batch + one 4-wide batch cover both halves of the
    // scheduler's contract (determinism and exact metering) — the batches
    // are expensive (full training runs), so they are executed once.
    let seq = run_batch(1);
    let par = run_batch(4);
    assert_eq!(seq.outputs.len(), 4);
    assert_eq!(par.outputs.len(), 4);

    for (a, b) in seq.outputs.iter().zip(par.outputs.iter()) {
        assert_eq!(a.label, b.label, "submission order must be preserved");
        // per-run loss trajectories: bit-for-bit (per-step asserts give a
        // usable diagnostic; the shared helper is asserted too so this
        // test keeps covering the exact predicate selftest/bench use)
        assert_eq!(a.sgd_losses.len(), b.sgd_losses.len(), "{}", a.label);
        for (i, (x, y)) in a.sgd_losses.iter().zip(b.sgd_losses.iter()).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{}: step {i} loss diverged under jobs=4 ({x} vs {y})",
                a.label
            );
        }
        assert_eq!(
            a.summary.final_test_loss.to_bits(),
            b.summary.final_test_loss.to_bits(),
            "{}: final test loss diverged",
            a.label
        );
        assert!(a.bit_identical(b), "{}: RunOutput::bit_identical disagrees", a.label);
        assert_eq!(a.summary.adam_steps, b.summary.adam_steps, "{}", a.label);
        assert_eq!(a.summary.sim_steps, b.summary.sim_steps, "{}", a.label);
        // the readback ring behaved identically (same dispatches, same
        // drains) — concurrency must not change any run's stream schedule
        assert_eq!(a.stream.steps, b.stream.steps, "{}", a.label);
        assert_eq!(a.stream.resolved, b.stream.resolved, "{}", a.label);
        assert_eq!(a.stream.interval_drains, b.stream.interval_drains, "{}", a.label);
        // FF runs: identical stage outcomes
        assert_eq!(a.stages.len(), b.stages.len(), "{}", a.label);
        for (sa, sb) in a.stages.iter().zip(b.stages.iter()) {
            assert_eq!(sa.tau_star, sb.tau_star, "{}", a.label);
            assert_eq!(sa.at_step, sb.at_step, "{}", a.label);
        }
    }

    // Same batch of work ⇒ same aggregate host↔device traffic, whether the
    // runs executed one-at-a-time or four-wide: the shared meters are
    // atomics (fetch_add), so concurrent updates tally exactly — a lost
    // update would show up here as a shortfall at jobs=4.
    assert_eq!(seq.transfers.uploads, par.transfers.uploads);
    assert_eq!(seq.transfers.uploaded_bytes, par.transfers.uploaded_bytes);
    assert_eq!(seq.transfers.downloads, par.transfers.downloads);
    assert_eq!(seq.transfers.downloaded_bytes, par.transfers.downloaded_bytes);
    assert_eq!(seq.transfers.donations, par.transfers.donations);
    assert_eq!(seq.transfers.donated_bytes, par.transfers.donated_bytes);
    assert!(seq.transfers.uploaded_bytes > 0, "batch moved real bytes");
}

#[test]
fn per_run_transfers_equal_solo_baselines_and_sum_to_the_batch_total() {
    // The per-engine TransferMeter contract: a run's
    // `RunSummary::transfers` is *its own* traffic, byte-for-byte,
    // at any jobs level. The PR-4 window approach (diffing the shared
    // global meters around the run) fails this whenever sibling runs
    // share the batch; the per-engine meter must match the solo-run
    // baseline exactly, and the batch's boundary-measured global window
    // must equal the sum of the per-run meters.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = Arc::new(ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap());
    let cache = ArtifactCache::new(root);
    let mk = |label: &str, seed: u64, ff: bool| RunSpec {
        label: label.to_string(),
        cfg: cfg(seed, ff),
        stop: StopRule::MaxSteps(6),
        base: Some(Arc::clone(&base)),
        drain_interval: None,
    };
    // solo baselines: one run per batch — nothing else can pollute even
    // a window, so solo per-run numbers are ground truth
    let solo_a = WorkerPool::new(1).run_all(&rt, &cache, vec![mk("a", 21, false)]).unwrap();
    let solo_b = WorkerPool::new(1).run_all(&rt, &cache, vec![mk("b", 22, true)]).unwrap();
    // the same two specs sharing one batch (threaded when gated)
    let both = WorkerPool::new(4)
        .run_all(&rt, &cache, vec![mk("a", 21, false), mk("b", 22, true)])
        .unwrap();
    assert_eq!(
        both.outputs[0].summary.transfers,
        solo_a.outputs[0].summary.transfers,
        "run a's exact meter must match its solo baseline byte-for-byte"
    );
    assert_eq!(
        both.outputs[1].summary.transfers,
        solo_b.outputs[0].summary.transfers,
        "run b's exact meter must match its solo baseline byte-for-byte"
    );
    let summed = both.outputs[0].summary.transfers.plus(&both.outputs[1].summary.transfers);
    assert!(summed.uploaded_bytes > 0);
    assert_eq!(
        summed,
        both.transfers,
        "per-run exact meters must sum to the batch's global window"
    );
}

/// The cache must not hold its map lock across artifact I/O: loads of
/// *different* keys proceed concurrently, while racing loads of the *same*
/// key still resolve to one shared entry. Four threads hammer two distinct
/// artifacts through one cold cache; each key must come back as a single
/// shared `Arc` (loaded exactly once), and the two keys must be distinct
/// artifacts. Gated: in the default build the xla-backed state is not
/// `Sync`, so there is no cross-thread cache access to test.
#[cfg(feature = "xla-shared-client")]
#[test]
fn concurrent_loads_of_distinct_artifacts_share_one_entry_per_key() {
    use std::sync::Barrier;
    let rt = Runtime::cpu().unwrap();
    let cache = ArtifactCache::new(artifacts_root());
    const KEYS: [&str; 2] = ["ff-tiny_lora_r8", "ff-tiny_lora_r8_pallas"];
    let barrier = Barrier::new(4);
    let loaded = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let (rt, cache, barrier) = (&rt, &cache, &barrier);
                s.spawn(move || {
                    barrier.wait(); // all four race the cold cache at once
                    cache.load(rt, KEYS[i % 2]).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
    });
    for (i, art) in loaded.iter().enumerate() {
        let again = cache.load(&rt, KEYS[i % 2]).unwrap();
        assert!(
            Arc::ptr_eq(art, &again),
            "'{}' was loaded more than once under contention",
            KEYS[i % 2]
        );
    }
    assert!(
        !Arc::ptr_eq(&loaded[0], &loaded[1]),
        "distinct keys must resolve to distinct artifacts"
    );
}

#[test]
fn pool_propagates_run_errors_with_the_failing_label() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let cache = ArtifactCache::new(root);
    let mut bad = cfg(1, false);
    bad.artifact = "no_such_artifact".into();
    let err = WorkerPool::new(2)
        .run_all(
            &rt,
            &cache,
            vec![RunSpec {
                label: "bad".into(),
                cfg: bad,
                stop: StopRule::MaxSteps(1),
                base: None,
                drain_interval: None,
            }],
        )
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no_such_artifact"), "{msg}");
}
