//! FF-specific integration (requires `make artifacts`): the line search on
//! the real loss surface, the Fig 10 fixed-τ probe, and the full-rank
//! failure mode (Fig 8) at the trainer level.

use std::path::{Path, PathBuf};

use fastforward::config::{presets, FfConfig, TrainConfig};
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::Trainer;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn cfg(artifact: &str, task: &str) -> TrainConfig {
    let mut cfg = presets::train_config(artifact, task, 1).unwrap();
    cfg.train_examples = 512;
    cfg.test_examples = 64;
    cfg.ff = FfConfig { warmup_steps: 4, t_interval: 4, ..FfConfig::default() };
    cfg
}

#[test]
fn ff_stage_improves_val_loss_early_in_training() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut c = cfg("ff-tiny_lora_r8", "medical");
    // exercise the paper's exact stop rule (any increase ends the stage)
    c.ff.min_rel_improvement = 0.0;
    let mut t = Trainer::new(&rt, &root, c, Some(&base)).unwrap();
    for _ in 0..6 {
        t.sgd_step().unwrap();
    }
    let stats = t.ff_stage().unwrap();
    assert!(stats.tau_star > 0, "early FF stage found no extrapolation: {stats:?}");
    assert!(stats.final_loss < stats.baseline_loss);
    assert_eq!(stats.probes, stats.tau_star + 1); // one rejected probe
    assert!(stats.grad_norm > 0.0);
}

#[test]
fn fixed_probe_is_convex_ish_and_restores_params() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut t = Trainer::new(&rt, &root, cfg("ff-tiny_lora_r8", "medical"), Some(&base)).unwrap();
    for _ in 0..6 {
        t.sgd_step().unwrap();
    }
    let before = t.trainables().unwrap();
    let losses = t.ff_probe_fixed(30).unwrap();
    let after = t.trainables().unwrap();
    // probe must not move the weights
    for (a, b) in before.iter().zip(after.iter()) {
        assert_eq!(a.data, b.data);
    }
    assert_eq!(losses.len(), 31);
    // the minimum should not be at τ=0 (there is something to gain) and
    // the curve should rise after its vertex (stop rule is meaningful)
    let argmin = losses
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert!(argmin > 0, "losses: {losses:?}");
    assert!(losses[30] >= losses[argmin]);
}

#[test]
fn full_rank_ff_fizzles_while_lora_extrapolates() {
    // Paper Fig 8: at full rank (attention-only), FF dies at/immediately
    // after the first simulated step at the mode's well-tuned lr, while
    // LoRA at its operating point extrapolates for several steps.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();

    let mean_tau = |artifact: &str, lr_override: Option<f32>| -> f64 {
        let mut c = cfg(artifact, "medical");
        if let Some(lr) = lr_override {
            c.lr = lr;
        }
        let mut t = Trainer::new(&rt, &root, c, Some(&base)).unwrap();
        let mut total = 0usize;
        for _ in 0..3 {
            for _ in 0..6 {
                t.sgd_step().unwrap();
            }
            total += t.ff_stage().unwrap().tau_star;
        }
        total as f64 / 3.0
    };

    let full = mean_tau("ff-tiny_full_attn", Some(1.2e-2)); // full-rank operating point
    let lora = mean_tau("ff-tiny_lora_r8", None); // preset operating point
    assert!(full <= 1.5, "full-rank FF extrapolated too much: mean τ* {full}");
    assert!(
        lora > full,
        "LoRA FF should out-extrapolate full rank: {lora} vs {full}"
    );
}

#[test]
fn dora_ff_also_extrapolates() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut t = Trainer::new(&rt, &root, cfg("ff-tiny_dora_r8", "medical"), Some(&base)).unwrap();
    for _ in 0..6 {
        t.sgd_step().unwrap();
    }
    let stats = t.ff_stage().unwrap();
    assert!(stats.tau_star > 0, "DoRA FF stage empty: {stats:?}");
}
