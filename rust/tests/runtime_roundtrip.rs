//! Integration: load real AOT artifacts (requires `make artifacts`), run
//! every program on the PJRT CPU client, and validate training numerics
//! end-to-end across the language boundary.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use fastforward::model::init::init_params;
use fastforward::model::tensor::Tensor;
use fastforward::runtime::{
    Artifact, ArtifactIndex, ExecStream, InputBuf, ParamSet, PendingLoss, PendingStep, Runtime,
    SyncReason,
};

fn artifacts_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn load(key: &str) -> (Arc<Runtime>, Artifact) {
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let art = Artifact::load(&rt, &artifacts_root().join(key)).expect("artifact");
    (rt, art)
}

fn mk_batch(b: usize, t: usize, vocab: usize, seed: u64) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let mut rng = fastforward::util::rng::Rng::new(seed);
    let tokens: Vec<i32> = (0..b * t).map(|_| rng.below(vocab) as i32).collect();
    let targets: Vec<i32> = (0..b * t).map(|_| rng.below(vocab) as i32).collect();
    (tokens, targets, vec![1.0; b * t])
}

#[test]
fn index_lists_smoke_artifacts() {
    let idx = ArtifactIndex::load(&artifacts_root()).expect("index.json");
    assert!(idx.entries.iter().any(|e| e.key == "ff-tiny_lora_r8"));
    let man = idx.manifest("ff-tiny_lora_r8").expect("manifest cross-check");
    assert_eq!(man.config.model.d_model, 64);
    assert!(idx.manifest("bogus_key").is_err());
}

#[test]
fn eval_loss_of_fresh_model_is_log_vocab() {
    let (rt, art) = load("ff-tiny_lora_r8");
    let man = &art.manifest;
    let vals = init_params(&man.config, 7);
    // Zero the unembed so logits are uniform → loss must be ln(V) exactly.
    let mut vals2: BTreeMap<String, Tensor> = vals.clone();
    vals2.insert("unembed".into(), Tensor::zeros(&[64, 512]));
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals2).unwrap();
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals2).unwrap();

    let prog = art.program("eval_loss").unwrap();
    let (b, t) = (man.config.model.eval_batch, man.config.model.seq_len);
    let (tokens, targets, mask) = mk_batch(b, t, 512, 1);
    let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
    let tgt = rt.upload_i32(&targets, &[b, t]).unwrap();
    let msk = rt.upload_f32(&mask, &[b, t]).unwrap();

    let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
    inputs.extend(tr.device_buffers().unwrap());
    // careful: can't hold two mutable borrows; collect frozen separately
    let fr_bufs = fr.device_buffers().unwrap();
    inputs.extend(fr_bufs);
    inputs.push(&tok);
    inputs.push(&tgt);
    inputs.push(&msk);

    let out = prog.execute_buffers(&inputs).unwrap();
    let loss = out.scalar("loss").unwrap();
    let want = (512.0f32).ln();
    assert!(
        (loss - want).abs() < 1e-3,
        "fresh-model loss {loss} != ln(512) = {want}"
    );
}

#[test]
fn train_step_decreases_loss_over_iterations() {
    let (rt, art) = load("ff-tiny_lora_r8");
    let man = &art.manifest;
    let vals = init_params(&man.config, 42);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals).unwrap();
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals).unwrap();
    let mut m = ParamSet::zeros_like(&rt, &tr);
    let mut v = ParamSet::zeros_like(&rt, &tr);

    let prog = art.program("train_step").unwrap();
    let (b, t) = (man.config.model.micro_batch, man.config.model.seq_len);
    let (tokens, targets, mask) = mk_batch(b, t, 512, 2);
    let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
    let tgt = rt.upload_i32(&targets, &[b, t]).unwrap();
    let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
    let lr = rt.upload_scalar(1e-2).unwrap();

    let n = tr.len();
    let mut losses = Vec::new();
    for step in 0..6 {
        let step_buf = rt.upload_scalar(step as f32).unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        let tr_b = tr.device_buffers().unwrap();
        inputs.extend(tr_b);
        inputs.extend(m.device_buffers().unwrap());
        inputs.extend(v.device_buffers().unwrap());
        inputs.push(&step_buf);
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        inputs.push(&lr);
        let out = prog.execute_buffers(&inputs).unwrap();
        losses.push(out.scalar("loss").unwrap());
        for i in 0..n {
            tr.set_flat(i, &out.values[1 + i]);
            m.set_flat(i, &out.values[1 + n + i]);
            v.set_flat(i, &out.values[1 + 2 * n + i]);
        }
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn pallas_artifact_matches_jnp_artifact() {
    // The L1 composition proof: identical params + batch through the
    // pallas-kernel artifact and the jnp artifact give the same loss.
    let rt = Runtime::cpu().unwrap();
    let a_jnp = Artifact::load(&rt, &artifacts_root().join("ff-tiny_lora_r8")).unwrap();
    let a_pal =
        Artifact::load(&rt, &artifacts_root().join("ff-tiny_lora_r8_pallas")).unwrap();

    let vals = init_params(&a_jnp.manifest.config, 11);
    let (b, t) = (8, 64);
    let (tokens, targets, mask) = mk_batch(b, t, 512, 3);

    let mut losses = Vec::new();
    for art in [&a_jnp, &a_pal] {
        let man = &art.manifest;
        let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals).unwrap();
        let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals).unwrap();
        // Perturb adapters so the LoRA path actually contributes.
        let delta: Vec<Tensor> =
            tr.tensors().iter().map(|x| Tensor::ones(&x.shape)).collect();
        tr.axpy(0.01, &delta);
        let prog = art.program("eval_loss").unwrap();
        let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
        let tgt = rt.upload_i32(&targets, &[b, t]).unwrap();
        let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        losses.push(prog.execute_buffers(&inputs).unwrap().scalar("loss").unwrap());
    }
    assert!(
        (losses[0] - losses[1]).abs() < 1e-4,
        "jnp={} pallas={}",
        losses[0],
        losses[1]
    );
}

#[test]
fn grad_step_plus_adam_apply_matches_train_step() {
    let (rt, art) = load("ff-tiny_lora_r8");
    let man = &art.manifest;
    let vals = init_params(&man.config, 5);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals).unwrap();
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals).unwrap();
    let mut m = ParamSet::zeros_like(&rt, &tr);
    let mut v = ParamSet::zeros_like(&rt, &tr);
    let (b, t) = (man.config.model.micro_batch, man.config.model.seq_len);
    let (tokens, targets, mask) = mk_batch(b, t, 512, 4);
    let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
    let tgt = rt.upload_i32(&targets, &[b, t]).unwrap();
    let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
    let lr = rt.upload_scalar(1e-3).unwrap();
    let step_buf = rt.upload_scalar(0.0).unwrap();
    let n = tr.len();

    // fused
    let fused = {
        let prog = art.program("train_step").unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(m.device_buffers().unwrap());
        inputs.extend(v.device_buffers().unwrap());
        inputs.push(&step_buf);
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        inputs.push(&lr);
        prog.execute_buffers(&inputs).unwrap()
    };

    // split
    let grads = {
        let prog = art.program("grad_step").unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        prog.execute_buffers(&inputs).unwrap()
    };
    // adam_apply donates t/m/v/g, so the borrowed-input decoded path is
    // rejected for it: hand the buffers over and decode selectively.
    let split: Vec<Vec<f32>> = {
        let prog = art.program("adam_apply").unwrap();
        let g_bufs: Vec<xla::PjRtBuffer> = (0..n)
            .map(|i| {
                rt.upload_f32(&grads.values[1 + i], &tr.tensors()[i].shape).unwrap()
            })
            .collect();
        let tr_b = tr.take_device_buffers().unwrap();
        let m_b = m.take_device_buffers().unwrap();
        let v_b = v.take_device_buffers().unwrap();
        let mut inputs: Vec<InputBuf> = Vec::new();
        inputs.extend(tr_b.into_iter().map(InputBuf::Donated));
        inputs.extend(m_b.into_iter().map(InputBuf::Donated));
        inputs.extend(v_b.into_iter().map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&step_buf));
        inputs.extend(g_bufs.into_iter().map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&lr));
        let outs = prog.execute_raw_donated(inputs).unwrap();
        (0..n).map(|i| prog.download_output(&outs[i], i).unwrap()).collect()
    };

    assert!((fused.scalar("loss").unwrap() - grads.scalar("loss").unwrap()).abs() < 1e-6);
    for i in 0..n {
        let a = &split[i];
        let b = &fused.values[1 + i];
        let max_d = a
            .iter()
            .zip(b.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_d < 1e-6, "param {i}: max delta {max_d}");
    }
}

#[test]
fn device_resident_train_steps_skip_reupload_and_download() {
    // The tentpole contract: retain train_step outputs as raw device
    // buffers, download only the loss scalar, and verify the param/m/v
    // upload counters stay flat after the first step while loss still
    // decreases.
    let (rt, art) = load("ff-tiny_lora_r8");
    let man = &art.manifest;
    let vals = init_params(&man.config, 9);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals).unwrap();
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals).unwrap();
    let mut m = ParamSet::zeros_like(&rt, &tr);
    let mut v = ParamSet::zeros_like(&rt, &tr);

    let prog = art.program("train_step").unwrap();
    let (b, t) = (man.config.model.micro_batch, man.config.model.seq_len);
    let (tokens, targets, mask) = mk_batch(b, t, 512, 8);
    let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
    let tgt = rt.upload_i32(&targets, &[b, t]).unwrap();
    let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
    let lr = rt.upload_scalar(1e-2).unwrap();
    let loss_i = prog.output_index("loss").unwrap();
    assert_eq!(loss_i, 0, "train_step outputs are [loss, tr.., m.., v..]");

    let n = tr.len();
    let mut losses = Vec::new();
    let mut uploads_after_first = 0;
    for step in 0..6 {
        let step_buf = rt.upload_scalar(step as f32).unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(m.device_buffers().unwrap());
        inputs.extend(v.device_buffers().unwrap());
        inputs.push(&step_buf);
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        inputs.push(&lr);
        let outs = prog.execute_raw(&inputs).unwrap();
        drop(inputs);
        // selective download: just the loss scalar crosses to the host
        losses.push(prog.download_output(&outs[loss_i], loss_i).unwrap()[0]);
        let mut it = outs.into_iter();
        drop(it.next().unwrap()); // loss buffer, already decoded
        tr.adopt_all(&mut it).unwrap();
        m.adopt_all(&mut it).unwrap();
        v.adopt_all(&mut it).unwrap();
        if step == 0 {
            uploads_after_first = tr.upload_count() + m.upload_count() + v.upload_count();
        }
    }
    let uploads_final = tr.upload_count() + m.upload_count() + v.upload_count();
    assert_eq!(
        uploads_final, uploads_after_first,
        "steady-state adam steps must not re-upload trainable/m/v"
    );
    assert_eq!(tr.download_count() + m.download_count() + v.download_count(), 0);
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
    // host views stay reachable on demand: one download per trainable
    tr.sync_host().unwrap();
    assert_eq!(tr.download_count(), n as u64);
    assert!(tr.tensors().iter().all(|t| t.data.iter().all(|x| x.is_finite())));
}

#[test]
fn device_accumulation_matches_host_mean() {
    // grad_accum + grad_finalize chained over micro-batches must equal the
    // host GradAccumulator's mean exactly (same adds, same order, same
    // 1/n scale — the device path is a relocation, not a reformulation).
    let (rt, art) = load("ff-tiny_lora_r8");
    let man = &art.manifest;
    if !man.has_program("grad_accum") {
        eprintln!("skipping: artifact predates grad_accum (regenerate with make artifacts)");
        return;
    }
    let vals = init_params(&man.config, 17);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals).unwrap();
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals).unwrap();
    let grad = art.program("grad_step").unwrap();
    let accum = art.program("grad_accum").unwrap();
    let finalize = art.program("grad_finalize").unwrap();
    let (b, t) = (man.config.model.micro_batch, man.config.model.seq_len);
    let n = tr.len();

    let mut host_acc = fastforward::optim::GradAccumulator::new(
        &(0..n).map(|i| tr.shape(i).to_vec()).collect::<Vec<_>>(),
    );
    let mut dev_acc = fastforward::optim::DeviceGradAccumulator::new();
    let n_micro = 3;
    for seed in 0..n_micro {
        let (tokens, targets, mask) = mk_batch(b, t, 512, 100 + seed);
        let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
        let tgt = rt.upload_i32(&targets, &[b, t]).unwrap();
        let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        // host side: decoded grads
        let out = grad.execute_buffers(&inputs).unwrap();
        let gslices: Vec<&[f32]> = (0..n).map(|i| out.values[1 + i].as_slice()).collect();
        host_acc.add_flat(&gslices, out.values[0][0]);
        // device side: raw grads folded through grad_accum
        let raw = grad.execute_raw(&inputs).unwrap();
        drop(inputs);
        let mut raw = raw.into_iter();
        let loss_buf = raw.next().unwrap();
        let loss = grad.download_output(&loss_buf, 0).unwrap()[0];
        dev_acc.add_raw(&accum, raw.collect(), loss).unwrap();
    }
    assert_eq!(dev_acc.count(), n_micro as usize);
    let inv_n = rt.upload_scalar(1.0 / n_micro as f32).unwrap();
    let (host_mean, host_loss) = host_acc.take_mean();
    let base = rt.stats.snapshot();
    let (dev_mean, dev_loss) = dev_acc.finalize(&finalize, &inv_n).unwrap();
    let donated = rt.stats.snapshot().since(&base);
    assert_eq!(
        donated.donations, n as u64,
        "finalize donates the whole accumulator set"
    );
    assert!((host_loss - dev_loss).abs() < 1e-6, "{host_loss} vs {dev_loss}");
    for i in 0..n {
        let dv = finalize.download_output(&dev_mean[i], i).unwrap();
        let hv = &host_mean[i].data;
        let max_d = dv
            .iter()
            .zip(hv.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_d < 1e-6, "param {i}: device vs host mean differs by {max_d}");
    }
}

#[test]
fn donated_adam_chain_reuses_state_without_reupload() {
    // The PR-2 contract at the runtime level: grad_step (raw) → donated
    // adam_apply, state adopted back each step. Uploads stay flat after
    // the first step, every state/gradient buffer is metered as donated
    // (PJRT reuses the allocations in place — addresses aren't observable
    // through the PJRT C API, so the meters + flat uploads are the
    // testable surface), and training still converges.
    let (rt, art) = load("ff-tiny_lora_r8");
    let man = &art.manifest;
    if !man.has_program("grad_accum") {
        eprintln!("skipping: artifact predates grad_accum (regenerate with make artifacts)");
        return;
    }
    let vals = init_params(&man.config, 23);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals).unwrap();
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals).unwrap();
    let mut m = ParamSet::zeros_like(&rt, &tr);
    let mut v = ParamSet::zeros_like(&rt, &tr);
    let grad = art.program("grad_step").unwrap();
    let adam = art.program("adam_apply").unwrap();
    let (b, t) = (man.config.model.micro_batch, man.config.model.seq_len);
    let (tokens, targets, mask) = mk_batch(b, t, 512, 31);
    let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
    let tgt = rt.upload_i32(&targets, &[b, t]).unwrap();
    let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
    let lr = rt.upload_scalar(1e-2).unwrap();
    let n = tr.len() as u64;

    let mut losses = Vec::new();
    let mut uploads_after_first = 0;
    for step in 0..6 {
        let step_buf = rt.upload_scalar(step as f32).unwrap();
        let mut ginputs: Vec<&xla::PjRtBuffer> = Vec::new();
        ginputs.extend(tr.device_buffers().unwrap());
        ginputs.extend(fr.device_buffers().unwrap());
        ginputs.push(&tok);
        ginputs.push(&tgt);
        ginputs.push(&msk);
        let gouts = grad.execute_raw(&ginputs).unwrap();
        drop(ginputs);
        let mut gouts = gouts.into_iter();
        let loss_buf = gouts.next().unwrap();
        losses.push(grad.download_output(&loss_buf, 0).unwrap()[0]);

        let base = rt.stats.snapshot();
        let tr_b = tr.take_device_buffers().unwrap();
        let m_b = m.take_device_buffers().unwrap();
        let v_b = v.take_device_buffers().unwrap();
        let mut inputs: Vec<InputBuf> = Vec::new();
        inputs.extend(tr_b.into_iter().map(InputBuf::Donated));
        inputs.extend(m_b.into_iter().map(InputBuf::Donated));
        inputs.extend(v_b.into_iter().map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&step_buf));
        inputs.extend(gouts.map(InputBuf::Donated));
        inputs.push(InputBuf::Borrowed(&lr));
        let outs = adam.execute_raw_donated(inputs).unwrap();
        let d = rt.stats.snapshot().since(&base);
        assert_eq!(d.donations, 4 * n, "t/m/v/g all donated");
        let mut outs = outs.into_iter();
        tr.adopt_all(&mut outs).unwrap();
        m.adopt_all(&mut outs).unwrap();
        v.adopt_all(&mut outs).unwrap();
        if step == 0 {
            uploads_after_first = tr.upload_count() + m.upload_count() + v.upload_count();
        }
    }
    assert_eq!(
        tr.upload_count() + m.upload_count() + v.upload_count(),
        uploads_after_first,
        "donated steady-state steps must not re-upload state"
    );
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
    tr.sync_host().unwrap();
    assert!(tr.tensors().iter().all(|x| x.data.iter().all(|v| v.is_finite())));
}

#[test]
fn decoded_and_raw_execution_agree() {
    let (rt, art) = load("ff-tiny_lora_r8");
    let man = &art.manifest;
    let vals = init_params(&man.config, 13);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals).unwrap();
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals).unwrap();
    let prog = art.program("eval_loss").unwrap();
    let (b, t) = (man.config.model.eval_batch, man.config.model.seq_len);
    let (tokens, targets, mask) = mk_batch(b, t, 512, 21);
    let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
    let tgt = rt.upload_i32(&targets, &[b, t]).unwrap();
    let msk = rt.upload_f32(&mask, &[b, t]).unwrap();

    let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
    inputs.extend(tr.device_buffers().unwrap());
    inputs.extend(fr.device_buffers().unwrap());
    inputs.push(&tok);
    inputs.push(&tgt);
    inputs.push(&msk);

    let decoded = prog.execute_buffers(&inputs).unwrap().scalar("loss").unwrap();
    let raw_bufs = prog.execute_raw(&inputs).unwrap();
    let loss_i = prog.output_index("loss").unwrap();
    let raw = prog.download_output(&raw_bufs[loss_i], loss_i).unwrap()[0];
    assert!(
        (decoded - raw).abs() < 1e-7,
        "decoded {decoded} != raw {raw}"
    );
}

#[test]
fn deferred_loss_readback_equals_sync_download() {
    // Stream-layer contract: a loss scalar held as a PendingLoss in the
    // ExecStream ring and drained later decodes to exactly the bits the
    // synchronous download produced — and no loss bytes cross the
    // host↔device boundary until the ring drains.
    let (rt, art) = load("ff-tiny_lora_r8");
    let man = &art.manifest;
    let vals = init_params(&man.config, 29);
    let mut tr = ParamSet::from_spec(&rt, &man.trainable, &vals).unwrap();
    let mut fr = ParamSet::from_spec(&rt, &man.frozen, &vals).unwrap();
    let prog = art.program("eval_loss").unwrap();
    let (b, t) = (man.config.model.eval_batch, man.config.model.seq_len);
    let loss_i = prog.output_index("loss").unwrap();

    let mut stream = ExecStream::new(3);
    let mut sync_losses = Vec::new();
    let mut resolved = Vec::new();
    for ticket in 0..5u64 {
        let (tokens, targets, mask) = mk_batch(b, t, 512, 200 + ticket);
        let tok = rt.upload_i32(&tokens, &[b, t]).unwrap();
        let tgt = rt.upload_i32(&targets, &[b, t]).unwrap();
        let msk = rt.upload_f32(&mask, &[b, t]).unwrap();
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::new();
        inputs.extend(tr.device_buffers().unwrap());
        inputs.extend(fr.device_buffers().unwrap());
        inputs.push(&tok);
        inputs.push(&tgt);
        inputs.push(&msk);
        // synchronous reference download
        let raw = prog.execute_raw(&inputs).unwrap();
        sync_losses.push(prog.download_output(&raw[loss_i], loss_i).unwrap()[0]);
        // deferred copy of the same dispatch
        let mut raw2 = prog.execute_raw(&inputs).unwrap();
        let loss_buf = raw2.swap_remove(loss_i);
        let deferred_window = rt.stats.snapshot(); // after this round's sync download
        let pending = PendingStep::new(ticket, vec![PendingLoss::new(&prog, loss_buf, loss_i)]);
        let depth_before = stream.depth();
        let drained = stream.push(pending).unwrap();
        if drained.is_empty() {
            // nothing crossed the boundary for the deferred dispatch
            let d = rt.stats.snapshot().since(&deferred_window);
            assert_eq!(d.downloads, 0, "deferred loss downloaded early: {d:?}");
            assert_eq!(stream.depth(), depth_before + 1);
        }
        resolved.extend(drained);
    }
    resolved.extend(stream.sync(SyncReason::Shutdown).unwrap());
    assert_eq!(resolved.len(), 5);
    for (r, want) in resolved.iter().zip(sync_losses.iter()) {
        assert_eq!(
            r.mean_loss.to_bits(),
            want.to_bits(),
            "deferred {} != sync {want}",
            r.mean_loss
        );
        assert_eq!(r.micro_losses.len(), 1);
    }
    // FIFO tickets
    assert_eq!(resolved.iter().map(|r| r.ticket).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
    let stats = stream.stats();
    assert_eq!(stats.steps, 5);
    assert_eq!(stats.interval_drains, 1, "5 pushes at K=3 → one interval drain");
    assert_eq!(stats.forced_drains.get("shutdown"), Some(&1));
}

#[test]
fn wrong_arity_is_rejected() {
    let (_rt, art) = load("ff-tiny_lora_r8");
    let prog = art.program("eval_loss").unwrap();
    let err = prog.execute_buffers(&[]).err().expect("should fail");
    assert!(format!("{err}").contains("expects"));
}
