//! Exhaustive model check of the run queue's submission lifecycle.
//!
//! The PR 7 churn harness (`selftest --queue --churn`) samples the
//! protocol under a seeded storm; this test makes the evidence
//! *exhaustive* on bounded configurations: a deterministic DFS explores
//! **every** interleaving of worker, environment, and delivery actions
//! over the pure model in `fastforward::sched::lifecycle::model` (built
//! on the same `Lifecycle` type `sched/queue.rs` consumes), checking
//! after every action that
//!
//! * **live-count conservation** holds (`live` == admitted-and-
//!   unfinished submissions),
//! * **delivery is exactly-once** (no outcome reaches `join` *and* the
//!   completions stream),
//! * **cancel beats park** (a cancelled run never re-enters the queue
//!   as `Parked`),
//! * **claims are exclusive** (no submission is ever owned by two
//!   executors — worker pop vs pack leader vs transient cancel claim),
//! * **held continuations are parked** (a streaming submission sitting
//!   data-starved off the ready list is `Parked` or a terminal husk,
//!   never `Running` — the stranded-joiner ordering bug),
//!
//! and that no reachable state is **stuck** (work remains but every
//! worker is asleep with no wakeup pending — a lost wakeup).
//!
//! Everything is deterministic by construction — fixed action
//! enumeration order, no randomness, no clocks — so a failure's printed
//! action trace reproduces it exactly.

use std::collections::HashSet;

use fastforward::sched::lifecycle::model::{Action, Config, QueueModel, Violation};

/// Why an exploration failed, with the exact action trace that did it.
#[derive(Debug)]
enum Fail {
    Violation(Violation, Vec<Action>),
    /// Incomplete state with no enabled action: a lost wakeup/deadlock.
    Stuck(Vec<Action>),
}

#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
struct Stats {
    /// Distinct states visited (memoized mode) or nodes (enumeration).
    states: u64,
    /// Complete schedules reached. In memoized mode this counts distinct
    /// complete *states*; in enumeration mode, distinct schedules.
    completes: u64,
    /// Transitions taken (every one invariant-checked).
    edges: u64,
}

fn dfs(
    m: &QueueModel,
    cfg: &Config,
    memo: &mut Option<HashSet<Vec<u8>>>,
    trace: &mut Vec<Action>,
    stats: &mut Stats,
) -> Result<(), Fail> {
    stats.states += 1;
    if m.is_complete(cfg) {
        stats.completes += 1;
        return Ok(());
    }
    let actions = m.enabled(cfg);
    if actions.is_empty() {
        return Err(Fail::Stuck(trace.clone()));
    }
    for a in actions {
        let mut next = m.fork();
        trace.push(a);
        if let Err(v) = next.apply(cfg, a) {
            return Err(Fail::Violation(v, trace.clone()));
        }
        stats.edges += 1;
        let revisit = match memo {
            Some(seen) => !seen.insert(next.encode()),
            None => false,
        };
        if !revisit {
            dfs(&next, cfg, memo, trace, stats)?;
        }
        trace.pop();
    }
    Ok(())
}

/// Explore every interleaving of `cfg`. `memoize` visits each distinct
/// state once (full invariant coverage, tractable on the big configs);
/// without it, every schedule is enumerated separately (exact counts,
/// tiny configs only).
fn explore(cfg: &Config, memoize: bool) -> Result<Stats, Fail> {
    let root = QueueModel::new(cfg);
    let mut memo = memoize.then(|| {
        let mut s = HashSet::new();
        s.insert(root.encode());
        s
    });
    let mut stats = Stats::default();
    dfs(&root, cfg, &mut memo, &mut Vec::new(), &mut stats)?;
    Ok(stats)
}

fn assert_passes(cfg: &Config) -> Stats {
    match explore(cfg, true) {
        Ok(stats) => {
            assert!(stats.completes > 0, "exploration must reach completion");
            stats
        }
        Err(Fail::Violation(v, trace)) => {
            panic!("invariant broken: {v:?}\nreproducing schedule: {trace:?}")
        }
        Err(Fail::Stuck(trace)) => {
            panic!("lost wakeup / deadlock\nreproducing schedule: {trace:?}")
        }
    }
}

#[test]
fn two_workers_three_submissions_with_cancel_park_and_join() {
    // The headline bounded config: 2 workers × 3 submissions, one
    // cancellable, one park-requestable, one joinable (racing the
    // completions stream). Every interleaving must keep all four
    // invariant families and never strand a worker.
    let cfg = Config {
        workers: 2,
        steps: vec![1, 2, 2],
        cancels: vec![1],
        parks: vec![2],
        joins: vec![0],
        ..Config::default()
    };
    let stats = assert_passes(&cfg);
    // Loose sanity floor: the run is only meaningful if the space is
    // genuinely combinatorial (exact counts live in the pure-steps
    // property test below, where they have a closed form).
    assert!(stats.states > 1_000, "suspiciously small space: {stats:?}");
}

#[test]
fn three_workers_four_submissions_with_pack_claims() {
    // Pack-claim exclusivity: submissions 0 and 2 are packable, so a
    // worker running one may claim the other out of the queue while a
    // second worker races to pop it (and a cancel races both on #3).
    let cfg = Config {
        workers: 3,
        steps: vec![2, 1, 1, 1],
        cancels: vec![3],
        packables: vec![0, 2],
        ..Config::default()
    };
    let stats = assert_passes(&cfg);
    assert!(stats.states > 1_000, "suspiciously small space: {stats:?}");
}

#[test]
fn cancel_vs_park_races_on_every_submission() {
    // Both flags may land on both submissions at any point: park while
    // cancelling, cancel while parked, cancel between park-yield and
    // re-queue. Cancel must win every time (no Parked-with-cancel state,
    // no resume after cancel).
    let cfg = Config {
        workers: 2,
        steps: vec![2, 2],
        cancels: vec![0, 1],
        parks: vec![0, 1],
        ..Config::default()
    };
    assert_passes(&cfg);
}

#[test]
fn streaming_hold_feed_with_cancel_and_join() {
    // Stream-feed lifecycle: submission 0 is streaming — its first slot
    // finds no data, parks its continuation *off* the ready list
    // (`JobYield::Held`), and only the tenant's `Feed` brings it back.
    // The feed may land before the first pop, between hold and re-pop,
    // or after a cancel already reaped the held run (re-enqueueing a
    // husk the next pop must reap); a joiner races the completions
    // stream on the batch submission throughout. No interleaving may
    // strand the held run, double-own it, or lose its single delivery.
    let cfg = Config {
        workers: 2,
        steps: vec![2, 1],
        streams: vec![0],
        cancels: vec![0],
        joins: vec![1],
        ..Config::default()
    };
    let stats = assert_passes(&cfg);
    assert!(stats.states > 200, "suspiciously small space: {stats:?}");
}

#[test]
fn two_streams_race_feeds_parks_and_a_cancel() {
    // Two streaming submissions against two workers: both hold, feeds
    // land in either order, a park request targets one stream (the park
    // flag must survive the hold and fire on the post-feed slot) and a
    // cancel targets the other (racing the hold, the held state, and
    // the resumed run). Exercises two continuations coexisting in the
    // held set and every feed/requeue/claim interleaving between them.
    let cfg = Config {
        workers: 2,
        steps: vec![2, 2],
        streams: vec![0, 1],
        parks: vec![0],
        cancels: vec![1],
        ..Config::default()
    };
    assert_passes(&cfg);
}

#[test]
fn exploration_is_deterministic() {
    // Reproducibility: two full explorations of the same config visit
    // identical state/edge/complete counts (fixed enumeration order, no
    // randomness — a failing trace replays exactly).
    let cfg = Config {
        workers: 2,
        steps: vec![1, 2],
        cancels: vec![0],
        parks: vec![1],
        ..Config::default()
    };
    let a = explore(&cfg, true).expect("passes");
    let b = explore(&cfg, true).expect("passes");
    assert_eq!(a, b);
}

#[test]
fn explorer_catches_a_seeded_park_beats_cancel_bug() {
    // Self-test of the checker: flip the model's boundary check order
    // (park before cancel — the opposite of Trainer::park_due and
    // repark_entry) and the explorer must find the interleaving where a
    // cancelled run parks anyway. If this config ever passes, the
    // checker has gone blind, not the queue correct.
    let cfg = Config {
        workers: 1,
        steps: vec![2],
        cancels: vec![0],
        parks: vec![0],
        buggy_park_before_cancel: true,
        ..Config::default()
    };
    match explore(&cfg, true) {
        Err(Fail::Violation(Violation::ParkBeatCancel { sub: 0 }, trace)) => {
            assert!(!trace.is_empty());
        }
        other => panic!("seeded bug must be caught as ParkBeatCancel, got {other:?}"),
    }
}

#[test]
fn schedule_counts_match_the_multinomial_oracle() {
    // Property test: in pure-steps mode (every worker pre-claimed on its
    // own submission, only Step actions enabled) the number of complete
    // schedules has a closed form — the multinomial coefficient
    // (s_1 + … + s_w)! / (s_1! · … · s_w!) of interleavings of the
    // workers' step sequences. The un-memoized explorer must enumerate
    // exactly that many.
    let multinomial = |steps: &[u8]| -> u64 {
        let total: u64 = steps.iter().map(|&s| s as u64).sum();
        let fact = |n: u64| (1..=n).product::<u64>();
        steps.iter().fold(fact(total), |acc, &s| acc / fact(s as u64))
    };
    for steps in [vec![2, 2], vec![1, 1, 1], vec![1, 2], vec![3, 1], vec![2, 2, 1]] {
        let cfg = Config {
            workers: steps.len(),
            steps: steps.clone(),
            pure_steps: true,
            ..Config::default()
        };
        let stats = explore(&cfg, false).expect("pure steps cannot violate");
        assert_eq!(
            stats.completes,
            multinomial(&steps),
            "schedule count for step profile {steps:?}"
        );
    }
}
