//! End-to-end trainer integration (requires `make artifacts`): pretrain a
//! tiny base model, finetune with plain Adam vs Fast Forward, and verify
//! the paper's core claim holds on this substrate — FF matches the
//! baseline's test loss with fewer FLOPs.

use std::path::{Path, PathBuf};

use fastforward::config::{presets, FfConfig, TrainConfig};
use fastforward::metrics::StepKind;
use fastforward::runtime::{Runtime, SyncReason};
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::{StopRule, Trainer};

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn tiny_cfg(ff_enabled: bool, steps: usize) -> TrainConfig {
    let mut cfg = presets::train_config("ff-tiny_lora_r8", "medical", 1).unwrap();
    cfg.max_steps = steps;
    cfg.train_examples = 512; // small corpus: fast epochs
    cfg.test_examples = 128;
    cfg.ff = FfConfig { enabled: ff_enabled, warmup_steps: 4, t_interval: 4, ..FfConfig::default() };
    cfg
}

#[test]
fn ff_matches_baseline_loss_with_fewer_flops() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();

    // Baseline: fixed-step plain Adam run.
    let steps = 48;
    let mut baseline = Trainer::new(&rt, &root, tiny_cfg(false, steps), Some(&base)).unwrap();
    let bsum = baseline.run(&StopRule::MaxSteps(steps)).unwrap();
    assert!(bsum.final_test_loss.is_finite());
    assert_eq!(bsum.adam_steps, steps);
    assert_eq!(bsum.sim_steps, 0);

    // FF: run until it matches the baseline's final test loss.
    let mut ff = Trainer::new(&rt, &root, tiny_cfg(true, steps), Some(&base)).unwrap();
    let fsum = ff
        .run(&StopRule::TargetLoss {
            target: bsum.final_test_loss,
            eps: 1e-3,
            eval_every: 4,
            max_steps: steps * 3,
        })
        .unwrap();

    assert!(fsum.reached_target, "FF never matched baseline loss: {} vs {}",
            fsum.final_test_loss, bsum.final_test_loss);
    assert!(fsum.sim_steps > 0, "FF never simulated a step");
    let saved = 1.0 - fsum.flops.total() as f64 / bsum.flops.total() as f64;
    println!(
        "baseline: {} steps, {:.3e} FLOPs; FF: {} adam + {} sim steps, {:.3e} FLOPs ({:.0}% saved)",
        bsum.adam_steps,
        bsum.flops.total() as f64,
        fsum.adam_steps,
        fsum.sim_steps,
        fsum.flops.total() as f64,
        saved * 100.0
    );
    assert!(
        fsum.flops.total() < bsum.flops.total(),
        "FF used more FLOPs: {} vs {}",
        fsum.flops.total(),
        bsum.flops.total()
    );
}

#[test]
fn pretraining_is_cached_and_reduces_loss() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let a = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    // second call loads the cache and must be identical
    let b = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    assert_eq!(a, b);
    assert!(a.contains_key("embed.tok"));
    assert!(a.contains_key("layer1.mlp.w_out"));
}

#[test]
fn trainer_logs_and_flops_are_consistent() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut t = Trainer::new(&rt, &root, tiny_cfg(true, 16), Some(&base)).unwrap();
    let sum = t.run(&StopRule::MaxSteps(16)).unwrap();
    assert_eq!(sum.adam_steps, 16);
    // log records: one per SGD step + one per kept simulated step
    assert_eq!(t.log.n_sgd(), 16);
    assert_eq!(t.log.n_ff(), sum.sim_steps);
    // flops monotone over records
    let mut prev = 0u64;
    for r in &t.log.records {
        assert!(r.flops >= prev);
        prev = r.flops;
    }
    // FF stage stats recorded when FF ran
    if sum.sim_steps > 0 {
        assert!(!t.ffc.stages.is_empty());
        assert!(t.ffc.stages.iter().any(|s| s.tau_star > 0));
    }
    // train-time timer excludes test evals but is positive
    assert!(sum.train_seconds > 0.0);
}

#[test]
fn device_residency_keeps_state_uploads_flat_and_eval_cached() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut t = Trainer::new(&rt, &root, tiny_cfg(true, 32), Some(&base)).unwrap();

    // warm up: the first step uploads trainable/m/v once
    t.sgd_step().unwrap();
    t.sgd_step().unwrap();
    let (ups0, downs0) = t.state_transfer_counts();
    for _ in 0..3 {
        t.sgd_step().unwrap();
    }
    let (ups1, downs1) = t.state_transfer_counts();
    assert_eq!(
        ups1, ups0,
        "steady-state Adam steps re-uploaded param/optimizer state"
    );
    // lazy host sync downloads exactly the trainable set per step (Δ_W)
    let n = t.trainable_count() as u64;
    assert_eq!(downs1 - downs0, 3 * n, "expected one Δ_W sync per step");

    // eval buffers cache: after the first eval, repeated probes at fixed W
    // perform zero uploads (only loss scalars come back)
    t.eval_val().unwrap(); // builds the val cache
    let tr0 = t.transfers();
    let l1 = t.eval_val().unwrap();
    let l2 = t.eval_val().unwrap();
    let d = t.transfers().since(&tr0);
    assert_eq!(
        d.uploads, 0,
        "repeated eval_val at fixed W must not upload anything: {d:?}"
    );
    assert!((l1 - l2).abs() < 1e-7, "eval_val not deterministic: {l1} vs {l2}");

    // run summary surfaces the transfer accounting
    let sum = t.run(&StopRule::MaxSteps(8)).unwrap();
    assert!(sum.transfers.uploaded_bytes > 0);
    assert!(sum.transfers.downloaded_bytes > 0);
}

#[test]
fn device_accumulation_uploads_batch_bytes_only() {
    // PR-2 acceptance: a steady-state baseline Adam step uploads the batch
    // (tokens/targets/mask per micro) plus one 4-byte step scalar —
    // nothing else. The O(|trainable|) mean-gradient upload is gone, and
    // state/gradient buffers are donated in place.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let cfg = tiny_cfg(false, 8);
    let global_batch = cfg.global_batch;
    let mut t = Trainer::new(&rt, &root, cfg, Some(&base)).unwrap();
    if !t.art.manifest.has_program("grad_accum") {
        eprintln!("skipping: artifact predates grad_accum (regenerate with make artifacts)");
        return;
    }

    // warm up twice: first step uploads state, lr and 1/n scalars
    t.sgd_step().unwrap();
    t.sgd_step().unwrap();
    let tr0 = t.transfers();
    let steps = 3u64;
    for _ in 0..steps {
        t.sgd_step().unwrap();
    }
    let d = t.transfers().since(&tr0);
    let mc = t.art.manifest.config.model.clone();
    let n_micro = global_batch / mc.micro_batch;
    let batch_bytes = (n_micro * 3 * mc.micro_batch * mc.seq_len * 4 + 4) as u64;
    assert_eq!(
        d.uploaded_bytes,
        steps * batch_bytes,
        "steady-state uploads must be batch data + step scalar only: {d:?}"
    );
    // each step donates t/m/v + the accumulated gradient (4·|trainable|)
    // plus the grad_accum/grad_finalize accumulator generations
    assert!(
        d.donations >= steps * 4 * t.trainable_count() as u64,
        "donation metering: {d:?}"
    );
    // baseline runs download only the per-micro loss scalars
    assert_eq!(d.downloaded_bytes, steps * n_micro as u64 * 4, "{d:?}");
    assert!(t.last_grads.is_empty(), "baseline step must not download grads");
}

#[test]
fn steady_state_contract_holds_per_engine_amid_sibling_traffic() {
    // §3's batch-bytes-only contract must hold *per engine*, not just
    // globally: interleave a sibling trainer's steps (and an eval-cache
    // build) inside the measured window and assert the measured
    // trainer's transfer delta is unchanged. A window over the shared
    // global meters — the pre-TransferMeter approach — fails this even
    // single-threaded; the per-engine meter keeps sibling traffic out.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let cfg = tiny_cfg(false, 8);
    let global_batch = cfg.global_batch;
    let mut t = Trainer::new(&rt, &root, cfg, Some(&base)).unwrap();
    if !t.art.manifest.has_program("grad_accum") {
        eprintln!("skipping: artifact predates grad_accum (regenerate with make artifacts)");
        return;
    }
    // the sibling is FF-enabled: its steps move Δ_W downloads, eval
    // uploads, and gradient downloads — loud pollution for a window
    let mut sibling = Trainer::new(&rt, &root, tiny_cfg(true, 8), Some(&base)).unwrap();

    t.sgd_step().unwrap();
    t.sgd_step().unwrap();
    sibling.sgd_step().unwrap();
    let tr0 = t.transfers();
    let steps = 3u64;
    for _ in 0..steps {
        t.sgd_step().unwrap();
        sibling.sgd_step().unwrap();
        sibling.eval_val().unwrap();
    }
    let d = t.transfers().since(&tr0);
    let mc = t.art.manifest.config.model.clone();
    let n_micro = global_batch / mc.micro_batch;
    let batch_bytes = (n_micro * 3 * mc.micro_batch * mc.seq_len * 4 + 4) as u64;
    assert_eq!(
        d.uploaded_bytes,
        steps * batch_bytes,
        "per-engine steady-state uploads must stay batch + step scalar \
         only, sibling traffic excluded: {d:?}"
    );
    assert_eq!(
        d.downloaded_bytes,
        steps * n_micro as u64 * 4,
        "per-engine downloads must be this engine's loss scalars only: {d:?}"
    );
}

#[test]
fn host_and_device_accumulation_paths_agree() {
    // keep_micro_grads forces the host GradAccumulator path (Fig 13's
    // setting); it must reproduce the device path's training trajectory.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut dev = Trainer::new(&rt, &root, tiny_cfg(false, 8), Some(&base)).unwrap();
    if !dev.art.manifest.has_program("grad_accum") {
        eprintln!("skipping: artifact predates grad_accum (regenerate with make artifacts)");
        return;
    }
    let mut host = Trainer::new(&rt, &root, tiny_cfg(false, 8), Some(&base)).unwrap();
    host.keep_micro_grads = true;

    let n_micro = dev.cfg.global_batch / dev.art.manifest.config.model.micro_batch;
    for step in 0..4 {
        let dl = dev.sgd_step().unwrap();
        let hl = host.sgd_step().unwrap();
        assert!(
            (dl - hl).abs() < 1e-5,
            "step {step}: device loss {dl} != host loss {hl}"
        );
        // Fig 13 inputs: every micro gradient of the last global batch
        assert_eq!(host.last_micro_grads.len(), n_micro);
        let consistency =
            fastforward::analysis::grads::batch_consistency(&host.last_micro_grads);
        assert!(consistency.is_finite());
        // host path keeps the mean gradient; device baseline path skips it
        assert!(!host.last_grads.is_empty());
    }
    let dw = dev.trainables().unwrap();
    let hw = host.trainables().unwrap();
    for (a, b) in dw.iter().zip(hw.iter()) {
        let max_d = a
            .data
            .iter()
            .zip(b.data.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_d < 1e-5, "weights diverged between paths: {max_d}");
    }
}

#[test]
fn deferred_readback_matches_synchronous_losses() {
    // The pipeline's correctness contract: dispatching steps through the
    // deferred-readback ring (drain every K) must produce bit-for-bit the
    // same losses, in the same order, as the synchronous path (drain
    // every 1) — deferral changes *when* the 4-byte scalars cross, never
    // their values. Same seed + same config ⇒ identical batch streams.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();

    let steps = 10;
    let mut sync = Trainer::new(&rt, &root, tiny_cfg(false, steps), Some(&base)).unwrap();
    sync.set_drain_interval(1);
    let mut sync_losses = Vec::new();
    for _ in 0..steps {
        sync_losses.push(sync.sgd_step().unwrap());
    }

    let mut pipe = Trainer::new(&rt, &root, tiny_cfg(false, steps), Some(&base)).unwrap();
    pipe.set_drain_interval(4);
    for _ in 0..steps {
        pipe.dispatch_sgd_step().unwrap();
    }
    // 10 dispatches with K=4: two interval drains have fired, two steps
    // are still in flight until the boundary sync retires them.
    assert_eq!(pipe.pending_steps(), 2, "ring should still hold 10 mod 4 steps");
    pipe.drain_pending(SyncReason::Shutdown).unwrap();
    assert_eq!(pipe.pending_steps(), 0);

    let pipe_losses: Vec<f32> = pipe
        .log
        .records
        .iter()
        .filter(|r| r.kind == StepKind::Sgd)
        .map(|r| r.loss)
        .collect();
    assert_eq!(pipe_losses.len(), steps);
    for (i, (a, b)) in sync_losses.iter().zip(pipe_losses.iter()).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "step {i}: sync {a} != deferred {b}");
    }
    // the deferred log carries the same step indices, in order
    let steps_logged: Vec<usize> = pipe.log.records.iter().map(|r| r.step).collect();
    assert_eq!(steps_logged, (1..=steps).collect::<Vec<_>>());
    // and the stream actually deferred: 2 interval drains + 1 forced
    let ss = pipe.stream_stats();
    assert_eq!(ss.interval_drains, 2, "{}", ss.report());
    assert_eq!(ss.forced_total(), 1, "{}", ss.report());
    assert!(ss.max_depth >= 4, "{}", ss.report());

    // weights agree too: pipelining must not change the trajectory
    let ws = sync.trainables().unwrap();
    let wp = pipe.trainables().unwrap();
    for (a, b) in ws.iter().zip(wp.iter()) {
        let max_d = a
            .data
            .iter()
            .zip(b.data.iter())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_d <= 1e-7, "weights diverged under deferred readback: {max_d}");
    }
}

#[test]
fn pipelined_steps_keep_batch_only_upload_contract() {
    // PR-2's steady-state upload assertion must survive prefetch and
    // deferred readback: each dispatched step still uploads exactly one
    // global batch + one 4-byte step scalar (the batch is the *next*
    // step's, staged while this one executes — same bytes, earlier).
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let cfg = tiny_cfg(false, 16);
    let global_batch = cfg.global_batch;
    let mut t = Trainer::new(&rt, &root, cfg, Some(&base)).unwrap();
    if !t.art.manifest.has_program("grad_accum") {
        eprintln!("skipping: artifact predates grad_accum (regenerate with make artifacts)");
        return;
    }
    t.set_drain_interval(4);

    // warm up: state uploads, lr/1-n scalars, and the prefetch slot
    t.sgd_step().unwrap();
    t.sgd_step().unwrap();
    let tr0 = t.transfers();
    let steps = 8u64;
    for _ in 0..steps {
        t.dispatch_sgd_step().unwrap();
    }
    t.drain_pending(SyncReason::Shutdown).unwrap();
    let d = t.transfers().since(&tr0);
    let mc = t.art.manifest.config.model.clone();
    let n_micro = global_batch / mc.micro_batch;
    let batch_bytes = (n_micro * 3 * mc.micro_batch * mc.seq_len * 4 + 4) as u64;
    assert_eq!(
        d.uploaded_bytes,
        steps * batch_bytes,
        "pipelined steady-state uploads must stay batch data + step scalar only: {d:?}"
    );
    // deferred readback moves loss downloads later, never changes them:
    // one 4-byte scalar per micro-batch per step
    assert_eq!(d.downloaded_bytes, steps * n_micro as u64 * 4, "{d:?}");
    assert!(
        d.donations >= steps * 4 * t.trainable_count() as u64,
        "donation metering under pipelining: {d:?}"
    );
}

#[test]
fn forced_drains_leave_no_pending_records() {
    // The drain invariant is a *hard* error now, not a debug_assert: a
    // forced sync that left records pending would silently drop run-log
    // losses in release builds. Exercise every boundary that forces a
    // drain — eval, FF stage, snapshot, shutdown — with steps in flight,
    // and verify the log ends up complete each time.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut t = Trainer::new(&rt, &root, tiny_cfg(true, 64), Some(&base)).unwrap();
    t.set_drain_interval(16); // large ring: boundaries do the draining

    // eval boundary with 3 steps in flight
    for _ in 0..3 {
        t.dispatch_sgd_step().unwrap();
    }
    assert_eq!(t.pending_steps(), 3);
    t.eval_val().unwrap();
    assert_eq!(t.pending_steps(), 0, "eval must retire in-flight steps");
    assert_eq!(t.log.n_sgd(), 3, "eval drain must backfill the log");

    // FF boundary with steps in flight (warmup already satisfied)
    for _ in 0..2 {
        t.dispatch_sgd_step().unwrap();
    }
    t.ff_stage().unwrap();
    assert_eq!(t.pending_steps(), 0, "ff_stage must retire in-flight steps");
    assert_eq!(t.log.n_sgd(), 5);

    // snapshot boundary
    t.dispatch_sgd_step().unwrap();
    t.trainables().unwrap();
    assert_eq!(t.pending_steps(), 0, "snapshot must retire in-flight steps");
    assert_eq!(t.log.n_sgd(), 6);

    // shutdown boundary via the explicit drain
    t.dispatch_sgd_step().unwrap();
    t.dispatch_sgd_step().unwrap();
    t.drain_pending(SyncReason::Shutdown).unwrap();
    assert_eq!(t.pending_steps(), 0);
    assert_eq!(t.log.n_sgd(), 8, "no dispatched step may drop from the log");
    // every record carries a finite loss — none were dropped or zero-filled
    assert!(t.log.records.iter().all(|r| r.loss.is_finite()));
}

#[test]
fn convergence_rule_disables_ff_eventually() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut cfg = tiny_cfg(true, 400);
    cfg.ff.convergence_patience = Some(3);
    let mut t = Trainer::new(&rt, &root, cfg, Some(&base)).unwrap();
    let sum = t
        .run(&StopRule::Convergence { max_steps: 400, tail: 6 })
        .unwrap();
    // Either FF shut itself off (paper §5.1 behaviour) or we hit max_steps;
    // on this tiny task it should shut off well before 400 steps.
    assert!(
        t.ffc.is_permanently_off() || sum.adam_steps >= 400,
        "neither converged nor exhausted: {} steps",
        sum.adam_steps
    );
}
