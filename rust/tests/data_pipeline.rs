//! Cross-module data-path integration: corpora → batcher → pipeline →
//! device upload shapes, and QA benchmark scoring through a live model.

use std::path::{Path, PathBuf};

use fastforward::config::presets;
use fastforward::data::batcher::{eval_batches, Batcher};
use fastforward::data::corpus::make_dataset;
use fastforward::data::pipeline::Pipeline;
use fastforward::eval::qa::{qa_accuracy, QaBenchmark};
use fastforward::runtime::Runtime;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::Trainer;

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn batches_match_artifact_shapes_for_all_tasks() {
    let rt = Runtime::cpu().unwrap();
    let idx = fastforward::runtime::ArtifactIndex::load(&artifacts_root()).unwrap();
    let man = idx.manifest("ff-tiny_lora_r8").unwrap();
    let m = &man.config.model;
    for task in presets::TASKS {
        let ds = make_dataset(task, m.vocab_size, m.seq_len, 128, 32, 32, 1).unwrap();
        let mut b = Batcher::new(&ds.train, m.micro_batch, 32, 0);
        let g = b.next_global();
        for micro in &g.micro {
            assert_eq!(micro.b, m.micro_batch);
            assert_eq!(micro.t, m.seq_len);
            // uploads must succeed with the manifest shapes
            rt.upload_i32(&micro.tokens, &[micro.b, micro.t]).unwrap();
            rt.upload_f32(&micro.mask, &[micro.b, micro.t]).unwrap();
        }
        let chunks = eval_batches(&ds.val, m.eval_batch);
        assert_eq!(chunks.len(), 32 / m.eval_batch);
    }
}

#[test]
fn pipeline_feeds_a_real_training_step() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut cfg = presets::train_config("ff-tiny_lora_r8", "chat", 1).unwrap();
    cfg.train_examples = 256;
    cfg.test_examples = 32;
    let mut t = Trainer::new(&rt, &root, cfg, Some(&base)).unwrap();
    let l1 = t.sgd_step().unwrap();
    let l2 = t.sgd_step().unwrap();
    assert!(l1.is_finite() && l2.is_finite());
}

#[test]
fn pipeline_outlives_many_epochs() {
    let ds = make_dataset("pile", 512, 64, 96, 0, 0, 5).unwrap();
    let mut pipe = Pipeline::spawn(ds.train, 8, 32, 1, 2);
    // 96 examples / 32 per global = 3 steps/epoch; pull 20 → ~7 epochs
    for _ in 0..20 {
        let g = pipe.next();
        assert_eq!(g.micro.len(), 4);
    }
}

#[test]
fn qa_scoring_through_live_model_is_valid_probability_range() {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut cfg = presets::train_config("ff-tiny_lora_r8", "medical", 1).unwrap();
    cfg.train_examples = 256;
    cfg.test_examples = 32;
    let mut t = Trainer::new(&rt, &root, cfg, Some(&base)).unwrap();
    let bench = QaBenchmark::generate(512, 64, 12, 3);
    let acc = qa_accuracy(&bench, |ex| t.eval_example_loss(ex)).unwrap();
    assert!((0.0..=1.0).contains(&acc));
}
