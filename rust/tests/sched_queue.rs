//! Multi-tenant run-queue integration (requires `make artifacts` for the
//! training-run tests): the long-lived [`RunQueue`] must produce
//! bit-identical results to `WorkerPool::run_all` for identical specs,
//! honor priorities (highest class first, FIFO within), cancel cleanly
//! (before start: nothing is ever constructed; mid-run: the cooperative
//! flag stops the trainer at a step boundary), and keep per-tenant
//! transfer accounting **exact** — tenant byte totals sum precisely to
//! the global `Runtime::stats` delta because every run meters through
//! its own per-engine `TransferMeter`.
//!
//! Everything here holds in both builds: with `xla-shared-client` the
//! queue drains on real worker threads; without it submissions drain
//! inline at `join`, in priority order (see `crate::sched::queue`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use fastforward::config::{presets, FfConfig, TrainConfig};
use fastforward::runtime::{Runtime, TransferSnapshot};
use fastforward::sched::{
    join_all, threads_enabled, ArtifactCache, RunPoll, RunQueue, RunResult, RunSpec, WorkerPool,
};
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::{StopRule, Trainer};

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn cfg(seed: u64, ff_enabled: bool) -> TrainConfig {
    let mut cfg = presets::train_config("ff-tiny_lora_r8", "medical", 1).unwrap();
    cfg.train_examples = 256;
    cfg.test_examples = 32;
    cfg.seed = seed;
    cfg.ff = FfConfig {
        enabled: ff_enabled,
        warmup_steps: 3,
        t_interval: 3,
        ..FfConfig::default()
    };
    cfg
}

struct Rig {
    rt: Arc<Runtime>,
    base: Arc<std::collections::BTreeMap<String, fastforward::model::tensor::Tensor>>,
    cache: Arc<ArtifactCache>,
}

fn rig() -> Rig {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = Arc::new(ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap());
    let cache = Arc::new(ArtifactCache::new(root));
    Rig { rt, base, cache }
}

fn spec(rig: &Rig, label: &str, seed: u64, ff: bool, steps: usize) -> RunSpec {
    RunSpec {
        label: label.to_string(),
        cfg: cfg(seed, ff),
        stop: StopRule::MaxSteps(steps),
        base: Some(Arc::clone(&rig.base)),
        drain_interval: None,
    }
}

#[test]
fn queue_results_are_bit_identical_to_run_all_with_exact_meters() {
    let r = rig();
    // Reference: the finite-batch scheduler, sequentially.
    let pool = WorkerPool::new(1)
        .run_all(&r.rt, &r.cache, vec![spec(&r, "a", 31, false, 6), spec(&r, "b", 32, true, 6)])
        .unwrap();

    // Same specs through the long-lived queue, with mixed priorities and
    // tenants — scheduling must never change a run's results.
    let q = RunQueue::new(2);
    let handles = vec![
        q.submit_run(&r.rt, &r.cache, spec(&r, "a", 31, false, 6), 0, "alice"),
        q.submit_run(&r.rt, &r.cache, spec(&r, "b", 32, true, 6), 3, "bob"),
    ];
    let results = join_all(handles).unwrap();
    assert_eq!(results.len(), 2);
    for (a, res) in pool.outputs.iter().zip(results) {
        let b = res.done().expect("queued runs complete normally");
        assert!(a.bit_identical(&b), "{}: queue changed the losses", a.label);
        assert_eq!(a.summary.adam_steps, b.summary.adam_steps, "{}", a.label);
        assert_eq!(a.summary.sim_steps, b.summary.sim_steps, "{}", a.label);
        assert!(!b.summary.cancelled, "{}", a.label);
        // per-run exact meters: identical specs move identical bytes,
        // whichever scheduler ran them
        assert_eq!(
            a.summary.transfers,
            b.summary.transfers,
            "{}: per-run exact meter diverged between pool and queue",
            a.label
        );
    }
    let alice = q.tenant("alice");
    let bob = q.tenant("bob");
    assert_eq!(alice.completed, 1);
    assert_eq!(bob.completed, 1);
    assert_eq!(alice.adam_steps + bob.adam_steps, 12);
    assert!(bob.ff_stages > 0, "the FF run's stages are accounted to bob");
}

#[test]
fn tenant_byte_totals_sum_exactly_to_the_global_meter_delta() {
    let r = rig();
    // Quiescent start: W0 built, artifact cache constructed. Every byte
    // the global meters move between here and the post-join snapshot is
    // queue-run traffic, and each run's engine meters it exactly.
    let before = r.rt.stats.snapshot();
    let q = RunQueue::new(2);
    let handles = vec![
        q.submit_run(&r.rt, &r.cache, spec(&r, "a0", 41, false, 4), 0, "alice"),
        q.submit_run(&r.rt, &r.cache, spec(&r, "a1", 42, false, 4), 1, "alice"),
        q.submit_run(&r.rt, &r.cache, spec(&r, "b0", 43, true, 4), 0, "bob"),
    ];
    for res in join_all(handles).unwrap() {
        assert!(res.done().is_some());
    }
    let delta = r.rt.stats.snapshot().since(&before);
    let mut summed = TransferSnapshot::default();
    for stats in q.tenants().values() {
        summed = summed.plus(&stats.transfers);
    }
    assert!(delta.uploaded_bytes > 0, "runs moved real bytes");
    assert_eq!(summed, delta, "per-tenant exact meters must sum to the global delta");
    assert_eq!(q.tenant("alice").completed, 2);
    assert_eq!(q.tenant("bob").completed, 1);
}

#[test]
fn cancel_before_start_never_constructs_a_trainer() {
    let r = rig();
    // The victim's artifact does not exist: executing it would fail at
    // Trainer construction — joining as Cancelled(None) proves nothing
    // was ever constructed.
    let mut bad = cfg(1, false);
    bad.artifact = "no_such_artifact".into();
    let q = RunQueue::new_paused(1);
    let victim = q.submit_run(
        &r.rt,
        &r.cache,
        RunSpec {
            label: "victim".into(),
            cfg: bad,
            stop: StopRule::MaxSteps(1),
            base: None,
            drain_interval: None,
        },
        9,
        "t",
    );
    let survivor = q.submit_run(&r.rt, &r.cache, spec(&r, "ok", 5, false, 2), 0, "t");
    victim.cancel();
    assert_eq!(victim.poll(), RunPoll::Cancelled);
    q.release();
    match victim.join().unwrap() {
        RunResult::Cancelled(None) => {}
        _ => panic!("cancel-before-start must join as Cancelled(None)"),
    }
    let out = survivor.join().unwrap().done().expect("survivor completes");
    assert!(out.summary.final_test_loss.is_finite());
    let t = q.tenant("t");
    assert_eq!(t.submitted, 2);
    assert_eq!(t.cancelled, 1);
    assert_eq!(t.completed, 1);
    assert_eq!(t.failed, 0, "the bogus artifact was never touched");
}

#[test]
fn cooperative_cancel_stops_trainer_at_a_step_boundary() {
    // Trainer-level half of mid-run cancellation, fully deterministic
    // (no timing): dispatch real work, raise the flag between step
    // boundaries, then enter the run loop — it must stop at its first
    // boundary check with the already-dispatched work retired, drained,
    // and logged, and the final eval still run.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut t = Trainer::new(&rt, &root, cfg(7, false), Some(&base)).unwrap();
    let flag = Arc::new(AtomicBool::new(false));
    t.set_cancel_flag(Arc::clone(&flag));
    for _ in 0..3 {
        t.dispatch_sgd_step().unwrap(); // pipelined work in flight
    }
    flag.store(true, Ordering::SeqCst);
    let sum = t.run(&StopRule::MaxSteps(400)).unwrap();
    assert!(sum.cancelled, "flag raised mid-run must mark the summary cancelled");
    assert_eq!(sum.adam_steps, 3, "no further step may dispatch past the boundary");
    assert_eq!(t.log.n_sgd(), 3, "in-flight steps retired and logged at the boundary");
    assert_eq!(t.pending_steps(), 0, "pipeline drained before the final eval");
    assert!(sum.final_test_loss.is_finite(), "the final eval still ran");

    // The converse race: a flag raised only after the run already
    // completed its budget must NOT mark the delivered run cancelled.
    let mut done = Trainer::new(&rt, &root, cfg(8, false), Some(&base)).unwrap();
    let late = Arc::new(AtomicBool::new(false));
    done.set_cancel_flag(Arc::clone(&late));
    let first = done.run(&StopRule::MaxSteps(3)).unwrap();
    assert!(!first.cancelled);
    late.store(true, Ordering::SeqCst);
    let rerun = done.run(&StopRule::MaxSteps(3)).unwrap();
    assert!(
        !rerun.cancelled,
        "a run that already satisfied its stop rule is delivered, not cancelled"
    );
    assert_eq!(rerun.adam_steps, 3);
}

#[test]
fn queue_cancel_mid_run_reports_cancelled_not_error() {
    // Queue-level mid-run cancel needs a worker actually executing while
    // this thread cancels — only real in the gated build (inline-drain
    // builds cover the same contract via the trainer-level test above
    // plus the queue's cooperative-cancel unit test).
    if !threads_enabled() {
        return;
    }
    let r = rig();
    let q = RunQueue::new(1);
    // A step budget far beyond anything a worker can finish while this
    // thread polls + cancels: the cancel always lands mid-run.
    let budget = 1_000_000;
    let h = q.submit_run(&r.rt, &r.cache, spec(&r, "long", 9, false, budget), 0, "t");
    while h.poll() == RunPoll::Queued {
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    h.cancel();
    match h.join().unwrap() {
        RunResult::Cancelled(Some(out)) => {
            assert!(out.summary.cancelled);
            assert!(out.summary.adam_steps < budget, "stopped at a step boundary");
        }
        RunResult::Cancelled(None) => panic!("the run had started"),
        RunResult::Done(_) => panic!("cancel mid-run must report Cancelled"),
    }
    assert_eq!(q.tenant("t").cancelled, 1);
}

/// A spec the queue can pack: fixed steps, no FF, and `global_batch ==
/// micro_batch` (the batched chain has no gradient accumulation). All
/// members share the rig's base checkpoint, so frozen weights are
/// identical across seeds and only the adapters differ.
fn packable_spec(rig: &Rig, label: &str, seed: u64, steps: usize) -> RunSpec {
    let mut c = cfg(seed, false);
    c.global_batch = 8; // == ff-tiny micro_batch
    RunSpec {
        label: label.to_string(),
        cfg: c,
        stop: StopRule::MaxSteps(steps),
        base: Some(Arc::clone(&rig.base)),
        drain_interval: None,
    }
}

#[test]
fn packed_group_is_bit_identical_to_solo_with_exact_meter_slices() {
    // The tentpole acceptance gate: K runs packed into one batched
    // program group must (a) reproduce each member's solo losses
    // bit-for-bit, (b) slice the group's transfer traffic so member
    // bytes sum *exactly* to the global meter delta, and (c) actually
    // share the frozen base (fewer uploaded bytes than K solo runs).
    let r = rig();
    let art = r.cache.load(&r.rt, "ff-tiny_lora_r8").unwrap();
    let sizes = art.manifest.batched_group_sizes();
    if sizes.is_empty() {
        eprintln!("skipping: artifacts predate batched program variants (re-run make artifacts)");
        return;
    }
    let k = sizes[0];
    let steps = 5;
    let seeds: Vec<u64> = (0..k as u64).map(|i| 70 + i).collect();

    // Reference: every member runs solo through the queue.
    let q_solo = RunQueue::new(1);
    let solo_handles: Vec<_> = seeds
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            let spec = packable_spec(&r, &format!("m{i}"), s, steps);
            q_solo.submit_run(&r.rt, &r.cache, spec, 0, "t")
        })
        .collect();
    let solo: Vec<_> = join_all(solo_handles)
        .unwrap()
        .into_iter()
        .map(|res| res.done().expect("solo reference completes"))
        .collect();

    // Packed: identical specs into a paused queue so all K are waiting
    // when the first one pops and becomes the pack leader.
    let before = r.rt.stats.snapshot();
    let q = RunQueue::new_paused(1);
    let handles: Vec<_> = seeds
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            let spec = packable_spec(&r, &format!("m{i}"), s, steps);
            q.submit_run_packable(&r.rt, &r.cache, spec, 0, "t")
        })
        .collect();
    q.release();
    let packed: Vec<_> = join_all(handles)
        .unwrap()
        .into_iter()
        .map(|res| res.done().expect("packed members complete normally"))
        .collect();
    let delta = r.rt.stats.snapshot().since(&before);

    // (a) bit-identity per member, in submission order.
    assert_eq!(packed.len(), k);
    for (s, p) in solo.iter().zip(&packed) {
        assert!(s.bit_identical(p), "{}: packed losses diverged from solo", s.label);
        assert_eq!(s.summary.adam_steps, p.summary.adam_steps, "{}", s.label);
        assert!(!p.summary.cancelled, "{}", s.label);
    }

    // (b) member meter slices sum exactly to the global byte delta.
    // Bytes only: one physical call fans out to K member records, so
    // call *counts* are attributed per member and do not sum to the
    // global counts (docs/transfer-contract.md §5).
    let mut summed = TransferSnapshot::default();
    for p in &packed {
        summed = summed.plus(&p.summary.transfers);
    }
    assert_eq!(
        (summed.uploaded_bytes, summed.downloaded_bytes, summed.donated_bytes),
        (delta.uploaded_bytes, delta.downloaded_bytes, delta.donated_bytes),
        "member byte slices must sum exactly to the global delta"
    );

    // (c) packing really happened: the group uploads the frozen base
    // once (and skips the per-micro inv_n scalar), so it moves strictly
    // fewer bytes than the K solo runs did.
    let solo_uploaded: usize = solo.iter().map(|s| s.summary.transfers.uploaded_bytes).sum();
    assert!(
        delta.uploaded_bytes < solo_uploaded,
        "packed group uploaded {} bytes, not fewer than the {} of {k} solo runs",
        delta.uploaded_bytes,
        solo_uploaded
    );

    // Tenant accounting: K completed runs, steps and FLOPs folded in.
    let t = q.tenant("t");
    assert_eq!(t.completed, k as u64);
    assert_eq!(t.adam_steps, (k * steps) as u64);
    assert!(t.flops > 0);
}

#[test]
fn ineligible_specs_fall_back_to_solo_through_the_packable_path() {
    // global_batch != micro_batch (gradient accumulation) can never
    // pack: submit_run_packable must deliver it solo, bit-identical to
    // submit_run, with clean tenant accounting.
    let r = rig();
    let q = RunQueue::new(1);
    let a = q.submit_run(&r.rt, &r.cache, spec(&r, "solo", 21, false, 3), 0, "t");
    let b = q.submit_run_packable(&r.rt, &r.cache, spec(&r, "fallback", 21, false, 3), 0, "t");
    let a = a.join().unwrap().done().unwrap();
    let b = b.join().unwrap().done().unwrap();
    assert!(a.bit_identical(&b), "fallback path changed the losses");
    assert_eq!(a.summary.transfers, b.summary.transfers, "fallback meter must match solo exactly");
    assert_eq!(q.tenant("t").completed, 2);
}

#[test]
fn priority_ordering_from_a_cold_queue() {
    // Public-API ordering check with plain closures (no artifacts): a
    // cold backlog drains highest class first, FIFO within a class, in
    // both the worker-thread and inline-drain builds.
    let q: RunQueue<usize> = RunQueue::new_paused(1);
    let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
    let mut handles = Vec::new();
    for (name, prio) in [("low-a", 0), ("high-a", 2), ("low-b", 0), ("high-b", 2), ("mid", 1)] {
        let order = Arc::clone(&order);
        handles.push(q.submit("t", prio, move |_| {
            order.lock().unwrap().push(name);
            Ok(0usize)
        }));
    }
    q.release();
    join_all(handles).unwrap();
    assert_eq!(*order.lock().unwrap(), vec!["high-a", "high-b", "mid", "low-a", "low-b"]);
}
