//! Multi-tenant run-queue integration (requires `make artifacts` for the
//! training-run tests): the long-lived [`RunQueue`] must produce
//! bit-identical results to `WorkerPool::run_all` for identical specs,
//! honor priorities (highest class first, FIFO within), cancel cleanly
//! (before start: nothing is ever constructed; mid-run: the cooperative
//! flag stops the trainer at a step boundary), and keep per-tenant
//! transfer accounting **exact** — tenant byte totals sum precisely to
//! the global `Runtime::stats` delta because every run meters through
//! its own per-engine `TransferMeter`.
//!
//! Everything here holds in both builds: with `xla-shared-client` the
//! queue drains on real worker threads; without it submissions drain
//! inline at `join`, in priority order (see `crate::sched::queue`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use fastforward::config::{presets, FfConfig, FfPolicyKind, OptimBackend, TrainConfig};
use fastforward::metrics::StepKind;
use fastforward::runtime::{Runtime, TransferSnapshot};
use fastforward::sched::{
    join_all, threads_enabled, ArtifactCache, RunPoll, RunQueue, RunResult, RunSpec, WorkerPool,
};
use fastforward::train::checkpoint::{load_park_state, save_park_state};
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::{StopRule, Trainer};

fn artifacts_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn cfg(seed: u64, ff_enabled: bool) -> TrainConfig {
    let mut cfg = presets::train_config("ff-tiny_lora_r8", "medical", 1).unwrap();
    cfg.train_examples = 256;
    cfg.test_examples = 32;
    cfg.seed = seed;
    cfg.ff = FfConfig {
        enabled: ff_enabled,
        warmup_steps: 3,
        t_interval: 3,
        ..FfConfig::default()
    };
    cfg
}

struct Rig {
    rt: Arc<Runtime>,
    base: Arc<std::collections::BTreeMap<String, fastforward::model::tensor::Tensor>>,
    cache: Arc<ArtifactCache>,
}

fn rig() -> Rig {
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = Arc::new(ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap());
    let cache = Arc::new(ArtifactCache::new(root));
    Rig { rt, base, cache }
}

fn spec(rig: &Rig, label: &str, seed: u64, ff: bool, steps: usize) -> RunSpec {
    RunSpec {
        label: label.to_string(),
        cfg: cfg(seed, ff),
        stop: StopRule::MaxSteps(steps),
        base: Some(Arc::clone(&rig.base)),
        drain_interval: None,
    }
}

#[test]
fn queue_results_are_bit_identical_to_run_all_with_exact_meters() {
    let r = rig();
    // Reference: the finite-batch scheduler, sequentially.
    let pool = WorkerPool::new(1)
        .run_all(&r.rt, &r.cache, vec![spec(&r, "a", 31, false, 6), spec(&r, "b", 32, true, 6)])
        .unwrap();

    // Same specs through the long-lived queue, with mixed priorities and
    // tenants — scheduling must never change a run's results.
    let q = RunQueue::new(2);
    let handles = vec![
        q.submit_run(&r.rt, &r.cache, spec(&r, "a", 31, false, 6), 0, "alice").unwrap(),
        q.submit_run(&r.rt, &r.cache, spec(&r, "b", 32, true, 6), 3, "bob").unwrap(),
    ];
    let results = join_all(handles).unwrap();
    assert_eq!(results.len(), 2);
    for (a, res) in pool.outputs.iter().zip(results) {
        let b = res.done().expect("queued runs complete normally");
        assert!(a.bit_identical(&b), "{}: queue changed the losses", a.label);
        assert_eq!(a.summary.adam_steps, b.summary.adam_steps, "{}", a.label);
        assert_eq!(a.summary.sim_steps, b.summary.sim_steps, "{}", a.label);
        assert!(!b.summary.cancelled, "{}", a.label);
        // per-run exact meters: identical specs move identical bytes,
        // whichever scheduler ran them
        assert_eq!(
            a.summary.transfers,
            b.summary.transfers,
            "{}: per-run exact meter diverged between pool and queue",
            a.label
        );
    }
    let alice = q.tenant("alice");
    let bob = q.tenant("bob");
    assert_eq!(alice.completed, 1);
    assert_eq!(bob.completed, 1);
    assert_eq!(alice.adam_steps + bob.adam_steps, 12);
    assert!(bob.ff_stages > 0, "the FF run's stages are accounted to bob");
}

#[test]
fn tenant_byte_totals_sum_exactly_to_the_global_meter_delta() {
    let r = rig();
    // Quiescent start: W0 built, artifact cache constructed. Every byte
    // the global meters move between here and the post-join snapshot is
    // queue-run traffic, and each run's engine meters it exactly.
    let before = r.rt.stats.snapshot();
    let q = RunQueue::new(2);
    let handles = vec![
        q.submit_run(&r.rt, &r.cache, spec(&r, "a0", 41, false, 4), 0, "alice").unwrap(),
        q.submit_run(&r.rt, &r.cache, spec(&r, "a1", 42, false, 4), 1, "alice").unwrap(),
        q.submit_run(&r.rt, &r.cache, spec(&r, "b0", 43, true, 4), 0, "bob").unwrap(),
    ];
    for res in join_all(handles).unwrap() {
        assert!(res.done().is_some());
    }
    let delta = r.rt.stats.snapshot().since(&before);
    let mut summed = TransferSnapshot::default();
    for stats in q.tenants().values() {
        summed = summed.plus(&stats.transfers);
    }
    assert!(delta.uploaded_bytes > 0, "runs moved real bytes");
    assert_eq!(summed, delta, "per-tenant exact meters must sum to the global delta");
    assert_eq!(q.tenant("alice").completed, 2);
    assert_eq!(q.tenant("bob").completed, 1);
}

#[test]
fn cancel_before_start_never_constructs_a_trainer() {
    let r = rig();
    // The victim's artifact does not exist: executing it would fail at
    // Trainer construction — joining as Cancelled(None) proves nothing
    // was ever constructed.
    let mut bad = cfg(1, false);
    bad.artifact = "no_such_artifact".into();
    let q = RunQueue::new_paused(1);
    let victim = q.submit_run(
        &r.rt,
        &r.cache,
        RunSpec {
            label: "victim".into(),
            cfg: bad,
            stop: StopRule::MaxSteps(1),
            base: None,
            drain_interval: None,
        },
        9,
        "t",
    )
    .unwrap();
    let survivor = q.submit_run(&r.rt, &r.cache, spec(&r, "ok", 5, false, 2), 0, "t").unwrap();
    victim.cancel();
    assert_eq!(victim.poll(), RunPoll::Cancelled);
    q.release();
    match victim.join().unwrap() {
        RunResult::Cancelled(None) => {}
        _ => panic!("cancel-before-start must join as Cancelled(None)"),
    }
    let out = survivor.join().unwrap().done().expect("survivor completes");
    assert!(out.summary.final_test_loss.is_finite());
    let t = q.tenant("t");
    assert_eq!(t.submitted, 2);
    assert_eq!(t.cancelled, 1);
    assert_eq!(t.completed, 1);
    assert_eq!(t.failed, 0, "the bogus artifact was never touched");
}

#[test]
fn cooperative_cancel_stops_trainer_at_a_step_boundary() {
    // Trainer-level half of mid-run cancellation, fully deterministic
    // (no timing): dispatch real work, raise the flag between step
    // boundaries, then enter the run loop — it must stop at its first
    // boundary check with the already-dispatched work retired, drained,
    // and logged, and the final eval still run.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let mut t = Trainer::new(&rt, &root, cfg(7, false), Some(&base)).unwrap();
    let flag = Arc::new(AtomicBool::new(false));
    t.set_cancel_flag(Arc::clone(&flag));
    for _ in 0..3 {
        t.dispatch_sgd_step().unwrap(); // pipelined work in flight
    }
    flag.store(true, Ordering::SeqCst);
    let sum = t.run(&StopRule::MaxSteps(400)).unwrap();
    assert!(sum.cancelled, "flag raised mid-run must mark the summary cancelled");
    assert_eq!(sum.adam_steps, 3, "no further step may dispatch past the boundary");
    assert_eq!(t.log.n_sgd(), 3, "in-flight steps retired and logged at the boundary");
    assert_eq!(t.pending_steps(), 0, "pipeline drained before the final eval");
    assert!(sum.final_test_loss.is_finite(), "the final eval still ran");

    // The converse race: a flag raised only after the run already
    // completed its budget must NOT mark the delivered run cancelled.
    let mut done = Trainer::new(&rt, &root, cfg(8, false), Some(&base)).unwrap();
    let late = Arc::new(AtomicBool::new(false));
    done.set_cancel_flag(Arc::clone(&late));
    let first = done.run(&StopRule::MaxSteps(3)).unwrap();
    assert!(!first.cancelled);
    late.store(true, Ordering::SeqCst);
    let rerun = done.run(&StopRule::MaxSteps(3)).unwrap();
    assert!(
        !rerun.cancelled,
        "a run that already satisfied its stop rule is delivered, not cancelled"
    );
    assert_eq!(rerun.adam_steps, 3);
}

#[test]
fn queue_cancel_mid_run_reports_cancelled_not_error() {
    // Queue-level mid-run cancel needs a worker actually executing while
    // this thread cancels — only real in the gated build (inline-drain
    // builds cover the same contract via the trainer-level test above
    // plus the queue's cooperative-cancel unit test).
    if !threads_enabled() {
        return;
    }
    let r = rig();
    let q = RunQueue::new(1);
    // A step budget far beyond anything a worker can finish while this
    // thread polls + cancels: the cancel always lands mid-run.
    let budget = 1_000_000;
    let h = q.submit_run(&r.rt, &r.cache, spec(&r, "long", 9, false, budget), 0, "t").unwrap();
    while h.poll() == RunPoll::Queued {
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    h.cancel();
    match h.join().unwrap() {
        RunResult::Cancelled(Some(out)) => {
            assert!(out.summary.cancelled);
            assert!(out.summary.adam_steps < budget, "stopped at a step boundary");
        }
        RunResult::Cancelled(None) => panic!("the run had started"),
        RunResult::Done(_) => panic!("cancel mid-run must report Cancelled"),
    }
    assert_eq!(q.tenant("t").cancelled, 1);
}

/// A spec the queue can pack: fixed steps, no FF, and `global_batch ==
/// micro_batch` (the batched chain has no gradient accumulation). All
/// members share the rig's base checkpoint, so frozen weights are
/// identical across seeds and only the adapters differ.
fn packable_spec(rig: &Rig, label: &str, seed: u64, steps: usize) -> RunSpec {
    let mut c = cfg(seed, false);
    c.global_batch = 8; // == ff-tiny micro_batch
    RunSpec {
        label: label.to_string(),
        cfg: c,
        stop: StopRule::MaxSteps(steps),
        base: Some(Arc::clone(&rig.base)),
        drain_interval: None,
    }
}

#[test]
fn packed_group_is_bit_identical_to_solo_with_exact_meter_slices() {
    // The tentpole acceptance gate: K runs packed into one batched
    // program group must (a) reproduce each member's solo losses
    // bit-for-bit, (b) slice the group's transfer traffic so member
    // bytes sum *exactly* to the global meter delta, and (c) actually
    // share the frozen base (fewer uploaded bytes than K solo runs).
    let r = rig();
    let art = r.cache.load(&r.rt, "ff-tiny_lora_r8").unwrap();
    let sizes = art.manifest.batched_group_sizes();
    if sizes.is_empty() {
        eprintln!("skipping: artifacts predate batched program variants (re-run make artifacts)");
        return;
    }
    let k = sizes[0];
    let steps = 5;
    let seeds: Vec<u64> = (0..k as u64).map(|i| 70 + i).collect();

    // Reference: every member runs solo through the queue.
    let q_solo = RunQueue::new(1);
    let solo_handles: Vec<_> = seeds
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            let spec = packable_spec(&r, &format!("m{i}"), s, steps);
            q_solo.submit_run(&r.rt, &r.cache, spec, 0, "t").unwrap()
        })
        .collect();
    let solo: Vec<_> = join_all(solo_handles)
        .unwrap()
        .into_iter()
        .map(|res| res.done().expect("solo reference completes"))
        .collect();

    // Packed: identical specs into a paused queue so all K are waiting
    // when the first one pops and becomes the pack leader.
    let before = r.rt.stats.snapshot();
    let q = RunQueue::new_paused(1);
    let handles: Vec<_> = seeds
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            let spec = packable_spec(&r, &format!("m{i}"), s, steps);
            q.submit_run_packable(&r.rt, &r.cache, spec, 0, "t").unwrap()
        })
        .collect();
    q.release();
    let packed: Vec<_> = join_all(handles)
        .unwrap()
        .into_iter()
        .map(|res| res.done().expect("packed members complete normally"))
        .collect();
    let delta = r.rt.stats.snapshot().since(&before);

    // (a) bit-identity per member, in submission order.
    assert_eq!(packed.len(), k);
    for (s, p) in solo.iter().zip(&packed) {
        assert!(s.bit_identical(p), "{}: packed losses diverged from solo", s.label);
        assert_eq!(s.summary.adam_steps, p.summary.adam_steps, "{}", s.label);
        assert!(!p.summary.cancelled, "{}", s.label);
    }

    // (b) member meter slices sum exactly to the global byte delta.
    // Bytes only: one physical call fans out to K member records, so
    // call *counts* are attributed per member and do not sum to the
    // global counts (docs/transfer-contract.md §5).
    let mut summed = TransferSnapshot::default();
    for p in &packed {
        summed = summed.plus(&p.summary.transfers);
    }
    assert_eq!(
        (summed.uploaded_bytes, summed.downloaded_bytes, summed.donated_bytes),
        (delta.uploaded_bytes, delta.downloaded_bytes, delta.donated_bytes),
        "member byte slices must sum exactly to the global delta"
    );

    // (c) packing really happened: the group uploads the frozen base
    // once (and skips the per-micro inv_n scalar), so it moves strictly
    // fewer bytes than the K solo runs did.
    let solo_uploaded: usize = solo.iter().map(|s| s.summary.transfers.uploaded_bytes).sum();
    assert!(
        delta.uploaded_bytes < solo_uploaded,
        "packed group uploaded {} bytes, not fewer than the {} of {k} solo runs",
        delta.uploaded_bytes,
        solo_uploaded
    );

    // Tenant accounting: K completed runs, steps and FLOPs folded in.
    let t = q.tenant("t");
    assert_eq!(t.completed, k as u64);
    assert_eq!(t.adam_steps, (k * steps) as u64);
    assert!(t.flops > 0);
}

#[test]
fn ineligible_specs_fall_back_to_solo_through_the_packable_path() {
    // global_batch != micro_batch (gradient accumulation) can never
    // pack: submit_run_packable must deliver it solo, bit-identical to
    // submit_run, with clean tenant accounting.
    let r = rig();
    let q = RunQueue::new(1);
    let a = q.submit_run(&r.rt, &r.cache, spec(&r, "solo", 21, false, 3), 0, "t").unwrap();
    let b = q
        .submit_run_packable(&r.rt, &r.cache, spec(&r, "fallback", 21, false, 3), 0, "t")
        .unwrap();
    let a = a.join().unwrap().done().unwrap();
    let b = b.join().unwrap().done().unwrap();
    assert!(a.bit_identical(&b), "fallback path changed the losses");
    assert_eq!(a.summary.transfers, b.summary.transfers, "fallback meter must match solo exactly");
    assert_eq!(q.tenant("t").completed, 2);
}

#[test]
fn priority_ordering_from_a_cold_queue() {
    // Public-API ordering check with plain closures (no artifacts): a
    // cold backlog drains highest class first, FIFO within a class, in
    // both the worker-thread and inline-drain builds.
    let q: RunQueue<usize> = RunQueue::new_paused(1);
    let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
    let mut handles = Vec::new();
    for (name, prio) in [("low-a", 0), ("high-a", 2), ("low-b", 0), ("high-b", 2), ("mid", 1)] {
        let order = Arc::clone(&order);
        handles.push(
            q.submit("t", prio, move |_| {
                order.lock().unwrap().push(name);
                Ok(0usize)
            })
            .unwrap(),
        );
    }
    q.release();
    join_all(handles).unwrap();
    assert_eq!(*order.lock().unwrap(), vec!["high-a", "high-b", "mid", "low-a", "low-b"]);
}

#[test]
fn park_resume_is_bit_identical_with_exact_byte_overhead() {
    // The preemption acceptance gate: a run parked at step k and resumed
    // on a fresh trainer must be bitwise identical to the uninterrupted
    // run — every SGD loss and the final eval — with the park/resume
    // transfer overhead billed on top *exactly*. Park downloads the full
    // optimizer state (trainables + Adam m + v = 3T bytes); resume
    // re-uploads that state plus the fresh engine's one-time uploads
    // (frozen base, lr and inv_n scalars) and re-stages the one batch
    // the parked slot prefetched but never consumed.
    let rt = Runtime::cpu().unwrap();
    let root = artifacts_root();
    let base = ensure_pretrained(&rt, &root, "ff-tiny", Some(60)).unwrap();
    let n = 6;

    // Reference: uninterrupted.
    let mut a = Trainer::new(&rt, &root, cfg(11, false), Some(&base)).unwrap();
    let sum_a = a.run(&StopRule::MaxSteps(n)).unwrap();
    assert!(!sum_a.parked && !sum_a.cancelled);
    let losses_a: Vec<u32> = a
        .log
        .records
        .iter()
        .filter(|r| r.kind == StepKind::Sgd)
        .map(|r| r.loss.to_bits())
        .collect();
    assert_eq!(losses_a.len(), n);

    // Interrupted: a step quantum of 3 parks the run at k = 3...
    let mut b = Trainer::new(&rt, &root, cfg(11, false), Some(&base)).unwrap();
    b.set_step_quantum(3);
    let sum_b = b.run(&StopRule::MaxSteps(n)).unwrap();
    assert!(sum_b.parked && !sum_b.cancelled);
    assert_eq!(sum_b.adam_steps, 3);
    assert!(sum_b.final_test_loss.is_nan(), "a parked slot never runs the final eval");
    let state = b.park_state().unwrap();
    let path = std::env::temp_dir().join(format!("ffq-it-park-{}.ffpk", std::process::id()));
    save_park_state(&path, &state).unwrap();
    drop(b); // the parked trainer is gone: resume must not depend on it
    let state = load_park_state(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    // ...and a fresh trainer resumes — not restarts — it.
    let mut c = Trainer::new(&rt, &root, cfg(11, false), Some(&base)).unwrap();
    c.resume_from(&state).unwrap();
    let sum_c = c.run(&StopRule::MaxSteps(n)).unwrap();
    assert!(!sum_c.parked && !sum_c.cancelled);
    assert_eq!(sum_c.adam_steps, n, "the resumed summary reports the whole run");
    let losses_c: Vec<u32> = c
        .log
        .records
        .iter()
        .filter(|r| r.kind == StepKind::Sgd)
        .map(|r| r.loss.to_bits())
        .collect();
    assert_eq!(losses_a, losses_c, "resumed losses must be bitwise identical");
    assert_eq!(
        sum_a.final_test_loss.to_bits(),
        sum_c.final_test_loss.to_bits(),
        "resumed final eval must be bitwise identical"
    );
    assert_eq!(sum_a.sim_steps, sum_c.sim_steps);

    // Exact byte overhead of one park/resume cycle, from model geometry
    // (docs/transfer-contract.md §5): T/F = trainable/frozen bytes, the
    // scalar pair is lr + inv_n (4 bytes each), and one full global
    // batch (3 arrays: tokens, targets, mask) is staged twice.
    let t_bytes = (c.trainable_numel() * 4) as u64;
    let t_len = c.trainable_count() as u64;
    let f_bytes = (c.frozen_numel() * 4) as u64;
    let f_len = c.frozen_count() as u64;
    let mc = presets::model("ff-tiny").unwrap();
    let gb = cfg(11, false).global_batch;
    let batch_bytes = (3 * gb * mc.seq_len * 4) as u64;
    let batch_calls = 3 * (gb / mc.micro_batch) as u64;
    let (at, ct) = (sum_a.transfers, sum_c.transfers);
    assert_eq!(
        ct.uploaded_bytes,
        at.uploaded_bytes + 3 * t_bytes + f_bytes + 8 + batch_bytes,
        "resume upload overhead must be exactly state + engine one-times + one batch"
    );
    assert_eq!(ct.uploads, at.uploads + 3 * t_len + f_len + 2 + batch_calls);
    assert_eq!(
        ct.downloaded_bytes,
        at.downloaded_bytes + 3 * t_bytes,
        "park download overhead must be exactly the optimizer state"
    );
    assert_eq!(ct.downloads, at.downloads + 3 * t_len);
    assert_eq!(ct.donated_bytes, at.donated_bytes, "park/resume donates nothing extra");
    assert_eq!(ct.donations, at.donations);
}

#[test]
fn queue_quantum_parks_and_resumes_with_exact_tenant_accounting() {
    // End-to-end through the queue: a step quantum of 1 forces maximum
    // churn — every 4-step run parks at every boundary and re-enters the
    // queue — yet the delivered outputs are bit-identical to a solo run,
    // report whole-run step counts, and the per-tenant meters (slot
    // deltas summed across all the parks) still reconcile exactly with
    // the global meter.
    let r = rig();
    let q0 = RunQueue::new(1);
    let solo = q0
        .submit_run(&r.rt, &r.cache, spec(&r, "ref", 17, false, 4), 0, "t")
        .unwrap()
        .join()
        .unwrap()
        .done()
        .expect("solo reference completes");

    let before = r.rt.stats.snapshot();
    let q = RunQueue::new_paused(2);
    q.set_step_quantum(1);
    let h0 = q.submit_run(&r.rt, &r.cache, spec(&r, "x", 17, false, 4), 0, "alice").unwrap();
    let h1 = q.submit_run(&r.rt, &r.cache, spec(&r, "y", 18, false, 4), 0, "bob").unwrap();
    q.release();
    let x = h0.join().unwrap().done().expect("parked run resumes to completion");
    let y = h1.join().unwrap().done().expect("parked run resumes to completion");
    assert!(solo.bit_identical(&x), "quantum time-slicing changed the losses");
    assert_eq!(x.summary.adam_steps, 4, "resumed run reports whole-run steps");
    assert_eq!(y.summary.adam_steps, 4);
    assert!(!x.summary.parked, "the delivered summary is the finished slot's");

    // Each run parks after steps 1, 2, and 3; the 4th slot hits the stop
    // rule before the quantum and finishes. 4 slots picked per run.
    let alice = q.tenant("alice");
    let bob = q.tenant("bob");
    assert_eq!(alice.parked, 3);
    assert_eq!(bob.parked, 3);
    assert_eq!(alice.picked, 4);
    assert_eq!(bob.picked, 4);
    assert_eq!(alice.completed, 1);
    assert_eq!(alice.adam_steps, 4, "slot deltas must sum to the whole run");

    let delta = r.rt.stats.snapshot().since(&before);
    let mut summed = TransferSnapshot::default();
    for stats in q.tenants().values() {
        summed = summed.plus(&stats.transfers);
    }
    assert_eq!(summed, delta, "park/resume billing must stay exact");
}

/// An FF-enabled spec with an explicit trigger policy and optimizer
/// backend (warmup 3 + T_interval 3 from [`cfg`], so an 8-step run is
/// guaranteed to cross FF stages — park/resume round-trips policy state,
/// not just weights).
fn policy_spec(
    rig: &Rig,
    label: &str,
    kind: FfPolicyKind,
    backend: OptimBackend,
    steps: usize,
) -> RunSpec {
    let mut c = cfg(23, true);
    c.backend = backend;
    c.ff.policy = kind;
    RunSpec {
        label: label.to_string(),
        cfg: c,
        stop: StopRule::MaxSteps(steps),
        base: Some(Arc::clone(&rig.base)),
        drain_interval: None,
    }
}

#[test]
fn every_policy_survives_park_resume_bit_identically() {
    // The FfPosition snapshot is tagged per policy: for each trigger
    // policy (and the LoFT backend on top), a quantum-2 churned run must
    // reproduce the uninterrupted reference bit-for-bit with whole-run
    // step counts.
    let r = rig();
    let mut pairs: Vec<(FfPolicyKind, OptimBackend)> =
        FfPolicyKind::ALL.iter().map(|&k| (k, OptimBackend::Adam)).collect();
    pairs.push((FfPolicyKind::Interval, OptimBackend::Loft));
    for (kind, backend) in pairs {
        let tag = format!("{}-{}", kind.as_str(), backend.as_str());
        let reference = RunQueue::new(1)
            .submit_run(&r.rt, &r.cache, policy_spec(&r, &format!("ref/{tag}"), kind, backend, 8), 0, "t")
            .unwrap()
            .join()
            .unwrap()
            .done()
            .expect("reference completes");
        if kind == FfPolicyKind::Interval {
            assert!(!reference.stages.is_empty(), "interval must fast-forward within 8 steps");
        }
        let q = RunQueue::new_paused(1);
        q.set_step_quantum(2);
        let h = q
            .submit_run(&r.rt, &r.cache, policy_spec(&r, &format!("churn/{tag}"), kind, backend, 8), 0, "t")
            .unwrap();
        q.release();
        let churned = h.join().unwrap().done().expect("churned run resumes to completion");
        assert!(reference.bit_identical(&churned), "{tag}: park/resume changed the losses");
        assert_eq!(reference.summary.adam_steps, churned.summary.adam_steps, "{tag}");
        assert_eq!(reference.summary.sim_steps, churned.summary.sim_steps, "{tag}");
        assert!(q.tenant("t").parked >= 1, "{tag}: quantum 2 over 8 steps must park");
    }
}

#[test]
fn loft_decay_one_is_bit_identical_to_adam_backend() {
    // decay = 1 scales the Adam moments by exactly 1.0 (m·1, v·1²): the
    // realignment dispatches but cannot perturb the trajectory, so the
    // whole run must match the plain-Adam backend bit-for-bit. A real
    // decay must leave a trace — at minimum the charged realign FLOPs.
    let r = rig();
    let run = |label: &str, backend: OptimBackend, decay: f32| {
        let mut s = policy_spec(&r, label, FfPolicyKind::Interval, backend, 8);
        s.cfg.loft_decay = decay;
        RunQueue::new(1)
            .submit_run(&r.rt, &r.cache, s, 0, "t")
            .unwrap()
            .join()
            .unwrap()
            .done()
            .expect("run completes")
    };
    let adam = run("adam", OptimBackend::Adam, 0.5);
    let noop = run("loft-noop", OptimBackend::Loft, 1.0);
    assert!(adam.bit_identical(&noop), "decay-1 realignment must be a bit-exact no-op");
    let loft = run("loft", OptimBackend::Loft, 0.5);
    assert!(
        loft.summary.flops.total() > adam.summary.flops.total(),
        "the LoFT backend must charge its realignment FLOPs"
    );
}

#[test]
fn streaming_run_matches_its_batch_twin_with_exact_tenant_bytes() {
    // submit_stream: the tenant feeds examples in uneven chunks and then
    // closes the stream. The trainer consumes them under the same
    // park/resume machinery as any queue run, so the result must be
    // bit-identical to a batch submission of the same spec — and the
    // streaming tenant's byte totals (data-starved holds and resumes
    // included) must still sum exactly to the global meter delta.
    let r = rig();
    let steps = 6;
    let batch = RunQueue::new(1)
        .submit_run(&r.rt, &r.cache, spec(&r, "batch", 51, true, steps), 0, "t")
        .unwrap()
        .join()
        .unwrap()
        .done()
        .expect("batch twin completes");

    let before = r.rt.stats.snapshot();
    let q = RunQueue::new(1);
    let s = spec(&r, "stream", 51, true, steps);
    let gb = s.cfg.global_batch as u64;
    let total = gb * steps as u64;
    let (h, stream) = q.submit_stream(&r.rt, &r.cache, s, 0, "erin").unwrap();
    stream.feed(gb / 2); // less than one step's worth: starved at first
    stream.feed(total - gb / 2);
    assert_eq!(stream.fed(), total);
    stream.finish();
    stream.feed(999); // after finish: a no-op, the step budget is fixed
    assert_eq!(stream.fed(), total, "feeds after finish must not change the budget");
    let out = h.join().unwrap().done().expect("stream completes after finish");
    assert!(batch.bit_identical(&out), "streamed run diverged from its batch twin");
    assert_eq!(out.summary.adam_steps, steps, "the stream fed exactly the step budget");

    let delta = r.rt.stats.snapshot().since(&before);
    let mut summed = TransferSnapshot::default();
    for stats in q.tenants().values() {
        summed = summed.plus(&stats.transfers);
    }
    assert_eq!(summed, delta, "streaming tenant bytes must stay exact");
}

#[test]
fn submit_stream_rejects_non_maxsteps_stop_rules() {
    // A stream's upper bound is its MaxSteps rule; target-loss rules
    // would race the feed and must be refused at submission, loudly.
    let r = rig();
    let q = RunQueue::new(1);
    let mut s = spec(&r, "bad", 1, false, 4);
    s.stop = StopRule::TargetLoss { target: 0.0, eps: 1e-3, eval_every: 2, max_steps: 8 };
    let err = q.submit_stream(&r.rt, &r.cache, s, 0, "t").unwrap_err();
    assert!(format!("{err:#}").contains("MaxSteps"), "{err:#}");
}
