//! Content-addressed artifact store shared across hosts.
//!
//! The in-process [`ArtifactCache`](crate::sched::ArtifactCache) and W0
//! cache (PR 4) stop at the process boundary: every host that runs a grid
//! cell pays the full AOT-compile and pretrain cost even when an identical
//! artifact was already built elsewhere. This module is the cross-host
//! half: a content-addressed store (CAS) on a shared filesystem holding
//!
//! * **compiled AOT program bundles** — an artifact directory
//!   (`manifest.json` + `*.hlo.txt`) packed into a single `FFAB1` blob,
//!   keyed by the artifact's *content hash* (the canonical manifest bytes
//!   plus every program's HLO bytes — the same recipe
//!   `python/compile/aot.py` stamps into `manifest.json` as
//!   `content_hash`), and
//! * **W0 pretrain checkpoints** — raw `FFCK1` bytes keyed by their
//!   sha256, with a small named ref pointing at the current blob.
//!
//! Layout (`docs/artifact-store.md` has the full contract):
//!
//! ```text
//! store/<hh>/<sha256>         object blobs, hh = first two hex chars
//! store/refs/<name>           name -> hash pointers (artifact/<key>, w0/<model>-<steps>)
//! store/quarantine/<hash>.<pid>  corrupt objects, moved aside on detection
//! ```
//!
//! Every read re-verifies content: a corrupt entry is *loudly* moved to
//! `quarantine/` and reported as a miss so the caller rebuilds — never
//! silently reused. Quarantining also drops any `refs/*` pointer still
//! naming the corrupt hash ([`ArtifactStore::drop_ref`]): a dangling ref
//! would turn every later resolution into an object-missing dead end
//! instead of a clean, rebuildable miss. All writes are temp-then-rename (the PR-4 checkpoint
//! idiom), so concurrent hosts racing on the same object converge on one
//! valid blob. Store traffic is host-disk I/O only; it never touches the
//! device transfer meters (`docs/transfer-contract.md`).

pub mod sha256;

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;
use sha256::{sha256_hex, Sha256};

/// Magic prefix of a packed artifact-bundle object.
const BUNDLE_MAGIC: &[u8; 6] = b"FFAB1\n";

// ---------------------------------------------------------------------------
// Stats
// ---------------------------------------------------------------------------

/// Atomic hit/miss/byte counters for one [`ArtifactStore`] (the same shape
/// as the runtime's `TransferStats`: relaxed atomics, snapshot to read).
#[derive(Debug, Default)]
pub struct StoreStats {
    artifact_hits: AtomicU64,
    artifact_misses: AtomicU64,
    w0_hits: AtomicU64,
    w0_misses: AtomicU64,
    w0_builds: AtomicU64,
    ingests: AtomicU64,
    quarantined: AtomicU64,
    bytes_read: AtomicU64,
    bytes_written: AtomicU64,
}

impl StoreStats {
    fn bump(counter: &AtomicU64, n: u64) {
        counter.fetch_add(n, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> StoreSnapshot {
        StoreSnapshot {
            artifact_hits: self.artifact_hits.load(Ordering::Relaxed),
            artifact_misses: self.artifact_misses.load(Ordering::Relaxed),
            w0_hits: self.w0_hits.load(Ordering::Relaxed),
            w0_misses: self.w0_misses.load(Ordering::Relaxed),
            w0_builds: self.w0_builds.load(Ordering::Relaxed),
            ingests: self.ingests.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            bytes_read: self.bytes_read.load(Ordering::Relaxed),
            bytes_written: self.bytes_written.load(Ordering::Relaxed),
        }
    }
}

/// Plain-value snapshot of [`StoreStats`] (also used as a delta between two
/// snapshots, see [`StoreSnapshot::since`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreSnapshot {
    /// Artifact bundles resolved from the store (or already present in it
    /// at ingest time).
    pub artifact_hits: u64,
    /// Artifact resolutions the store could not serve (cold ingest or a
    /// missing/corrupt object).
    pub artifact_misses: u64,
    /// W0 checkpoints resolved from the store.
    pub w0_hits: u64,
    /// W0 resolutions the store could not serve.
    pub w0_misses: u64,
    /// W0 checkpoints pretrained from scratch ("rebuilds").
    pub w0_builds: u64,
    /// Objects published into the store from local builds.
    pub ingests: u64,
    /// Corrupt objects detected and moved to `quarantine/`.
    pub quarantined: u64,
    /// Object bytes read out of the store.
    pub bytes_read: u64,
    /// Object bytes written into the store.
    pub bytes_written: u64,
}

impl StoreSnapshot {
    /// Counter delta `self - earlier` (saturating; counters only grow).
    pub fn since(&self, earlier: &StoreSnapshot) -> StoreSnapshot {
        StoreSnapshot {
            artifact_hits: self.artifact_hits.saturating_sub(earlier.artifact_hits),
            artifact_misses: self.artifact_misses.saturating_sub(earlier.artifact_misses),
            w0_hits: self.w0_hits.saturating_sub(earlier.w0_hits),
            w0_misses: self.w0_misses.saturating_sub(earlier.w0_misses),
            w0_builds: self.w0_builds.saturating_sub(earlier.w0_builds),
            ingests: self.ingests.saturating_sub(earlier.ingests),
            quarantined: self.quarantined.saturating_sub(earlier.quarantined),
            bytes_read: self.bytes_read.saturating_sub(earlier.bytes_read),
            bytes_written: self.bytes_written.saturating_sub(earlier.bytes_written),
        }
    }

    /// True when every resolution in this window was served from the store:
    /// no cold compiles, no pretrain rebuilds, no corrupt objects.
    pub fn all_hits(&self) -> bool {
        self.artifact_misses == 0
            && self.w0_misses == 0
            && self.w0_builds == 0
            && self.ingests == 0
            && self.quarantined == 0
    }

    pub fn report(&self) -> String {
        format!(
            "store: artifacts {} hit / {} miss, w0 {} hit / {} miss ({} rebuilt), \
             {} ingested, {} quarantined, {} B in / {} B out",
            self.artifact_hits,
            self.artifact_misses,
            self.w0_hits,
            self.w0_misses,
            self.w0_builds,
            self.ingests,
            self.quarantined,
            self.bytes_read,
            self.bytes_written,
        )
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("artifact_hits", self.artifact_hits as i64)
            .set("artifact_misses", self.artifact_misses as i64)
            .set("w0_hits", self.w0_hits as i64)
            .set("w0_misses", self.w0_misses as i64)
            .set("w0_builds", self.w0_builds as i64)
            .set("ingests", self.ingests as i64)
            .set("quarantined", self.quarantined as i64)
            .set("bytes_read", self.bytes_read as i64)
            .set("bytes_written", self.bytes_written as i64)
    }
}

// ---------------------------------------------------------------------------
// Content hashing
// ---------------------------------------------------------------------------

/// Result of hashing an artifact directory with the canonical recipe.
#[derive(Debug, Clone)]
pub struct ArtifactDigest {
    /// Hash computed from the directory contents.
    pub computed: String,
    /// Hash recorded in `manifest.json` by the python emitter, if stamped.
    pub recorded: Option<String>,
    /// Program files covered by the hash, in recipe order (program-name
    /// sorted). `manifest.json` itself is not listed.
    pub files: Vec<String>,
}

/// Split a manifest text into its canonical (pre-stamp) bytes and the
/// recorded hash. The python emitter appends `content_hash` as the last
/// key of the top-level object, so a stamped manifest always ends with
/// `,\n "content_hash": "<64 hex>"\n}` — stripping that suffix recovers
/// exactly the bytes that were hashed. Unstamped manifests hash whole.
fn split_recorded(manifest_text: &str) -> (String, Option<String>) {
    const MARK: &str = ",\n \"content_hash\": \"";
    if let Some(pos) = manifest_text.rfind(MARK) {
        let rest = &manifest_text[pos + MARK.len()..];
        let hex_ok = rest.len() == 64 + 3
            && rest.ends_with("\"\n}")
            && rest[..64].bytes().all(|b| b.is_ascii_hexdigit());
        if hex_ok {
            let canonical = format!("{}\n}}", &manifest_text[..pos]);
            return (canonical, Some(rest[..64].to_string()));
        }
    }
    (manifest_text.to_string(), None)
}

/// Canonical content-hash recipe, shared with `python/compile/aot.py`:
/// sha256 over the canonical manifest bytes, then for each program file in
/// program-name-sorted order `\0<file name>\0<file bytes>`.
fn digest_from(
    manifest_text: &str,
    mut file_bytes: impl FnMut(&str) -> Result<Vec<u8>>,
) -> Result<ArtifactDigest> {
    let (canonical, recorded) = split_recorded(manifest_text);
    let parsed = Json::parse(manifest_text)
        .map_err(|e| anyhow!("manifest.json is not valid JSON: {e}"))?;
    let programs = parsed
        .get("programs")
        .as_obj()
        .context("manifest.json has no programs object")?;
    let mut h = Sha256::new();
    h.update(canonical.as_bytes());
    let mut files = Vec::with_capacity(programs.len());
    for (prog, spec) in programs {
        let fname = spec
            .get("file")
            .as_str()
            .with_context(|| format!("program '{prog}' has no file field"))?;
        h.update(b"\0");
        h.update(fname.as_bytes());
        h.update(b"\0");
        h.update(&file_bytes(fname)?);
        files.push(fname.to_string());
    }
    Ok(ArtifactDigest { computed: h.hex(), recorded, files })
}

/// Hash an on-disk artifact directory with the canonical recipe.
pub fn digest_artifact_dir(dir: &Path) -> Result<ArtifactDigest> {
    let manifest_text = fs::read_to_string(dir.join("manifest.json"))
        .with_context(|| format!("reading {}", dir.join("manifest.json").display()))?;
    digest_from(&manifest_text, |fname| {
        fs::read(dir.join(fname)).with_context(|| format!("reading {}", dir.join(fname).display()))
    })
}

/// Verify a local artifact directory against its own recorded hash and an
/// optional lockfile pin, failing fast with a clear mismatch error. Returns
/// the computed content hash.
pub fn verify_local_artifact(dir: &Path, key: &str, pinned: Option<&str>) -> Result<String> {
    let d = digest_artifact_dir(dir)?;
    if let Some(rec) = &d.recorded {
        if *rec != d.computed {
            bail!(
                "artifact '{key}': manifest records content_hash {rec} but the directory \
                 hashes to {} — the artifact dir is corrupt or was edited; re-run \
                 `make artifacts`",
                d.computed
            );
        }
    }
    if let Some(pin) = pinned {
        if pin != d.computed {
            bail!(
                "lockfile pins artifact '{key}' at {pin} but the local build hashes to {} — \
                 refusing to run a mixed grid; rebuild artifacts on every host from the same \
                 compile inputs or re-emit the manifest + lockfile",
                d.computed
            );
        }
    }
    Ok(d.computed)
}

// ---------------------------------------------------------------------------
// Bundle codec
// ---------------------------------------------------------------------------

/// Pack named files into one blob: `FFAB1\n` + u64-LE header length + a
/// JSON header listing `{name, len}` in order + the raw file bytes
/// concatenated in the same order.
fn encode_bundle(files: &[(String, Vec<u8>)]) -> Vec<u8> {
    let header = Json::Arr(
        files
            .iter()
            .map(|(name, data)| {
                Json::obj().set("len", data.len()).set("name", name.as_str())
            })
            .collect(),
    );
    let header = Json::obj().set("files", header).to_string();
    let mut out = Vec::with_capacity(
        BUNDLE_MAGIC.len() + 8 + header.len() + files.iter().map(|(_, d)| d.len()).sum::<usize>(),
    );
    out.extend_from_slice(BUNDLE_MAGIC);
    out.extend_from_slice(&(header.len() as u64).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for (_, data) in files {
        out.extend_from_slice(data);
    }
    out
}

fn decode_bundle(bytes: &[u8]) -> Result<Vec<(String, Vec<u8>)>> {
    let body = bytes
        .strip_prefix(BUNDLE_MAGIC.as_slice())
        .context("not an FFAB1 bundle (bad magic)")?;
    let (len_bytes, body) = body.split_at_checked(8).context("truncated bundle header")?;
    let header_len = u64::from_le_bytes(len_bytes.try_into().unwrap()) as usize;
    let (header, mut data) = body
        .split_at_checked(header_len)
        .context("truncated bundle header")?;
    let header = std::str::from_utf8(header).context("bundle header is not utf-8")?;
    let header = Json::parse(header).map_err(|e| anyhow!("bundle header: {e}"))?;
    let entries = header.get("files").as_arr().context("bundle header has no files")?;
    let mut out = Vec::with_capacity(entries.len());
    for entry in entries {
        let name = entry.get("name").as_str().context("bundle entry has no name")?;
        if name.contains('/') || name.contains('\\') || name.contains("..") || name.is_empty() {
            bail!("bundle entry has unsafe file name {name:?}");
        }
        let len = entry.get("len").as_usize().context("bundle entry has no len")?;
        let (file, rest) = data.split_at_checked(len).context("truncated bundle data")?;
        out.push((name.to_string(), file.to_vec()));
        data = rest;
    }
    if !data.is_empty() {
        bail!("bundle has {} trailing bytes", data.len());
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------------

/// A content-addressed store rooted at a (possibly network-mounted)
/// directory. Cheap to open; all methods are `&self` and safe to share
/// across threads and hosts (atomic counters + rename-based writes).
#[derive(Debug)]
pub struct ArtifactStore {
    root: PathBuf,
    /// Hit/miss/byte counters for this handle (per-process, not global).
    pub stats: StoreStats,
}

impl ArtifactStore {
    pub fn open(root: impl Into<PathBuf>) -> Result<ArtifactStore> {
        let root = root.into();
        fs::create_dir_all(&root)
            .with_context(|| format!("creating store root {}", root.display()))?;
        Ok(ArtifactStore { root, stats: StoreStats::default() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn object_path(&self, hash: &str) -> PathBuf {
        let shard = hash.get(..2).unwrap_or("xx");
        self.root.join(shard).join(hash)
    }

    pub fn contains(&self, hash: &str) -> bool {
        self.object_path(hash).exists()
    }

    /// Write an object if absent. Returns true when this call created it.
    fn write_object(&self, hash: &str, bytes: &[u8]) -> Result<bool> {
        let path = self.object_path(hash);
        if path.exists() {
            return Ok(false);
        }
        atomic_write(&path, bytes)?;
        StoreStats::bump(&self.stats.bytes_written, bytes.len() as u64);
        Ok(true)
    }

    fn read_object(&self, hash: &str) -> Result<Option<Vec<u8>>> {
        let path = self.object_path(hash);
        match fs::read(&path) {
            Ok(b) => {
                StoreStats::bump(&self.stats.bytes_read, b.len() as u64);
                Ok(Some(b))
            }
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e).with_context(|| format!("reading {}", path.display())),
        }
    }

    /// Move a corrupt object aside (never deleted, never reused). The pid
    /// suffix keeps concurrent detectors from clobbering each other.
    fn quarantine_object(&self, hash: &str) {
        let dst = self
            .root
            .join("quarantine")
            .join(format!("{hash}.{}", std::process::id()));
        let _ = fs::create_dir_all(dst.parent().unwrap());
        let _ = fs::rename(self.object_path(hash), &dst);
        StoreStats::bump(&self.stats.quarantined, 1);
    }

    // -- refs ---------------------------------------------------------------

    /// Read a name -> hash pointer (e.g. `artifact/<key>`, `w0/<model>-<n>`).
    pub fn read_ref(&self, name: &str) -> Option<String> {
        let text = fs::read_to_string(self.root.join("refs").join(name)).ok()?;
        let hash = text.trim().to_string();
        (hash.len() == 64 && hash.bytes().all(|b| b.is_ascii_hexdigit())).then_some(hash)
    }

    pub fn write_ref(&self, name: &str, hash: &str) -> Result<()> {
        atomic_write(&self.root.join("refs").join(name), format!("{hash}\n").as_bytes())
    }

    /// Remove a name -> hash pointer. Callers drop a ref when the object
    /// it names was quarantined **and the ref still points at that hash**
    /// — unconditionally dropping would race a concurrent re-publish that
    /// already repointed the name at a fresh object.
    pub fn drop_ref(&self, name: &str) {
        let _ = fs::remove_file(self.root.join("refs").join(name));
    }

    // -- W0 checkpoints -----------------------------------------------------

    /// Publish a local checkpoint under a named ref. Idempotent: if the ref
    /// already points at these exact bytes nothing is written.
    pub fn publish_checkpoint(&self, name: &str, bytes: &[u8]) -> Result<String> {
        let hash = sha256_hex(bytes);
        if self.read_ref(name).as_deref() == Some(hash.as_str()) && self.contains(&hash) {
            return Ok(hash);
        }
        if self.write_object(&hash, bytes)? {
            StoreStats::bump(&self.stats.ingests, 1);
        }
        self.write_ref(name, &hash)?;
        Ok(hash)
    }

    /// Resolve a named checkpoint, verifying the blob's sha256 on read.
    /// Returns `None` (a miss) when the ref is absent, the object is
    /// missing, or the object is corrupt — the corrupt case quarantines the
    /// blob so the caller's rebuild re-publishes a fresh one.
    pub fn fetch_checkpoint(&self, name: &str) -> Result<Option<Vec<u8>>> {
        let Some(hash) = self.read_ref(name) else {
            StoreStats::bump(&self.stats.w0_misses, 1);
            return Ok(None);
        };
        let Some(bytes) = self.read_object(&hash)? else {
            StoreStats::bump(&self.stats.w0_misses, 1);
            return Ok(None);
        };
        if sha256_hex(&bytes) != hash {
            eprintln!(
                "store: checkpoint object {hash} ('{name}') failed verification — \
                 quarantined, will rebuild"
            );
            self.quarantine_object(&hash);
            // The ref now names an object that no longer exists at its
            // address; drop it (unless a racing re-publish already
            // repointed it) so the next fetch is a clean miss.
            if self.read_ref(name).as_deref() == Some(hash.as_str()) {
                self.drop_ref(name);
            }
            StoreStats::bump(&self.stats.w0_misses, 1);
            return Ok(None);
        }
        StoreStats::bump(&self.stats.w0_hits, 1);
        Ok(Some(bytes))
    }

    /// Record that a W0 checkpoint had to be pretrained from scratch.
    pub fn note_w0_build(&self) {
        StoreStats::bump(&self.stats.w0_builds, 1);
    }

    // -- artifact bundles ---------------------------------------------------

    /// Publish a local artifact directory into the store, keyed by its
    /// canonical content hash. Counts a hit when the store already holds
    /// the object (another host got there first), a miss + ingest when this
    /// call had to pack and write it. Also updates the `artifact/<key>` ref.
    pub fn ingest_artifact(&self, key: &str, dir: &Path) -> Result<String> {
        let hash = verify_local_artifact(dir, key, None)?;
        if self.contains(&hash) {
            StoreStats::bump(&self.stats.artifact_hits, 1);
        } else {
            let d = digest_artifact_dir(dir)?;
            let mut files = vec![(
                "manifest.json".to_string(),
                fs::read(dir.join("manifest.json"))?,
            )];
            for fname in &d.files {
                files.push((fname.clone(), fs::read(dir.join(fname))?));
            }
            self.write_object(&hash, &encode_bundle(&files))?;
            StoreStats::bump(&self.stats.artifact_misses, 1);
            StoreStats::bump(&self.stats.ingests, 1);
        }
        self.write_ref(&format!("artifact/{key}"), &hash)?;
        Ok(hash)
    }

    /// Materialize an artifact into `dest` from the store, resolving the
    /// object via the lockfile pin (preferred) or the `artifact/<key>` ref.
    /// The bundle is decoded and re-hashed with the canonical recipe before
    /// any file is written; a mismatch quarantines the object and errors.
    pub fn materialize_artifact(
        &self,
        key: &str,
        pinned: Option<&str>,
        dest: &Path,
    ) -> Result<String> {
        let Some(hash) = pinned
            .map(str::to_string)
            .or_else(|| self.read_ref(&format!("artifact/{key}")))
        else {
            StoreStats::bump(&self.stats.artifact_misses, 1);
            bail!(
                "artifact '{key}' is not built locally and the store has no pin or ref for \
                 it — build it once (`make artifacts`) on a host that shares this store"
            );
        };
        let Some(bytes) = self.read_object(&hash)? else {
            StoreStats::bump(&self.stats.artifact_misses, 1);
            bail!(
                "artifact '{key}' resolves to store object {hash}, which is missing — \
                 re-ingest it from a host that has the build"
            );
        };
        let verified = (|| -> Result<Vec<(String, Vec<u8>)>> {
            let files = decode_bundle(&bytes)?;
            let manifest = files
                .iter()
                .find(|(n, _)| n == "manifest.json")
                .context("bundle has no manifest.json")?;
            let manifest_text =
                std::str::from_utf8(&manifest.1).context("manifest.json is not utf-8")?;
            let lookup: BTreeMap<&str, &[u8]> =
                files.iter().map(|(n, d)| (n.as_str(), d.as_slice())).collect();
            let d = digest_from(manifest_text, |fname| {
                lookup
                    .get(fname)
                    .map(|b| b.to_vec())
                    .with_context(|| format!("bundle is missing program file {fname}"))
            })?;
            if d.computed != hash {
                bail!("content hash mismatch: object named {hash} hashes to {}", d.computed);
            }
            Ok(files)
        })();
        let files = match verified {
            Ok(files) => files,
            Err(e) => {
                eprintln!("store: artifact object {hash} ('{key}') failed verification — quarantined");
                self.quarantine_object(&hash);
                // Drop the key's ref only when it still names the
                // quarantined hash — under a lockfile pin the ref may
                // legitimately point at a different (healthy) object.
                let ref_name = format!("artifact/{key}");
                if self.read_ref(&ref_name).as_deref() == Some(hash.as_str()) {
                    self.drop_ref(&ref_name);
                }
                StoreStats::bump(&self.stats.artifact_misses, 1);
                return Err(e.context(format!(
                    "store object {hash} for artifact '{key}' is corrupt (quarantined, never \
                     reused) — rebuild with `make artifacts` and re-ingest"
                )));
            }
        };
        // manifest.json is written last: a partially materialized dir never
        // looks like a complete artifact to other readers.
        fs::create_dir_all(dest).with_context(|| format!("creating {}", dest.display()))?;
        for (name, data) in files.iter().filter(|(n, _)| n != "manifest.json") {
            atomic_write(&dest.join(name), data)?;
        }
        let manifest = files.iter().find(|(n, _)| n == "manifest.json").unwrap();
        atomic_write(&dest.join("manifest.json"), &manifest.1)?;
        StoreStats::bump(&self.stats.artifact_hits, 1);
        Ok(hash)
    }
}

/// Temp-then-rename write (the PR-4 checkpoint idiom): readers never see a
/// partial file, and last-writer-wins is safe because object content is
/// immutable for a given name.
fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let parent = path.parent().context("path has no parent")?;
    fs::create_dir_all(parent).with_context(|| format!("creating {}", parent.display()))?;
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    fs::write(&tmp, bytes).with_context(|| format!("writing {}", tmp.display()))?;
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "ff-store-test-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    /// Build a synthetic artifact dir with a stamped manifest, exactly the
    /// way `python/compile/aot.py` stamps it (content_hash appended as the
    /// last top-level key).
    fn fake_artifact(dir: &Path, hlo_a: &[u8], hlo_b: &[u8]) {
        fs::create_dir_all(dir).unwrap();
        fs::write(dir.join("a.hlo.txt"), hlo_a).unwrap();
        fs::write(dir.join("b.hlo.txt"), hlo_b).unwrap();
        let canonical = "{\n \"format_version\": 1,\n \"key\": \"fake\",\n \"programs\": {\n  \"adam_apply\": {\n   \"file\": \"a.hlo.txt\"\n  },\n  \"train_step\": {\n   \"file\": \"b.hlo.txt\"\n  }\n }\n}";
        let mut h = Sha256::new();
        h.update(canonical.as_bytes());
        for (name, data) in [("a.hlo.txt", hlo_a), ("b.hlo.txt", hlo_b)] {
            h.update(b"\0");
            h.update(name.as_bytes());
            h.update(b"\0");
            h.update(data);
        }
        let hash = h.hex();
        let stamped = format!(
            "{},\n \"content_hash\": \"{hash}\"\n}}",
            &canonical[..canonical.len() - 2]
        );
        fs::write(dir.join("manifest.json"), stamped).unwrap();
    }

    #[test]
    fn recorded_hash_matches_computed_and_is_stable() {
        let root = tmp_dir("digest");
        let art = root.join("art");
        fake_artifact(&art, b"hlo-a", b"hlo-b");
        let d = digest_artifact_dir(&art).unwrap();
        assert_eq!(d.recorded.as_ref(), Some(&d.computed));
        assert_eq!(d.files, vec!["a.hlo.txt", "b.hlo.txt"]);
        // Stable across re-reads, sensitive to content.
        assert_eq!(digest_artifact_dir(&art).unwrap().computed, d.computed);
        fs::write(art.join("a.hlo.txt"), b"hlo-a CHANGED").unwrap();
        assert_ne!(digest_artifact_dir(&art).unwrap().computed, d.computed);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn unstamped_manifest_hashes_whole_text() {
        let root = tmp_dir("unstamped");
        let art = root.join("art");
        fs::create_dir_all(&art).unwrap();
        fs::write(art.join("p.hlo.txt"), b"p").unwrap();
        let text = "{\n \"programs\": {\n  \"p\": {\n   \"file\": \"p.hlo.txt\"\n  }\n }\n}";
        fs::write(art.join("manifest.json"), text).unwrap();
        let d = digest_artifact_dir(&art).unwrap();
        assert_eq!(d.recorded, None);
        assert_eq!(d.files, vec!["p.hlo.txt"]);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn bundle_round_trips() {
        let files = vec![
            ("manifest.json".to_string(), b"{}".to_vec()),
            ("x.hlo.txt".to_string(), vec![0u8, 1, 255, 7]),
            ("empty".to_string(), vec![]),
        ];
        let enc = encode_bundle(&files);
        assert_eq!(decode_bundle(&enc).unwrap(), files);
        assert!(decode_bundle(&enc[..enc.len() - 1]).is_err());
        assert!(decode_bundle(b"nope").is_err());
    }

    #[test]
    fn ingest_then_materialize_round_trips_with_full_hits() {
        let root = tmp_dir("roundtrip");
        let art = root.join("art");
        fake_artifact(&art, b"AAAA", b"BBBB");
        let store = ArtifactStore::open(root.join("store")).unwrap();
        let hash = store.ingest_artifact("fake", &art).unwrap();
        let s = store.stats.snapshot();
        assert_eq!((s.artifact_misses, s.ingests), (1, 1), "cold ingest");
        // Second ingest of identical content: pure hit.
        store.ingest_artifact("fake", &art).unwrap();
        assert_eq!(store.stats.snapshot().artifact_hits, 1);
        // Materialize on a "second host" (empty dir), via ref and via pin.
        let dest = root.join("host2").join("fake");
        let got = store.materialize_artifact("fake", None, &dest).unwrap();
        assert_eq!(got, hash);
        for f in ["manifest.json", "a.hlo.txt", "b.hlo.txt"] {
            assert_eq!(fs::read(dest.join(f)).unwrap(), fs::read(art.join(f)).unwrap());
        }
        let dest3 = root.join("host3").join("fake");
        store.materialize_artifact("fake", Some(&hash), &dest3).unwrap();
        let s = store.stats.snapshot();
        assert_eq!(s.artifact_hits, 3);
        assert_eq!(s.quarantined, 0);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupt_object_is_quarantined_and_rebuilt_never_reused() {
        let root = tmp_dir("corrupt");
        let art = root.join("art");
        fake_artifact(&art, b"AAAA", b"BBBB");
        let store = ArtifactStore::open(root.join("store")).unwrap();
        let hash = store.ingest_artifact("fake", &art).unwrap();
        // Flip one byte in the stored object.
        let obj = store.object_path(&hash);
        let mut bytes = fs::read(&obj).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        fs::write(&obj, &bytes).unwrap();
        // Read back: loud failure, object moved to quarantine.
        let dest = root.join("host2").join("fake");
        let err = store.materialize_artifact("fake", Some(&hash), &dest).unwrap_err();
        assert!(err.to_string().contains("quarantined"), "{err:#}");
        assert!(!obj.exists(), "corrupt object must not stay at its address");
        assert!(store
            .root()
            .join("quarantine")
            .read_dir()
            .unwrap()
            .any(|e| e.unwrap().file_name().to_string_lossy().starts_with(&hash)));
        assert!(!dest.join("manifest.json").exists(), "no partial materialization");
        assert_eq!(store.stats.snapshot().quarantined, 1);
        // Rebuild: re-ingest from the good local dir, then materialize fine.
        store.ingest_artifact("fake", &art).unwrap();
        store.materialize_artifact("fake", Some(&hash), &dest).unwrap();
        assert_eq!(fs::read(dest.join("b.hlo.txt")).unwrap(), b"BBBB");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn quarantine_drops_stale_refs_but_not_repointed_ones() {
        let root = tmp_dir("staleref");
        let art = root.join("art");
        fake_artifact(&art, b"AAAA", b"BBBB");
        let store = ArtifactStore::open(root.join("store")).unwrap();
        let corrupt = |hash: &str| {
            let obj = store.object_path(hash);
            let mut bytes = fs::read(&obj).unwrap();
            let last = bytes.len() - 1;
            bytes[last] ^= 0x01;
            fs::write(&obj, &bytes).unwrap();
        };
        let hash = store.ingest_artifact("fake", &art).unwrap();
        assert_eq!(store.read_ref("artifact/fake").as_deref(), Some(hash.as_str()));
        // Corrupt + resolve via the ref: quarantine must also drop the
        // now-dangling ref, so later resolutions report "no ref — build
        // it" (rebuildable) instead of an object-missing dead end.
        corrupt(&hash);
        let dest = root.join("host2").join("fake");
        store.materialize_artifact("fake", None, &dest).unwrap_err();
        assert!(store.read_ref("artifact/fake").is_none(), "stale ref must go");
        let err = store.materialize_artifact("fake", None, &dest).unwrap_err();
        assert!(err.to_string().contains("no pin or ref"), "{err:#}");
        // Recovery: re-ingest recreates both object and ref.
        store.ingest_artifact("fake", &art).unwrap();
        assert_eq!(store.read_ref("artifact/fake").as_deref(), Some(hash.as_str()));
        store.materialize_artifact("fake", None, &dest).unwrap();
        // A pin-resolved quarantine must only drop the ref while it still
        // names the corrupt hash — a racing re-publish that repointed the
        // name at another object must survive.
        corrupt(&hash);
        let other = "0".repeat(64);
        store.write_ref("artifact/fake", &other).unwrap();
        store.materialize_artifact("fake", Some(&hash), &dest).unwrap_err();
        assert_eq!(
            store.read_ref("artifact/fake").as_deref(),
            Some(other.as_str()),
            "a repointed ref must survive another object's quarantine"
        );
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn lockfile_pin_mismatch_fails_fast() {
        let root = tmp_dir("pin");
        let art = root.join("art");
        fake_artifact(&art, b"AAAA", b"BBBB");
        let bogus = "0".repeat(64);
        let err = verify_local_artifact(&art, "fake", Some(&bogus)).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("lockfile pins artifact 'fake'"), "{msg}");
        assert!(msg.contains(&bogus), "{msg}");
        // And a tampered dir trips the recorded-hash check even unpinned.
        fs::write(art.join("b.hlo.txt"), b"EVIL").unwrap();
        let err = verify_local_artifact(&art, "fake", None).unwrap_err();
        assert!(err.to_string().contains("corrupt or was edited"), "{err:#}");
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn checkpoint_publish_fetch_and_corruption() {
        let root = tmp_dir("ckpt");
        let store = ArtifactStore::open(root.join("store")).unwrap();
        let blob = b"FFCK1 pretend checkpoint bytes".to_vec();
        let hash = store.publish_checkpoint("w0/ff-tiny-120", &blob).unwrap();
        // Idempotent republish.
        assert_eq!(store.publish_checkpoint("w0/ff-tiny-120", &blob).unwrap(), hash);
        assert_eq!(store.stats.snapshot().ingests, 1);
        assert_eq!(store.fetch_checkpoint("w0/ff-tiny-120").unwrap().unwrap(), blob);
        assert_eq!(store.fetch_checkpoint("w0/missing").unwrap(), None);
        // Corrupt the blob: fetch quarantines and misses; republish recovers.
        let obj = store.object_path(&hash);
        let mut bytes = fs::read(&obj).unwrap();
        bytes[0] ^= 0xff;
        fs::write(&obj, &bytes).unwrap();
        assert_eq!(store.fetch_checkpoint("w0/ff-tiny-120").unwrap(), None);
        assert!(!obj.exists());
        assert!(
            store.read_ref("w0/ff-tiny-120").is_none(),
            "quarantine must drop the stale w0 ref, not leave it dangling"
        );
        let s = store.stats.snapshot();
        assert_eq!((s.quarantined, s.w0_hits, s.w0_misses), (1, 1, 2));
        store.publish_checkpoint("w0/ff-tiny-120", &blob).unwrap();
        assert_eq!(store.fetch_checkpoint("w0/ff-tiny-120").unwrap().unwrap(), blob);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn snapshot_delta_and_all_hits() {
        let a = StoreSnapshot { artifact_hits: 2, bytes_read: 100, ..Default::default() };
        let b = StoreSnapshot { artifact_hits: 5, bytes_read: 350, ..Default::default() };
        let d = b.since(&a);
        assert_eq!((d.artifact_hits, d.bytes_read), (3, 250));
        assert!(d.all_hits());
        assert!(!StoreSnapshot { w0_builds: 1, ..Default::default() }.all_hits());
        assert!(!StoreSnapshot { ingests: 1, ..Default::default() }.all_hits());
    }
}
