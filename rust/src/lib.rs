//! # fastforward
//!
//! Production-grade reproduction of **"Fast Forwarding Low-Rank Training"**
//! (Rahamim, Kangaslahti, Saphra, Belinkov — EMNLP 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the training coordinator, itself split into a
//!   pipelined three-layer step stack (`docs/step-pipeline.md`): a
//!   schedule-policy `Trainer` (Fast Forward controller, stop rules, eval
//!   cadence, FLOPs/transfer accounting) over a `StepEngine` dispatch
//!   layer (device-side gradient accumulation with buffer donation, batch
//!   prefetch, Δ_W tracking) over an `ExecStream` deferred-readback ring
//!   (loss scalars drain every K steps instead of blocking each
//!   micro-batch), the concurrent run scheduler (`sched` — a worker pool
//!   that fans whole training runs out over host threads against one
//!   shared runtime, and a long-lived multi-tenant `RunQueue` with
//!   priorities, poll/join/cancel handles, and exact per-tenant transfer
//!   accounting), plus the data pipeline, experiments, and the PJRT
//!   runtime that executes AOT-compiled artifacts.
//! * **L2 (python/compile/model.py)** — the transformer fwd/bwd in JAX with
//!   LoRA / DoRA / full-rank train modes, lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — the fused LoRA-matmul Pallas kernel,
//!   lowered (interpret mode) into the same HLO.
//!
//! Python never runs on the training path: after `make artifacts` the
//! `fastforward` binary is self-contained. See README.md for the repo
//! tour and docs/transfer-contract.md for the host↔device movement rules
//! (the ParamSet sync machine, donation, steady-state expectations).

pub mod analysis;
pub mod config;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod ff;
pub mod flops;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod sched;
pub mod store;
pub mod train;
pub mod util;
