//! # fastforward
//!
//! Production-grade reproduction of **"Fast Forwarding Low-Rank Training"**
//! (Rahamim, Kangaslahti, Saphra, Belinkov — EMNLP 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the training coordinator: data pipeline, micro-
//!   batch scheduler with device-side gradient accumulation (per-micro
//!   gradients never visit the host), the Fast Forward controller
//!   (interval scheduling + line search on a tiny validation set), FLOPs
//!   and transfer accounting, experiments, and the PJRT runtime that
//!   executes AOT-compiled artifacts with buffer donation on the optimizer
//!   path.
//! * **L2 (python/compile/model.py)** — the transformer fwd/bwd in JAX with
//!   LoRA / DoRA / full-rank train modes, lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — the fused LoRA-matmul Pallas kernel,
//!   lowered (interpret mode) into the same HLO.
//!
//! Python never runs on the training path: after `make artifacts` the
//! `fastforward` binary is self-contained. See README.md for the repo
//! tour and docs/transfer-contract.md for the host↔device movement rules
//! (the ParamSet sync machine, donation, steady-state expectations).

pub mod analysis;
pub mod config;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod ff;
pub mod flops;
pub mod metrics;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod train;
pub mod util;
