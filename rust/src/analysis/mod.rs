//! Analysis substrate for the paper's §6 / appendix measurements:
//! condition numbers (Fig 12b), gradient-history cosine similarity (Fig 6,
//! Fig 13), and the loss-plane scan (Fig 5).

pub mod grads;
pub mod linalg;
pub mod plane;

pub use grads::GradHistory;
pub use linalg::{condition_number, singular_values};
pub use plane::{plane_grid, PlanePoint};
