//! Gradient-history probes (paper Fig 6 & Fig 13, Appendix C):
//! cosine similarity of the current gradient against every previously
//! saved gradient, and batch-wise gradient consistency measured right
//! before a Fast Forward stage.

use crate::model::tensor::{cosine_similarity, Tensor};

/// Rolling store of gradient snapshots taken every `every` optimizer steps.
#[derive(Debug)]
pub struct GradHistory {
    every: usize,
    max_kept: usize,
    saved: Vec<(usize, Vec<Tensor>)>,
    /// (step, mean similarity vs all previous, per-history sims) series.
    pub series: Vec<(usize, f64, Vec<f64>)>,
}

impl GradHistory {
    pub fn new(every: usize, max_kept: usize) -> GradHistory {
        GradHistory { every: every.max(1), max_kept, saved: Vec::new(), series: Vec::new() }
    }

    /// Observe the gradient at `step`; records similarity vs history and
    /// (every `every` steps) saves a snapshot.
    pub fn observe(&mut self, step: usize, grads: &[Tensor]) {
        if !self.saved.is_empty() {
            let sims: Vec<f64> =
                self.saved.iter().map(|(_, g)| cosine_similarity(grads, g)).collect();
            let mean = sims.iter().sum::<f64>() / sims.len() as f64;
            self.series.push((step, mean, sims));
        }
        if step % self.every == 0 {
            if self.saved.len() == self.max_kept {
                self.saved.remove(0);
            }
            self.saved.push((step, grads.to_vec()));
        }
    }

    pub fn n_saved(&self) -> usize {
        self.saved.len()
    }
}

/// Batch-wise gradient consistency (Fig 13): mean pairwise cosine
/// similarity between per-micro-batch gradients.
pub fn batch_consistency(per_batch_grads: &[Vec<Tensor>]) -> f64 {
    let n = per_batch_grads.len();
    if n < 2 {
        return 1.0;
    }
    let mut sum = 0.0;
    let mut cnt = 0usize;
    for i in 0..n {
        for j in (i + 1)..n {
            sum += cosine_similarity(&per_batch_grads[i], &per_batch_grads[j]);
            cnt += 1;
        }
    }
    sum / cnt as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn g(v: &[f32]) -> Vec<Tensor> {
        vec![Tensor::from_vec(&[v.len()], v.to_vec())]
    }

    #[test]
    fn records_similarity_vs_history() {
        let mut h = GradHistory::new(1, 10);
        h.observe(0, &g(&[1.0, 0.0]));
        assert!(h.series.is_empty()); // nothing to compare against yet
        h.observe(1, &g(&[1.0, 0.0]));
        assert!((h.series[0].1 - 1.0).abs() < 1e-12);
        h.observe(2, &g(&[0.0, 1.0]));
        // vs [1,0] and [1,0]: mean 0
        assert!(h.series[1].1.abs() < 1e-12);
        assert_eq!(h.series[1].2.len(), 2);
        assert_eq!(h.n_saved(), 3);
    }

    #[test]
    fn respects_every_and_max_kept() {
        let mut h = GradHistory::new(2, 2);
        for step in 0..8 {
            h.observe(step, &g(&[step as f32 + 1.0, 0.0]));
        }
        assert_eq!(h.n_saved(), 2); // bounded
    }

    #[test]
    fn batch_consistency_extremes() {
        let same = vec![g(&[1.0, 1.0]), g(&[2.0, 2.0]), g(&[0.5, 0.5])];
        assert!((batch_consistency(&same) - 1.0).abs() < 1e-12);
        let ortho = vec![g(&[1.0, 0.0]), g(&[0.0, 1.0])];
        assert!(batch_consistency(&ortho).abs() < 1e-12);
        assert_eq!(batch_consistency(&[g(&[1.0])]), 1.0);
    }
}
