//! Loss-plane scan (paper Fig 5): evaluate test loss on the 2-D plane
//! through three parameter settings — the pretrained W0, the Adam-SGD
//! finetuned W_SGD, and the Fast-Forward finetuned W_FF.
//!
//! Basis construction: e₁ = (W_SGD − W0)/‖·‖; e₂ = orthonormalized
//! (W_FF − W0). A grid point (α, β) corresponds to W0 + α·u·e₁ + β·u·e₂
//! where u = ‖W_FF − W0‖ (the paper's axis scale).

use crate::model::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct PlanePoint {
    pub alpha: f64,
    pub beta: f64,
    pub loss: f32,
}

/// Orthonormal in-plane coordinates for the three anchors.
pub struct PlaneBasis {
    pub origin: Vec<Tensor>,
    pub e1: Vec<Tensor>,
    pub e2: Vec<Tensor>,
    /// Axis scale u = ‖W_FF − W0‖ (paper's normalization).
    pub unit: f64,
    /// (α, β) of W_SGD and W_FF in these coordinates.
    pub sgd_coords: (f64, f64),
    pub ff_coords: (f64, f64),
}

fn sub(a: &[Tensor], b: &[Tensor]) -> Vec<Tensor> {
    a.iter().zip(b).map(|(x, y)| Tensor::sub_from(x, y)).collect()
}

fn dot(a: &[Tensor], b: &[Tensor]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x.dot(y)).sum()
}

fn scale(a: &mut [Tensor], s: f32) {
    a.iter_mut().for_each(|t| t.scale(s));
}

impl PlaneBasis {
    pub fn new(w0: &[Tensor], w_sgd: &[Tensor], w_ff: &[Tensor]) -> anyhow::Result<PlaneBasis> {
        let d_sgd = sub(w_sgd, w0);
        let d_ff = sub(w_ff, w0);
        let n_sgd = dot(&d_sgd, &d_sgd).sqrt();
        let unit = dot(&d_ff, &d_ff).sqrt();
        if n_sgd < 1e-12 || unit < 1e-12 {
            anyhow::bail!("degenerate plane: anchors coincide");
        }
        let mut e1 = d_sgd.clone();
        scale(&mut e1, (1.0 / n_sgd) as f32);
        // Gram–Schmidt
        let proj = dot(&d_ff, &e1);
        let mut e2 = d_ff.clone();
        for (t, b) in e2.iter_mut().zip(e1.iter()) {
            t.axpy(-proj as f32, b);
        }
        let n2 = dot(&e2, &e2).sqrt();
        if n2 < 1e-9 * unit {
            anyhow::bail!("W_FF − W0 is collinear with W_SGD − W0; plane undefined");
        }
        scale(&mut e2, (1.0 / n2) as f32);
        Ok(PlaneBasis {
            origin: w0.to_vec(),
            sgd_coords: (n_sgd / unit, 0.0),
            ff_coords: (proj / unit, n2 / unit),
            e1,
            e2,
            unit,
        })
    }

    /// Materialize the parameters at plane coordinates (α, β).
    pub fn point(&self, alpha: f64, beta: f64) -> Vec<Tensor> {
        let mut w = self.origin.clone();
        for ((t, b1), b2) in w.iter_mut().zip(self.e1.iter()).zip(self.e2.iter()) {
            t.axpy((alpha * self.unit) as f32, b1);
            t.axpy((beta * self.unit) as f32, b2);
        }
        w
    }
}

/// Scan an (α, β) grid, evaluating `eval` at each materialized point.
pub fn plane_grid(
    basis: &PlaneBasis,
    alphas: &[f64],
    betas: &[f64],
    mut eval: impl FnMut(&[Tensor]) -> anyhow::Result<f32>,
) -> anyhow::Result<Vec<PlanePoint>> {
    let mut out = Vec::with_capacity(alphas.len() * betas.len());
    for &b in betas {
        for &a in alphas {
            let w = basis.point(a, b);
            out.push(PlanePoint { alpha: a, beta: b, loss: eval(&w)? });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(v: &[f32]) -> Vec<Tensor> {
        vec![Tensor::from_vec(&[v.len()], v.to_vec())]
    }

    #[test]
    fn anchors_recovered_at_their_coordinates() {
        let w0 = t(&[0.0, 0.0, 0.0]);
        let ws = t(&[2.0, 0.0, 0.0]);
        let wf = t(&[1.0, 2.0, 0.0]);
        let basis = PlaneBasis::new(&w0, &ws, &wf).unwrap();
        let (a, b) = basis.sgd_coords;
        let got = basis.point(a, b);
        for (x, y) in got[0].data.iter().zip(ws[0].data.iter()) {
            assert!((x - y).abs() < 1e-5);
        }
        let (a, b) = basis.ff_coords;
        let got = basis.point(a, b);
        for (x, y) in got[0].data.iter().zip(wf[0].data.iter()) {
            assert!((x - y).abs() < 1e-5);
        }
        // unit is ‖W_FF − W0‖ = √5
        assert!((basis.unit - 5.0f64.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn degenerate_anchors_rejected() {
        let w0 = t(&[0.0, 0.0]);
        assert!(PlaneBasis::new(&w0, &w0, &t(&[1.0, 0.0])).is_err());
        // collinear
        assert!(PlaneBasis::new(&w0, &t(&[1.0, 0.0]), &t(&[2.0, 0.0])).is_err());
    }

    #[test]
    fn grid_scan_on_quadratic_bowl() {
        let w0 = t(&[0.0, 0.0]);
        let ws = t(&[1.0, 0.0]);
        let wf = t(&[0.0, 1.0]);
        let basis = PlaneBasis::new(&w0, &ws, &wf).unwrap();
        // loss = ‖w − (0.5, 0.5)‖²
        let pts = plane_grid(&basis, &[0.0, 0.5, 1.0], &[0.0, 0.5, 1.0], |w| {
            let loss: f32 =
                w[0].data.iter().map(|x| (x - 0.5) * (x - 0.5)).sum();
            Ok(loss)
        })
        .unwrap();
        assert_eq!(pts.len(), 9);
        let min = pts.iter().min_by(|a, b| a.loss.partial_cmp(&b.loss).unwrap()).unwrap();
        assert_eq!((min.alpha, min.beta), (0.5, 0.5));
    }
}
