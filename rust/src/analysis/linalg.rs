//! Dense linear algebra for analysis probes: one-sided Jacobi SVD (enough
//! for the small LoRA gradient matrices, d×r with r ≤ 64) and the
//! condition-number measurement of paper Fig 12b.

use crate::model::tensor::Tensor;

/// Singular values of a [rows, cols] matrix via one-sided Jacobi on AᵀA
/// column rotations. Returns values sorted descending. O(rows·cols²·sweeps);
/// intended for cols ≤ ~128.
pub fn singular_values(t: &Tensor) -> Vec<f64> {
    assert_eq!(t.shape.len(), 2, "singular_values expects a matrix");
    let (rows, cols) = (t.shape[0], t.shape[1]);
    // Work on the thinner orientation: Jacobi cost scales with cols².
    if cols > rows {
        let mut tt = Tensor::zeros(&[cols, rows]);
        for r in 0..rows {
            for c in 0..cols {
                tt.data[c * rows + r] = t.data[r * cols + c];
            }
        }
        return singular_values(&tt);
    }
    // columns as f64 vectors
    let mut a: Vec<Vec<f64>> = (0..cols)
        .map(|c| (0..rows).map(|r| t.data[r * cols + c] as f64).collect())
        .collect();

    let dot = |x: &[f64], y: &[f64]| -> f64 { x.iter().zip(y).map(|(a, b)| a * b).sum() };
    let eps = 1e-12;
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..cols {
            for q in (p + 1)..cols {
                let (app, aqq) = (dot(&a[p], &a[p]), dot(&a[q], &a[q]));
                let apq = {
                    let (cp, cq) = (&a[p], &a[q]);
                    dot(cp, cq)
                };
                off += apq.abs();
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                let zeta = (aqq - app) / (2.0 * apq);
                let t_rot = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t_rot * t_rot).sqrt();
                let s = c * t_rot;
                for r in 0..rows {
                    let (vp, vq) = (a[p][r], a[q][r]);
                    a[p][r] = c * vp - s * vq;
                    a[q][r] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }
    let mut sv: Vec<f64> = a.iter().map(|col| dot(col, col).sqrt()).collect();
    sv.sort_by(|x, y| y.partial_cmp(x).unwrap());
    sv
}

/// σ_max / σ_min over the numerically non-zero spectrum (Fig 12b's
/// "condition number of the gradients").
pub fn condition_number(t: &Tensor) -> f64 {
    let sv = singular_values(t);
    let smax = sv.first().copied().unwrap_or(0.0);
    if smax <= 0.0 {
        return f64::INFINITY;
    }
    let floor = smax * 1e-9;
    let smin = sv.iter().rev().find(|&&s| s > floor).copied().unwrap_or(smax);
    smax / smin
}

/// Mean condition number over all ≥2-D tensors (grad lists mix matrices
/// with DoRA magnitude vectors; vectors are skipped).
pub fn mean_condition_number(grads: &[Tensor]) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for g in grads {
        if g.shape.len() == 2 && g.shape[0] > 1 && g.shape[1] > 1 {
            let c = condition_number(g);
            if c.is_finite() {
                sum += c;
                n += 1;
            }
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix_svs_are_abs_diagonal() {
        let t = Tensor::from_vec(&[3, 3], vec![3.0, 0.0, 0.0, 0.0, -5.0, 0.0, 0.0, 0.0, 1.0]);
        let sv = singular_values(&t);
        assert!((sv[0] - 5.0).abs() < 1e-9, "{sv:?}");
        assert!((sv[1] - 3.0).abs() < 1e-9);
        assert!((sv[2] - 1.0).abs() < 1e-9);
        assert!((condition_number(&t) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn rectangular_and_transpose_agree() {
        let mut rng = crate::util::rng::Rng::new(4);
        let data: Vec<f32> = (0..6 * 3).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let a = Tensor::from_vec(&[6, 3], data.clone());
        let mut tr = Tensor::zeros(&[3, 6]);
        for r in 0..6 {
            for c in 0..3 {
                tr.data[c * 6 + r] = data[r * 3 + c];
            }
        }
        let sa = singular_values(&a);
        let st = singular_values(&tr);
        for (x, y) in sa.iter().zip(st.iter()) {
            assert!((x - y).abs() < 1e-8, "{sa:?} vs {st:?}");
        }
    }

    #[test]
    fn frobenius_norm_preserved() {
        // Σ σ² must equal ‖A‖_F² (orthogonal invariance sanity).
        crate::util::prop::check(15, |g| {
            let rows = g.usize_in(2, 12);
            let cols = g.usize_in(2, 8);
            let t = Tensor::from_vec(&[rows, cols], g.vec_f32(rows * cols, 1.0));
            let fro2: f64 = t.data.iter().map(|v| (*v as f64).powi(2)).sum();
            let sv2: f64 = singular_values(&t).iter().map(|s| s * s).sum();
            if (fro2 - sv2).abs() > 1e-6 * fro2.max(1.0) {
                return Err(format!("fro²={fro2} vs Σσ²={sv2}"));
            }
            Ok(())
        });
    }

    #[test]
    fn rank_one_condition_over_nonzero_spectrum_is_one() {
        // outer product u vᵀ → rank 1; σ₂ ≈ 0 falls below the floor, so the
        // condition number is taken over the non-negligible spectrum: 1.
        let u = [1.0f32, 2.0, 3.0];
        let v = [1.0f32, -1.0];
        let mut t = Tensor::zeros(&[3, 2]);
        for r in 0..3 {
            for c in 0..2 {
                t.data[r * 2 + c] = u[r] * v[c];
            }
        }
        assert!((condition_number(&t) - 1.0).abs() < 1e-6);
        // a genuinely ill-conditioned (but full-rank) matrix is large:
        let ill = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1e-6]);
        assert!(condition_number(&ill) > 1e5);
    }

    #[test]
    fn mean_condition_skips_vectors() {
        let m = Tensor::from_vec(&[2, 2], vec![2.0, 0.0, 0.0, 1.0]);
        let vec1 = Tensor::ones(&[5]);
        let got = mean_condition_number(&[m, vec1]);
        assert!((got - 2.0).abs() < 1e-9);
    }
}
