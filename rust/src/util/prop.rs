//! Seeded property-test driver (proptest replacement).
//!
//! `check(cases, |g| { ... })` runs the closure against `cases` generated
//! inputs; on failure it reports the failing case's seed so the case can be
//! replayed exactly with `replay(seed, |g| ...)`. No shrinking — cases are
//! kept small by construction instead.

use crate::util::rng::Rng;

/// Generator handle passed to property bodies.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    pub fn vec_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal_f32(0.0, std)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `body` against `cases` seeded inputs; panics with the failing seed.
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(cases: usize, mut body: F) {
    let base = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xfa57f0_u64 ^ 0x5eed);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut g = Gen { rng: Rng::new(seed), seed };
        if let Err(msg) = body(&mut g) {
            panic!("property failed (replay with PROP_SEED={base}, case {i}, seed {seed}): {msg}");
        }
    }
}

/// Replay a single failing case by its seed.
pub fn replay<F: FnMut(&mut Gen) -> Result<(), String>>(seed: u64, mut body: F) {
    let mut g = Gen { rng: Rng::new(seed), seed };
    if let Err(msg) = body(&mut g) {
        panic!("replayed property failed (seed {seed}): {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(50, |g| {
            let n = g.usize_in(1, 10);
            let v = g.vec_f32(n, 1.0);
            if v.len() == n { Ok(()) } else { Err("len".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure_with_seed() {
        check(10, |g| {
            if g.usize_in(0, 100) <= 100 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_in_range() {
        check(100, |g| {
            let x = g.usize_in(3, 7);
            if !(3..=7).contains(&x) {
                return Err(format!("usize_in out of range: {x}"));
            }
            let f = g.f32_in(-1.0, 1.0);
            if !(-1.0..=1.0).contains(&f) {
                return Err(format!("f32_in out of range: {f}"));
            }
            Ok(())
        });
    }
}
