//! Callback-style streaming JSON reader (no value tree, no allocation per
//! token beyond the context stack).
//!
//! The shard-report merge (`sched::shard`) folds many per-shard
//! `reports/*.json` files into one canonical report by splicing verbatim
//! byte spans — deserializing every file into an owned [`Json`]
//! (`crate::util::json::Json`) tree would allocate the world and, worse,
//! re-serialization could perturb bytes. This reader lexes the source in
//! one pass and hands each token to a visitor with its byte offset, so a
//! caller can track nesting depth and recover exact element spans
//! (`&src[start..end]`) without owning anything.
//!
//! Scope: full JSON grammar plus `//` and `/* */` comments (the
//! json-iterator-reader idiom this follows supports them; our own reports
//! never emit any). String tokens are raw spans — escapes are *validated*
//! but not decoded; callers that need decoded text can hand the span to
//! `Json::parse`.

/// One lexical event. Borrowed spans point into the scanned source.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event<'a> {
    ObjectStart,
    ObjectEnd,
    ArrayStart,
    ArrayEnd,
    /// An object key (raw contents between the quotes, escapes undecoded).
    Key(&'a str),
    /// A string value (raw contents between the quotes).
    Str(&'a str),
    /// A number value, as written.
    Num(&'a str),
    Bool(bool),
    Null,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReadError {
    pub msg: &'static str,
    pub offset: usize,
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json read error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ReadError {}

/// Scan `src`, invoking `on(offset, event)` for every token. `offset` is
/// the byte position of the token's first character; for `ObjectEnd` /
/// `ArrayEnd` it is the closing bracket itself, so a container spanning
/// `[start, end)` yields `ObjectStart` at `start` and `ObjectEnd` at
/// `end - 1`.
pub fn scan<'a>(
    src: &'a str,
    on: &mut dyn FnMut(usize, Event<'a>),
) -> Result<(), ReadError> {
    let b = src.as_bytes();
    let mut i = 0usize;
    // Context stack: b'{' (expect key), b':' (expect value in object),
    // b'[' (expect value in array). Values at top level use an empty stack.
    let mut stack: Vec<u8> = Vec::new();
    let mut value_seen = false; // a complete top-level value was consumed
    let err = |msg: &'static str, offset: usize| ReadError { msg, offset };

    while i < b.len() {
        match b[i] {
            b' ' | b'\t' | b'\n' | b'\r' => i += 1,
            b'/' => {
                // Comment: `//` to end of line or `/* ... */`.
                match b.get(i + 1) {
                    Some(b'/') => {
                        while i < b.len() && b[i] != b'\n' {
                            i += 1;
                        }
                    }
                    Some(b'*') => {
                        let start = i;
                        i += 2;
                        while i + 1 < b.len() && !(b[i] == b'*' && b[i + 1] == b'/') {
                            i += 1;
                        }
                        if i + 1 >= b.len() {
                            return Err(err("unterminated comment", start));
                        }
                        i += 2;
                    }
                    _ => return Err(err("unexpected character", i)),
                }
            }
            b',' => {
                match stack.last() {
                    Some(b'{') | Some(b'[') => i += 1,
                    _ => return Err(err("unexpected ','", i)),
                }
            }
            b':' => match stack.last() {
                Some(b':') => i += 1,
                _ => return Err(err("unexpected ':'", i)),
            },
            b'}' => {
                if stack.pop() != Some(b'{') {
                    return Err(err("unbalanced '}'", i));
                }
                on(i, Event::ObjectEnd);
                close_value(&mut stack, &mut value_seen);
                i += 1;
            }
            b']' => {
                if stack.pop() != Some(b'[') {
                    return Err(err("unbalanced ']'", i));
                }
                on(i, Event::ArrayEnd);
                close_value(&mut stack, &mut value_seen);
                i += 1;
            }
            b'"' if stack.last() == Some(&b'{') => {
                let (span, next) = string_span(src, i)?;
                on(i, Event::Key(span));
                // Swap the frame: the next value belongs to this key.
                *stack.last_mut().unwrap() = b':';
                i = next;
            }
            c => {
                // A value position.
                if value_seen && stack.is_empty() {
                    return Err(err("trailing characters", i));
                }
                if stack.last() == Some(&b'{') {
                    return Err(err("expected object key", i));
                }
                let start = i;
                match c {
                    b'{' => {
                        on(start, Event::ObjectStart);
                        stack.push(b'{');
                        i += 1;
                        continue;
                    }
                    b'[' => {
                        on(start, Event::ArrayStart);
                        stack.push(b'[');
                        i += 1;
                        continue;
                    }
                    b'"' => {
                        let (span, next) = string_span(src, i)?;
                        on(start, Event::Str(span));
                        i = next;
                    }
                    b't' if src[i..].starts_with("true") => {
                        on(start, Event::Bool(true));
                        i += 4;
                    }
                    b'f' if src[i..].starts_with("false") => {
                        on(start, Event::Bool(false));
                        i += 5;
                    }
                    b'n' if src[i..].starts_with("null") => {
                        on(start, Event::Null);
                        i += 4;
                    }
                    b'-' | b'0'..=b'9' => {
                        let mut j = i + 1;
                        while j < b.len()
                            && matches!(b[j], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
                        {
                            j += 1;
                        }
                        on(start, Event::Num(&src[i..j]));
                        i = j;
                    }
                    _ => return Err(err("unexpected character", i)),
                }
                close_value(&mut stack, &mut value_seen);
            }
        }
    }
    if !stack.is_empty() {
        return Err(err("unexpected end of input", b.len()));
    }
    if !value_seen {
        return Err(err("empty input", 0));
    }
    Ok(())
}

/// A value just finished: pop a pending `key:` frame back to its object,
/// and mark completion at top level.
fn close_value(stack: &mut Vec<u8>, value_seen: &mut bool) {
    if stack.last() == Some(&b':') {
        *stack.last_mut().unwrap() = b'{';
    } else if stack.is_empty() {
        *value_seen = true;
    }
}

/// Scan a string starting at the opening quote `at`; returns the raw inner
/// span (escapes validated, not decoded) and the offset just past the
/// closing quote.
fn string_span(src: &str, at: usize) -> Result<(&str, usize), ReadError> {
    let b = src.as_bytes();
    let mut i = at + 1;
    while i < b.len() {
        match b[i] {
            b'"' => return Ok((&src[at + 1..i], i + 1)),
            b'\\' => {
                if i + 1 >= b.len() {
                    break;
                }
                i += 2;
            }
            _ => i += 1,
        }
    }
    Err(ReadError { msg: "unterminated string", offset: at })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn events(src: &str) -> Vec<(usize, String)> {
        let mut out = Vec::new();
        scan(src, &mut |off, ev| out.push((off, format!("{ev:?}")))).unwrap();
        out
    }

    #[test]
    fn lexes_nested_document() {
        let src = r#"{"a": [1, {"b": "x"}], "c": true, "d": null}"#;
        let got: Vec<String> = events(src).into_iter().map(|(_, e)| e).collect();
        assert_eq!(
            got,
            vec![
                "ObjectStart",
                "Key(\"a\")",
                "ArrayStart",
                "Num(\"1\")",
                "ObjectStart",
                "Key(\"b\")",
                "Str(\"x\")",
                "ObjectEnd",
                "ArrayEnd",
                "Key(\"c\")",
                "Bool(true)",
                "Key(\"d\")",
                "Null",
                "ObjectEnd",
            ]
        );
    }

    #[test]
    fn offsets_recover_exact_spans() {
        let src = r#"{"cells": [{"index":0,"x":"a}]"}, {"index":1}]}"#;
        let mut depth = 0usize;
        let mut start = None;
        let mut spans = Vec::new();
        scan(src, &mut |off, ev| match ev {
            Event::ObjectStart => {
                depth += 1;
                if depth == 2 {
                    start = Some(off);
                }
            }
            Event::ObjectEnd => {
                if depth == 2 {
                    spans.push(&src[start.unwrap()..off + 1]);
                }
                depth -= 1;
            }
            _ => {}
        })
        .unwrap();
        assert_eq!(spans, vec![r#"{"index":0,"x":"a}]"}"#, r#"{"index":1}"#]);
    }

    #[test]
    fn brackets_inside_strings_do_not_confuse_nesting() {
        // Also: escaped quotes inside values.
        let src = r#"{"k": "}]\"[{", "n": -1.5e-3}"#;
        let got = events(src);
        assert_eq!(got.last().unwrap().1, "ObjectEnd");
        assert!(got.iter().any(|(_, e)| e == "Num(\"-1.5e-3\")"));
    }

    #[test]
    fn skips_comments() {
        let src = "// header\n{\"a\": /* inline */ 1}\n// trailer";
        let got: Vec<String> = events(src).into_iter().map(|(_, e)| e).collect();
        assert_eq!(got, vec!["ObjectStart", "Key(\"a\")", "Num(\"1\")", "ObjectEnd"]);
    }

    #[test]
    fn rejects_malformed_input() {
        for (src, msg) in [
            ("{", "unexpected end of input"),
            ("[1, 2", "unexpected end of input"),
            ("}", "unbalanced '}'"),
            (r#"{"a": 1} extra"#, "trailing characters"),
            (r#""unterminated"#, "unterminated string"),
            ("{1: 2}", "expected object key"),
            ("/* open", "unterminated comment"),
            ("", "empty input"),
        ] {
            let e = scan(src, &mut |_, _| {}).unwrap_err();
            assert_eq!(e.msg, msg, "input {src:?}");
        }
    }

    #[test]
    fn parked_run_rows_round_trip_with_null_loss() {
        // A parked run's summary carries final_test_loss = NaN; the shard
        // row writer must emit `null` (Json::num_or_null), never a bare
        // `NaN` token — which this reader (and Json::parse) rejects.
        use crate::util::json::Json;
        let row = Json::obj()
            .set("adam_steps", 12usize)
            .set("final_loss", Json::num_or_null(f64::NAN))
            .to_string();
        assert_eq!(row, r#"{"adam_steps":12,"final_loss":null}"#);
        let mut saw_null = false;
        scan(&row, &mut |_, ev| saw_null |= ev == Event::Null).unwrap();
        assert!(saw_null, "the NaN loss must surface as a Null token");
        assert_eq!(Json::parse(&row).unwrap().to_string(), row);
        // The pre-fix emission is invalid to both parsers.
        let bad = format!("{{\"final_loss\":{}}}", f64::NAN);
        assert_eq!(scan(&bad, &mut |_, _| {}).unwrap_err().msg, "unexpected character");
        assert!(Json::parse(&bad).is_err());
    }

    #[test]
    fn agrees_with_the_tree_parser_on_real_rows() {
        // A row exactly as the shard report writer emits it (compact,
        // sorted keys): the reader must tokenize it and the offsets must
        // reconstruct the original bytes.
        let row = r#"{"adam_steps":12,"final_loss":2.125,"index":3,"label":"ff-tiny/medical"}"#;
        let mut rebuilt = Vec::new();
        scan(row, &mut |off, ev| rebuilt.push((off, ev))).unwrap();
        assert_eq!(rebuilt.first().unwrap().0, 0);
        assert_eq!(rebuilt.last().unwrap().0, row.len() - 1);
        assert!(crate::util::json::Json::parse(row).is_ok());
    }
}
