//! Measurement harness (criterion replacement): warmup + timed iterations,
//! reporting mean / p50 / p95 / min. Used by the `rust/benches/*` targets
//! (compiled with `harness = false`) and the §Perf profiling pass.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} iters={:<5} mean={:>10.3?} p50={:>10.3?} p95={:>10.3?} min={:>10.3?}",
            self.name, self.iters, self.mean, self.p50, self.p95, self.min
        )
    }

    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// JSON form for the machine-readable bench outputs
    /// (`BENCH_step.json` / `BENCH_runtime.json`) — durations in seconds,
    /// so cross-PR diffs don't have to parse `Duration` debug strings.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_secs", self.mean.as_secs_f64())
            .set("p50_secs", self.p50.as_secs_f64())
            .set("p95_secs", self.p95.as_secs_f64())
            .set("min_secs", self.min.as_secs_f64())
            .set("max_secs", self.max.as_secs_f64())
    }
}

/// Run `f` repeatedly: `warmup` untimed passes, then timed passes until both
/// `min_iters` iterations and `min_time` wall time have elapsed.
pub fn bench<F: FnMut()>(
    name: &str,
    warmup: usize,
    min_iters: usize,
    min_time: Duration,
    mut f: F,
) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(min_iters.max(8));
    let start = Instant::now();
    while samples.len() < min_iters || start.elapsed() < min_time {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 100_000 {
            break; // pathological fast function; enough samples
        }
    }
    stats_from(name, samples)
}

/// Quick preset: 2 warmups, ≥10 iters, ≥300 ms.
pub fn bench_quick<F: FnMut()>(name: &str, f: F) -> BenchStats {
    bench(name, 2, 10, Duration::from_millis(300), f)
}

fn stats_from(name: &str, mut samples: Vec<Duration>) -> BenchStats {
    assert!(!samples.is_empty());
    samples.sort();
    let total: Duration = samples.iter().sum();
    let n = samples.len();
    let pct = |p: f64| samples[((n as f64 - 1.0) * p).round() as usize];
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean: total / n as u32,
        p50: pct(0.50),
        p95: pct(0.95),
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Throughput helper: items/sec given a per-iteration item count.
pub fn throughput(stats: &BenchStats, items_per_iter: f64) -> f64 {
    items_per_iter / stats.mean_secs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_at_least_min_iters() {
        let s = bench("noop", 1, 25, Duration::from_millis(1), || {});
        assert!(s.iters >= 25);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
    }

    #[test]
    fn measures_sleep_roughly() {
        let s = bench("sleep", 0, 3, Duration::from_millis(1), || {
            std::thread::sleep(Duration::from_millis(5));
        });
        assert!(s.mean >= Duration::from_millis(4), "{:?}", s.mean);
        assert!(s.mean < Duration::from_millis(80), "{:?}", s.mean);
    }

    #[test]
    fn to_json_reports_seconds() {
        let s = BenchStats {
            name: "x".into(),
            iters: 4,
            mean: Duration::from_millis(250),
            p50: Duration::from_millis(240),
            p95: Duration::from_millis(300),
            min: Duration::from_millis(200),
            max: Duration::from_millis(310),
        };
        let j = s.to_json();
        assert_eq!(j.get("name").as_str(), Some("x"));
        assert_eq!(j.get("iters").as_usize(), Some(4));
        assert!((j.get("mean_secs").as_f64().unwrap() - 0.25).abs() < 1e-12);
        assert!((j.get("p95_secs").as_f64().unwrap() - 0.30).abs() < 1e-12);
    }

    #[test]
    fn throughput_math() {
        let s = BenchStats {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(100),
            p50: Duration::from_millis(100),
            p95: Duration::from_millis(100),
            min: Duration::from_millis(100),
            max: Duration::from_millis(100),
        };
        assert!((throughput(&s, 50.0) - 500.0).abs() < 1e-9);
    }
}
