//! Tiny CLI argument parser (clap replacement for this offline environment).
//!
//! Grammar: `binary <subcommand> [positional...] [--flag] [--key value]`.
//! `--key=value` is also accepted. Unknown flags are an error so typos
//! surface instead of silently running a default experiment.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    known: Vec<String>,
}

impl Args {
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(stripped.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.known.push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&mut self, name: &str) -> Option<String> {
        self.known.push(name.to_string());
        self.opts.get(name).cloned()
    }

    pub fn opt_or(&mut self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or_else(|| default.to_string())
    }

    pub fn opt_usize(&mut self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn opt_f64(&mut self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn opt_u64(&mut self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Call after all `flag`/`opt` lookups: rejects anything unrecognized.
    pub fn finish(&self) -> Result<(), String> {
        for k in self.opts.keys() {
            if !self.known.contains(k) {
                return Err(format!("unknown option --{k}"));
            }
        }
        for f in &self.flags {
            if !self.known.contains(f) {
                return Err(format!("unknown flag --{f}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("experiment fig2a extra");
        assert_eq!(a.subcommand.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["fig2a", "extra"]);
    }

    #[test]
    fn options_both_styles() {
        let mut a = parse("train --model ff-tiny --steps=100 --verbose");
        assert_eq!(a.opt("model").as_deref(), Some("ff-tiny"));
        assert_eq!(a.opt_usize("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = parse("train --oops 1");
        let _ = a.opt("model");
        assert!(a.finish().is_err());
    }

    #[test]
    fn numeric_parse_errors() {
        let mut a = parse("x --steps abc");
        assert!(a.opt_usize("steps", 0).is_err());
        let mut b = parse("x --lr 4e-5");
        assert_eq!(b.opt_f64("lr", 0.0).unwrap(), 4e-5);
    }

    #[test]
    fn trailing_flag_not_eating_next_flag() {
        let mut a = parse("x --fast --model ff-tiny");
        assert!(a.flag("fast"));
        assert_eq!(a.opt("model").as_deref(), Some("ff-tiny"));
    }
}
