//! Minimal JSON parser + serializer.
//!
//! This environment has no crates.io network access (only the `xla` crate's
//! dependency closure is vendored), so the manifest/config/report JSON
//! interchange with the python compile path is handled by this in-repo
//! implementation. It supports the full JSON grammar (RFC 8259) minus
//! `\u` surrogate-pair edge cases beyond the BMP, which the manifests never
//! emit; numbers are parsed as f64 (integers round-trip exactly up to 2^53).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (handy for golden-file tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -----------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders ------------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// `Json::Num` for a finite value, `Json::Null` otherwise. The
    /// serializer formats `Num` with `{}`, so a NaN or ±inf smuggled into
    /// a report prints the invalid tokens `NaN`/`inf` that no JSON parser
    /// (including [`Json::parse`]) accepts. Parked runs carry
    /// `final_test_loss = NaN` (`RunSummary` docs) — every emitter of a
    /// possibly-non-finite metric must route it through here.
    pub fn num_or_null(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(v)
        } else {
            Json::Null
        }
    }

    pub fn set(mut self, key: &str, v: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut o) = self {
            o.insert(key.to_string(), v.into());
        }
        self
    }

    // -- serialization ---------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            out.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").idx(2).get("b").as_str(), Some("c"));
        assert!(v.get("d").is_null());
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn round_trips() {
        let src = r#"{"config":{"d_model":64,"lr":4e-05,"name":"ff-tiny"},"ok":true,"shape":[8,64]}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes_round_trip() {
        let v = Json::Str("héllo \"wörld\"\n\t√".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(r#""Aé""#).unwrap(),
                   Json::Str("Aé".into()));
    }

    #[test]
    fn integers_round_trip_exactly() {
        let v = Json::parse("123456789012").unwrap();
        assert_eq!(v.to_string(), "123456789012");
        assert_eq!(v.as_i64(), Some(123456789012));
    }

    #[test]
    fn non_finite_nums_serialize_as_null_not_nan_tokens() {
        assert_eq!(Json::num_or_null(f64::NAN), Json::Null);
        assert_eq!(Json::num_or_null(f64::INFINITY), Json::Null);
        assert_eq!(Json::num_or_null(f64::NEG_INFINITY), Json::Null);
        assert_eq!(Json::num_or_null(2.125), Json::Num(2.125));
        // The guard exists because a raw Num(NaN) emits the invalid
        // token `NaN` that parse() itself rejects.
        assert!(Json::parse(&Json::Num(f64::NAN).to_string()).is_err());
        let row = Json::obj().set("final_loss", Json::num_or_null(f64::NAN));
        assert_eq!(row.to_string(), r#"{"final_loss":null}"#);
        assert_eq!(Json::parse(&row.to_string()).unwrap(), row);
    }

    #[test]
    fn builder_and_pretty() {
        let v = Json::obj().set("b", 2i64).set("a", "x");
        assert_eq!(v.to_string(), r#"{"a":"x","b":2}"#);
        assert!(v.to_string_pretty().contains('\n'));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"trainable":[{"name":"layer0.attn.wq.lora_a","shape":[64,8]}]}"#;
        let v = Json::parse(src).unwrap();
        let p = v.get("trainable").idx(0);
        assert_eq!(p.get("name").as_str().unwrap(), "layer0.attn.wq.lora_a");
        let shape: Vec<usize> =
            p.get("shape").as_arr().unwrap().iter().map(|s| s.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![64, 8]);
    }
}
