//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core.
//!
//! Replaces the `rand` crate (unavailable offline). Every stochastic
//! component in the library (parameter init, corpus generation, batch
//! sampling) takes an explicit seed so runs are exactly reproducible —
//! a requirement for the paper's baseline-vs-FF comparisons, which must
//! see identical data order.

/// xoshiro256++ with SplitMix64 seeding (Blackman & Vigna).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Derive an independent stream, e.g. per tensor name or per worker id.
    pub fn fork(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        Rng::new(self.s[0] ^ h.rotate_left(17))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Sample from an unnormalized discrete distribution.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn fork_gives_independent_streams() {
        let root = Rng::new(1);
        let mut a = root.fork("embed.tok");
        let mut b = root.fork("embed.pos");
        assert_ne!(a.next_u64(), b.next_u64());
        // fork is deterministic
        assert_eq!(root.fork("x").next_u64(), root.fork("x").next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(5);
        let mut hit = [0usize; 3];
        for _ in 0..30_000 {
            hit[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hit[2] > hit[1] && hit[1] > hit[0]);
        assert!((hit[2] as f64 / 30_000.0 - 0.7).abs() < 0.03);
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
