//! In-repo substrates replacing unavailable crates (see DESIGN.md
//! §Substrates): JSON codec, streaming JSON reader, CLI args, PRNG, bench
//! harness, property-test driver, and a leveled logger.

pub mod args;
pub mod bench;
pub mod json;
pub mod json_reader;
pub mod logging;
pub mod prop;
pub mod rng;
