//! Model-side substrate: host tensors, the parameter spec (mirroring
//! `python/compile/configs.param_spec`), and host-side initialization.

pub mod init;
pub mod spec;
pub mod tensor;

pub use spec::{param_spec, ParamInfo};
pub use tensor::Tensor;
