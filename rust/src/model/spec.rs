//! Canonical parameter spec — the rust mirror of
//! `python/compile/configs.param_spec`. The runtime cross-checks this
//! derivation against every artifact's manifest at load time; if the two
//! languages ever disagree on a name, shape, or ordering, loading fails
//! before any step executes.

use crate::config::{ArtifactConfig, TrainMode};

pub const ADAPTED_MATRICES: [&str; 4] = ["wq", "wk", "wv", "wo"];

#[derive(Debug, Clone, PartialEq)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub trainable: bool,
}

impl ParamInfo {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Ordered parameter list: embeddings, per-layer (ln1, attn + adapters,
/// ln2, mlp), final LN, unembedding — adapters directly after their matrix.
pub fn param_spec(ac: &ArtifactConfig) -> Vec<ParamInfo> {
    let m = &ac.model;
    let (d, v, t, r) = (m.d_model, m.vocab_size, m.seq_len, ac.lora_rank);
    let full_all = ac.train_mode == TrainMode::FullAll;
    let low_rank = ac.train_mode.is_low_rank();
    let mut out = Vec::new();
    let mut p = |name: String, shape: Vec<usize>, trainable: bool| {
        out.push(ParamInfo { name, shape, trainable: trainable || full_all });
    };

    p("embed.tok".into(), vec![v, d], false);
    p("embed.pos".into(), vec![t, d], false);
    for i in 0..m.n_layers {
        p(format!("layer{i}.ln1.scale"), vec![d], false);
        p(format!("layer{i}.ln1.bias"), vec![d], false);
        for w in ADAPTED_MATRICES {
            p(
                format!("layer{i}.attn.{w}"),
                vec![d, d],
                ac.train_mode == TrainMode::FullAttn,
            );
            if low_rank {
                p(format!("layer{i}.attn.{w}.lora_a"), vec![d, r], true);
                p(format!("layer{i}.attn.{w}.lora_b"), vec![r, d], true);
            }
            if ac.train_mode == TrainMode::Dora {
                p(format!("layer{i}.attn.{w}.dora_m"), vec![d], true);
            }
        }
        p(format!("layer{i}.ln2.scale"), vec![d], false);
        p(format!("layer{i}.ln2.bias"), vec![d], false);
        p(format!("layer{i}.mlp.w_in"), vec![d, m.d_ff()], false);
        p(format!("layer{i}.mlp.w_out"), vec![m.d_ff(), d], false);
    }
    p("final_ln.scale".into(), vec![d], false);
    p("final_ln.bias".into(), vec![d], false);
    p("unembed".into(), vec![d, v], false);
    out
}

pub fn trainable_spec(ac: &ArtifactConfig) -> Vec<ParamInfo> {
    param_spec(ac).into_iter().filter(|p| p.trainable).collect()
}

pub fn frozen_spec(ac: &ArtifactConfig) -> Vec<ParamInfo> {
    param_spec(ac).into_iter().filter(|p| !p.trainable).collect()
}

pub fn n_trainable(ac: &ArtifactConfig) -> usize {
    trainable_spec(ac).iter().map(|p| p.numel()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn ac(mode: TrainMode, rank: usize) -> ArtifactConfig {
        ArtifactConfig {
            model: presets::model("ff-tiny").unwrap(),
            train_mode: mode,
            lora_rank: rank,
            lora_alpha: 16.0,
            use_pallas: false,
        }
    }

    #[test]
    fn lora_trainable_count_matches_python_index() {
        // golden values from artifacts/index.json
        assert_eq!(n_trainable(&ac(TrainMode::Lora, 8)), 8192);
        assert_eq!(n_trainable(&ac(TrainMode::Lora, 1)), 1024);
        assert_eq!(n_trainable(&ac(TrainMode::Dora, 8)), 8704);
        assert_eq!(n_trainable(&ac(TrainMode::FullAttn, 8)), 32768);
        assert_eq!(n_trainable(&ac(TrainMode::FullAll, 8)), 168_576);
    }

    #[test]
    fn names_unique_and_partition_ordered() {
        let spec = param_spec(&ac(TrainMode::Dora, 4));
        let names: Vec<&String> = spec.iter().map(|p| &p.name).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
        // total numel equals model n_params + adapter params
        let total: usize = spec.iter().map(|p| p.numel()).sum();
        let m = presets::model("ff-tiny").unwrap();
        let adapters = m.n_layers * 4 * (2 * m.d_model * 4 + m.d_model);
        assert_eq!(total, m.n_params() + adapters);
    }

    #[test]
    fn full_all_has_no_frozen() {
        assert!(frozen_spec(&ac(TrainMode::FullAll, 8)).is_empty());
    }

    #[test]
    fn adapter_order_is_a_then_b_then_m() {
        let spec = param_spec(&ac(TrainMode::Dora, 8));
        let idx = |n: &str| spec.iter().position(|p| p.name == n).unwrap();
        let base = idx("layer0.attn.wq");
        assert_eq!(idx("layer0.attn.wq.lora_a"), base + 1);
        assert_eq!(idx("layer0.attn.wq.lora_b"), base + 2);
        assert_eq!(idx("layer0.attn.wq.dora_m"), base + 3);
    }
}
