//! Host-side dense f32 tensor: the unit of parameter state the coordinator
//! manipulates (Δ_W arithmetic, gradient accumulation, checkpoints).
//!
//! Deliberately minimal — all heavy compute runs inside the AOT-compiled
//! XLA programs; the host only needs elementwise ops over flat buffers.

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// self += alpha * other (the Δ_W application `W_t + τΔ_W` runs through
    /// this; it is the FF hot path on the host side).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// self = a - b (builds Δ_W = W_t − W_{t−1}).
    pub fn sub_from(a: &Tensor, b: &Tensor) -> Tensor {
        debug_assert_eq!(a.shape, b.shape);
        Tensor {
            shape: a.shape.clone(),
            data: a.data.iter().zip(b.data.iter()).map(|(x, y)| x - y).collect(),
        }
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    pub fn dot(&self, other: &Tensor) -> f64 {
        debug_assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| *a as f64 * *b as f64)
            .sum()
    }

    pub fn norm(&self) -> f64 {
        self.dot(self).sqrt()
    }

    /// Column L2 norms of a [rows, cols] matrix (DoRA magnitude init).
    pub fn col_norms(&self) -> Vec<f32> {
        assert_eq!(self.shape.len(), 2);
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f64; cols];
        for r in 0..rows {
            let row = &self.data[r * cols..(r + 1) * cols];
            for (o, v) in out.iter_mut().zip(row.iter()) {
                *o += (*v as f64) * (*v as f64);
            }
        }
        out.into_iter().map(|v| v.sqrt() as f32).collect()
    }
}

/// Cosine similarity between two same-shape tensor lists viewed as one
/// flattened vector (paper Fig 6 / Fig 13 measurements).
pub fn cosine_similarity(a: &[Tensor], b: &[Tensor]) -> f64 {
    let mut dot = 0.0;
    let mut na = 0.0;
    let mut nb = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        dot += x.dot(y);
        na += x.dot(x);
        nb += y.dot(y);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Flattened L2 norm over a tensor list (gradient-norm probe, Fig 12a).
pub fn list_norm(a: &[Tensor]) -> f64 {
    a.iter().map(|t| t.dot(t)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_sub() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![0.5, 0.5, 0.5, 0.5]);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        assert_eq!(c.data, vec![2.0, 3.0, 4.0, 5.0]);
        let d = Tensor::sub_from(&c, &a);
        assert_eq!(d.data, vec![1.0; 4]);
    }

    #[test]
    fn col_norms_matrix() {
        // [[3, 0], [4, 0]] → col norms [5, 0]
        let t = Tensor::from_vec(&[2, 2], vec![3.0, 0.0, 4.0, 0.0]);
        assert_eq!(t.col_norms(), vec![5.0, 0.0]);
    }

    #[test]
    fn cosine_of_parallel_and_orthogonal() {
        let a = vec![Tensor::from_vec(&[2], vec![1.0, 0.0])];
        let b = vec![Tensor::from_vec(&[2], vec![2.0, 0.0])];
        let c = vec![Tensor::from_vec(&[2], vec![0.0, 1.0])];
        assert!((cosine_similarity(&a, &b) - 1.0).abs() < 1e-12);
        assert!(cosine_similarity(&a, &c).abs() < 1e-12);
        assert!((cosine_similarity(&a, &vec![Tensor::from_vec(&[2], vec![-1.0, 0.0])]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_vector_cosine_is_zero() {
        let a = vec![Tensor::zeros(&[3])];
        let b = vec![Tensor::ones(&[3])];
        assert_eq!(cosine_similarity(&a, &b), 0.0);
    }

    #[test]
    fn list_norm_pythagoras() {
        let a = vec![
            Tensor::from_vec(&[1], vec![3.0]),
            Tensor::from_vec(&[1], vec![4.0]),
        ];
        assert!((list_norm(&a) - 5.0).abs() < 1e-12);
    }
}
