//! Host-side dense f32 tensor: the unit of parameter state the coordinator
//! manipulates (Δ_W arithmetic, gradient accumulation, checkpoints).
//!
//! Deliberately minimal — all heavy compute runs inside the AOT-compiled
//! XLA programs; the host only needs elementwise ops over flat buffers.

/// Fixed lane width for the chunked elementwise kernels below. Eight f32
/// lanes = one 256-bit vector register; the fixed-size inner loops compile
/// to branch-free straight-line code LLVM auto-vectorizes, which matters
/// because `axpy` *is* the host side of every FF simulated step.
const LANES: usize = 8;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// self += alpha * other (the Δ_W application `W_t + τΔ_W` runs through
    /// this; it is the FF hot path on the host side). Chunked into
    /// [`LANES`]-wide blocks with a scalar tail; per-element arithmetic is
    /// identical to the scalar loop.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        debug_assert_eq!(self.shape, other.shape);
        let mut av = self.data.chunks_exact_mut(LANES);
        let mut bv = other.data.chunks_exact(LANES);
        for (a, b) in (&mut av).zip(&mut bv) {
            for k in 0..LANES {
                a[k] += alpha * b[k];
            }
        }
        for (a, b) in av.into_remainder().iter_mut().zip(bv.remainder()) {
            *a += alpha * b;
        }
    }

    /// self = a - b (builds Δ_W = W_t − W_{t−1}). Chunked like `axpy`.
    pub fn sub_from(a: &Tensor, b: &Tensor) -> Tensor {
        debug_assert_eq!(a.shape, b.shape);
        let mut data = vec![0.0f32; a.data.len()];
        let mut ov = data.chunks_exact_mut(LANES);
        let mut av = a.data.chunks_exact(LANES);
        let mut bv = b.data.chunks_exact(LANES);
        for ((o, x), y) in (&mut ov).zip(&mut av).zip(&mut bv) {
            for k in 0..LANES {
                o[k] = x[k] - y[k];
            }
        }
        for ((o, x), y) in ov
            .into_remainder()
            .iter_mut()
            .zip(av.remainder())
            .zip(bv.remainder())
        {
            *o = x - y;
        }
        Tensor { shape: a.shape.clone(), data }
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn fill(&mut self, v: f32) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// Dot product in f64. [`LANES`] independent accumulators break the
    /// serial add-dependency chain so the loop vectorizes; the summation
    /// order therefore differs from the naive scalar loop by O(ulp).
    pub fn dot(&self, other: &Tensor) -> f64 {
        debug_assert_eq!(self.shape, other.shape);
        let mut acc = [0.0f64; LANES];
        let mut av = self.data.chunks_exact(LANES);
        let mut bv = other.data.chunks_exact(LANES);
        for (a, b) in (&mut av).zip(&mut bv) {
            for k in 0..LANES {
                acc[k] += a[k] as f64 * b[k] as f64;
            }
        }
        for (k, (a, b)) in av.remainder().iter().zip(bv.remainder()).enumerate() {
            acc[k] += *a as f64 * *b as f64;
        }
        acc.iter().sum()
    }

    pub fn norm(&self) -> f64 {
        self.dot(self).sqrt()
    }

    /// Column L2 norms of a [rows, cols] matrix (DoRA magnitude init).
    pub fn col_norms(&self) -> Vec<f32> {
        assert_eq!(self.shape.len(), 2);
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f64; cols];
        for r in 0..rows {
            let row = &self.data[r * cols..(r + 1) * cols];
            for (o, v) in out.iter_mut().zip(row.iter()) {
                *o += (*v as f64) * (*v as f64);
            }
        }
        out.into_iter().map(|v| v.sqrt() as f32).collect()
    }
}

/// Cosine similarity between two same-shape tensor lists viewed as one
/// flattened vector (paper Fig 6 / Fig 13 measurements).
pub fn cosine_similarity(a: &[Tensor], b: &[Tensor]) -> f64 {
    let mut dot = 0.0;
    let mut na = 0.0;
    let mut nb = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        dot += x.dot(y);
        na += x.dot(x);
        nb += y.dot(y);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot / (na.sqrt() * nb.sqrt())
}

/// Flattened L2 norm over a tensor list (gradient-norm probe, Fig 12a).
pub fn list_norm(a: &[Tensor]) -> f64 {
    a.iter().map(|t| t.dot(t)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_and_sub() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![0.5, 0.5, 0.5, 0.5]);
        let mut c = a.clone();
        c.axpy(2.0, &b);
        assert_eq!(c.data, vec![2.0, 3.0, 4.0, 5.0]);
        let d = Tensor::sub_from(&c, &a);
        assert_eq!(d.data, vec![1.0; 4]);
    }

    #[test]
    fn col_norms_matrix() {
        // [[3, 0], [4, 0]] → col norms [5, 0]
        let t = Tensor::from_vec(&[2, 2], vec![3.0, 0.0, 4.0, 0.0]);
        assert_eq!(t.col_norms(), vec![5.0, 0.0]);
    }

    #[test]
    fn cosine_of_parallel_and_orthogonal() {
        let a = vec![Tensor::from_vec(&[2], vec![1.0, 0.0])];
        let b = vec![Tensor::from_vec(&[2], vec![2.0, 0.0])];
        let c = vec![Tensor::from_vec(&[2], vec![0.0, 1.0])];
        assert!((cosine_similarity(&a, &b) - 1.0).abs() < 1e-12);
        assert!(cosine_similarity(&a, &c).abs() < 1e-12);
        assert!((cosine_similarity(&a, &vec![Tensor::from_vec(&[2], vec![-1.0, 0.0])]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_vector_cosine_is_zero() {
        let a = vec![Tensor::zeros(&[3])];
        let b = vec![Tensor::ones(&[3])];
        assert_eq!(cosine_similarity(&a, &b), 0.0);
    }

    #[test]
    fn list_norm_pythagoras() {
        let a = vec![
            Tensor::from_vec(&[1], vec![3.0]),
            Tensor::from_vec(&[1], vec![4.0]),
        ];
        assert!((list_norm(&a) - 5.0).abs() < 1e-12);
    }

    // -- chunked kernels vs scalar reference ---------------------------------
    //
    // The lane-chunked axpy/sub_from/dot must agree with the obvious scalar
    // loops on arbitrary lengths — in particular lengths that exercise the
    // remainder path (n % LANES ≠ 0) and the empty tensor.

    use crate::util::prop::check;

    fn ref_axpy(a: &[f32], alpha: f32, b: &[f32]) -> Vec<f32> {
        a.iter().zip(b).map(|(x, y)| x + alpha * y).collect()
    }

    fn ref_dot(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
    }

    #[test]
    fn prop_chunked_axpy_matches_scalar_reference() {
        check(200, |g| {
            let n = g.usize_in(0, 67); // straddles several lane boundaries
            let alpha = g.f32_in(-2.0, 2.0);
            let a = g.vec_f32(n, 1.0);
            let b = g.vec_f32(n, 1.0);
            let want = ref_axpy(&a, alpha, &b);
            let mut t = Tensor::from_vec(&[n], a);
            t.axpy(alpha, &Tensor::from_vec(&[n], b));
            for (i, (got, want)) in t.data.iter().zip(&want).enumerate() {
                if (got - want).abs() > 1e-6 {
                    return Err(format!("axpy[{i}] (n={n}): {got} != {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_chunked_sub_from_matches_scalar_reference() {
        check(200, |g| {
            let n = g.usize_in(0, 67);
            let a = g.vec_f32(n, 1.0);
            let b = g.vec_f32(n, 1.0);
            let want: Vec<f32> = a.iter().zip(&b).map(|(x, y)| x - y).collect();
            let d = Tensor::sub_from(
                &Tensor::from_vec(&[n], a),
                &Tensor::from_vec(&[n], b),
            );
            for (i, (got, want)) in d.data.iter().zip(&want).enumerate() {
                if (got - want).abs() > 1e-6 {
                    return Err(format!("sub_from[{i}] (n={n}): {got} != {want}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_chunked_dot_matches_scalar_reference() {
        check(200, |g| {
            let n = g.usize_in(0, 67);
            let a = g.vec_f32(n, 1.0);
            let b = g.vec_f32(n, 1.0);
            let want = ref_dot(&a, &b);
            let got = Tensor::from_vec(&[n], a).dot(&Tensor::from_vec(&[n], b));
            // only the summation order differs; the f64 accumulators keep
            // the discrepancy far below the 1e-6 contract
            let tol = 1e-6 * want.abs().max(1.0);
            if (got - want).abs() > tol {
                return Err(format!("dot (n={n}): {got} != {want}"));
            }
            Ok(())
        });
    }
}
