//! Host-side parameter initialization (python never runs at training time,
//! so the "pretrained" W0 and adapter inits are produced here).
//!
//! Rules (mirrored by `python/tests/conftest.init_params` for the L2 tests):
//!   * `lora_b`  → zeros (standard LoRA: adapters start as the identity),
//!   * `lora_a`  → N(0, 0.02),
//!   * `dora_m`  → column norms of the matrix it decorates (Liu et al. 2024),
//!   * LN scale  → ones, LN bias → zeros,
//!   * matmuls   → N(0, 0.5/√d_in) (residual-scaled), embeddings N(0, 0.02).

use std::collections::BTreeMap;

use crate::config::ArtifactConfig;
use crate::model::spec::{param_spec, ParamInfo};
use crate::model::tensor::Tensor;
use crate::util::rng::Rng;

pub const LORA_A_STD: f32 = 0.02;
pub const EMBED_STD: f32 = 0.02;
pub const DORA_EPS: f32 = 1e-6; // must equal python model.DORA_EPS

/// Initialize every parameter for an artifact config. Deterministic in
/// (seed, parameter name) — adding/removing parameters does not shift the
/// streams of the others.
pub fn init_params(ac: &ArtifactConfig, seed: u64) -> BTreeMap<String, Tensor> {
    let root = Rng::new(seed);
    let spec = param_spec(ac);
    let mut out: BTreeMap<String, Tensor> = BTreeMap::new();

    for p in &spec {
        let t = init_one(p, &root);
        out.insert(p.name.clone(), t);
    }
    // DoRA magnitudes decorate the *current* base matrix.
    for p in &spec {
        if let Some(base_name) = p.name.strip_suffix(".dora_m") {
            let norms: Vec<f32> = out[base_name]
                .col_norms()
                .into_iter()
                .map(|n| n + DORA_EPS)
                .collect();
            out.insert(p.name.clone(), Tensor::from_vec(&p.shape.clone(), norms));
        }
    }
    out
}

/// Like [`init_params`] but overriding base weights from a pretrained
/// checkpoint (the W0 the finetuning experiments start from). Adapter
/// params (`lora_a/b`) still come from the seeded init; DoRA magnitudes
/// are recomputed against the *pretrained* matrices.
pub fn init_with_base(
    ac: &ArtifactConfig,
    seed: u64,
    base: &BTreeMap<String, Tensor>,
) -> BTreeMap<String, Tensor> {
    let mut out = init_params(ac, seed);
    for (name, t) in base {
        if let Some(slot) = out.get_mut(name) {
            assert_eq!(slot.shape, t.shape, "checkpoint shape mismatch for {name}");
            *slot = t.clone();
        }
    }
    // Recompute DoRA magnitudes over the pretrained weights.
    let names: Vec<String> = out.keys().cloned().collect();
    for name in names {
        if let Some(base_name) = name.strip_suffix(".dora_m").map(str::to_string) {
            let norms: Vec<f32> =
                out[&base_name].col_norms().into_iter().map(|n| n + DORA_EPS).collect();
            let shape = out[&name].shape.clone();
            out.insert(name, Tensor::from_vec(&shape, norms));
        }
    }
    out
}

fn init_one(p: &ParamInfo, root: &Rng) -> Tensor {
    let mut rng = root.fork(&p.name);
    let name = p.name.as_str();
    if name.ends_with(".lora_b") {
        return Tensor::zeros(&p.shape);
    }
    if name.ends_with(".dora_m") {
        return Tensor::ones(&p.shape); // replaced by col-norms above
    }
    if name.contains(".ln") || name.starts_with("final_ln") {
        return if name.ends_with(".scale") {
            Tensor::ones(&p.shape)
        } else {
            Tensor::zeros(&p.shape)
        };
    }
    let std = if name.ends_with(".lora_a") {
        LORA_A_STD
    } else if name.starts_with("embed.") {
        EMBED_STD
    } else {
        // matmul weight [d_in, d_out]
        0.5 / (p.shape[0] as f32).sqrt()
    };
    let mut t = Tensor::zeros(&p.shape);
    for v in &mut t.data {
        *v = rng.normal_f32(0.0, std);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{presets, TrainMode};

    fn ac(mode: TrainMode) -> ArtifactConfig {
        ArtifactConfig {
            model: presets::model("ff-tiny").unwrap(),
            train_mode: mode,
            lora_rank: 8,
            lora_alpha: 16.0,
            use_pallas: false,
        }
    }

    #[test]
    fn deterministic_and_name_keyed() {
        let a = init_params(&ac(TrainMode::Lora), 7);
        let b = init_params(&ac(TrainMode::Lora), 7);
        assert_eq!(a, b);
        let c = init_params(&ac(TrainMode::Lora), 8);
        assert_ne!(a["embed.tok"], c["embed.tok"]);
        // same name ⇒ same stream even under a different mode
        let d = init_params(&ac(TrainMode::Dora), 7);
        assert_eq!(a["embed.tok"], d["embed.tok"]);
        assert_eq!(a["layer0.attn.wq"], d["layer0.attn.wq"]);
    }

    #[test]
    fn lora_b_zero_ln_identity() {
        let p = init_params(&ac(TrainMode::Lora), 1);
        assert!(p["layer0.attn.wq.lora_b"].data.iter().all(|v| *v == 0.0));
        assert!(p["layer0.ln1.scale"].data.iter().all(|v| *v == 1.0));
        assert!(p["layer0.ln1.bias"].data.iter().all(|v| *v == 0.0));
        assert!(p["layer0.attn.wq.lora_a"].data.iter().any(|v| *v != 0.0));
    }

    #[test]
    fn dora_m_equals_col_norms_of_base() {
        let p = init_params(&ac(TrainMode::Dora), 3);
        let norms = p["layer1.attn.wv"].col_norms();
        let m = &p["layer1.attn.wv.dora_m"];
        for (a, b) in norms.iter().zip(m.data.iter()) {
            assert!((a + DORA_EPS - b).abs() < 1e-6);
        }
    }

    #[test]
    fn weight_std_in_expected_range() {
        let p = init_params(&ac(TrainMode::Lora), 5);
        let w = &p["layer0.mlp.w_in"]; // [64, 256], std = 0.5/8 = 0.0625
        let n = w.data.len() as f64;
        let var: f64 = w.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>() / n;
        assert!((var.sqrt() - 0.0625).abs() < 0.005, "{}", var.sqrt());
    }

    #[test]
    fn covers_entire_spec() {
        let a = ac(TrainMode::Dora);
        let p = init_params(&a, 0);
        assert_eq!(p.len(), param_spec(&a).len());
        for info in param_spec(&a) {
            assert_eq!(p[&info.name].shape, info.shape, "{}", info.name);
        }
    }
}
