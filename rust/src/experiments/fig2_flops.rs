//! Fig 2a/2b + Fig 3: the headline grid — % FLOPs (and train time) saved
//! by Fast Forward to match the N-epoch Adam baseline's test loss, for
//! LoRA and DoRA across the model ladder and the three tasks.

use anyhow::Result;

use crate::config::presets;
use crate::experiments::common::{artifact_key, pct_cell, pct_json, run_pair};
use crate::experiments::ExpContext;
use crate::metrics::{write_report, TextTable};
use crate::util::json::Json;

fn run_grid(ctx: &ExpContext, mode: &str, id: &str) -> Result<Json> {
    // Every (model, task) cell is an independent pair-run; fan them out
    // through the scheduler (worker pool, or the run queue under
    // --queue). Pre-warm each model's W0 sequentially first so workers
    // share the in-memory Arc'd copy instead of serializing on the
    // pretrain build lock at fan-out time. The closure owns its captures
    // (Arc'd context, owned mode) — queue submissions outlive this frame.
    let mut cells: Vec<(String, &'static str)> = Vec::new();
    for model in &ctx.scale.models {
        ctx.pretrained(model)?;
        for task in presets::TASKS {
            cells.push((model.clone(), task));
        }
    }
    let cell_ctx = ctx.shared();
    let cell_mode = mode.to_string();
    let rows = ctx.scatter(cells, move |_i, (model, task)| {
        let ctx = &cell_ctx;
        let mode = cell_mode.as_str();
        let artifact = artifact_key(&model, mode, task);
        let pair = run_pair(ctx, &artifact, &model, task)?;
        // The row is assembled on the worker: only plain JSON crosses back
        // — both trainers (and all their device buffers) die here.
        Ok(Json::obj()
            .set("model", model.as_str())
            .set("paper_model", presets::paper_model(&model))
            .set("task", task)
            .set("mode", mode)
            .set("flops_saved_pct", pct_json(pair.flops_saved()))
            .set("time_saved_pct", pct_json(pair.time_saved()))
            .set("baseline_flops", pair.baseline.flops.total() as f64)
            .set("ff_flops", pair.ff.flops.total() as f64)
            .set("baseline_seconds", pair.baseline.train_seconds)
            .set("ff_seconds", pair.ff.train_seconds)
            .set("baseline_loss", Json::num_or_null(pair.baseline.final_test_loss as f64))
            .set("ff_loss", Json::num_or_null(pair.ff.final_test_loss as f64))
            .set("ff_adam_steps", pair.ff.adam_steps)
            .set("ff_sim_steps", pair.ff.sim_steps)
            .set("reached_target", pair.ff.reached_target))
    })?;
    let json = Json::obj().set("id", id).set("mode", mode).set("rows", Json::Arr(rows));
    Ok(json)
}

fn render(json: &Json, metric: &str, title: &str) -> String {
    let mut table = TextTable::new(&["model", "(paper)", "task", metric, "ff steps (adam+sim)", "matched"]);
    for row in json.get("rows").as_arr().unwrap_or(&[]) {
        let key = if metric == "time saved %" { "time_saved_pct" } else { "flops_saved_pct" };
        table.row(&[
            row.get("model").as_str().unwrap_or("?").to_string(),
            row.get("paper_model").as_str().unwrap_or("?").to_string(),
            row.get("task").as_str().unwrap_or("?").to_string(),
            // null ⇒ the baseline denominator was 0 at this scale: n/a
            pct_cell(row.get(key)),
            format!(
                "{}+{}",
                row.get("ff_adam_steps").as_i64().unwrap_or(0),
                row.get("ff_sim_steps").as_i64().unwrap_or(0)
            ),
            row.get("reached_target").as_bool().unwrap_or(false).to_string(),
        ]);
    }
    format!("{title}\n\n{}", table.render())
}

pub fn run_fig2a(ctx: &ExpContext) -> Result<()> {
    let json = run_grid(ctx, "lora", "fig2a")?;
    let text = render(&json, "flops saved %",
        "Fig 2a — % FLOPs saved by Fast Forward (LoRA), matching N-epoch Adam test loss\n\
         paper: 41–66% (Llama-3 8B) to 65–86% (Pythia 1.4B)");
    write_report(&ctx.reports_dir, "fig2a", &json, &text)
}

pub fn run_fig2b(ctx: &ExpContext) -> Result<()> {
    let json = run_grid(ctx, "dora", "fig2b")?;
    let text = render(&json, "flops saved %",
        "Fig 2b — % FLOPs saved by Fast Forward (DoRA)\n\
         paper: 42–69% (Llama-3 8B) to 66–85% (Pythia 1.4B)");
    write_report(&ctx.reports_dir, "fig2b", &json, &text)
}

/// Fig 3 re-renders fig2a's runs on the train-time axis (re-running the
/// grid if fig2a.json is absent).
pub fn run_fig3(ctx: &ExpContext) -> Result<()> {
    let path = ctx.reports_dir.join("fig2a.json");
    let json = if path.exists() {
        let mut j = Json::parse(&std::fs::read_to_string(&path)?)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        if let Json::Obj(ref mut o) = j {
            o.insert("id".into(), Json::Str("fig3".into()));
        }
        j
    } else {
        run_grid(ctx, "lora", "fig3")?
    };
    let text = render(&json, "time saved %",
        "Fig 3 — % train time saved by Fast Forward (LoRA)\n\
         paper: 41–65% (Llama-3 8B) to 63–78% (Pythia 1.4B)");
    write_report(&ctx.reports_dir, "fig3", &json, &text)
}
