//! Fig 13 (Appendix C): batch-wise gradient consistency (mean pairwise
//! cosine similarity between micro-batch gradients, measured immediately
//! before a FF stage) vs that stage's τ*. The paper finds *no significant
//! correlation* — "wide" directions aren't necessarily "long".

use anyhow::Result;

use crate::analysis::grads::batch_consistency;
use crate::config::FfConfig;
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::experiments::fig12_factors::pearson;
use crate::ff::controller::FfDecision;
use crate::metrics::{write_report, TextTable};
use crate::util::json::Json;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny";
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;
    let mut cfg = run_config(ctx, &artifact, "medical", FfConfig::default())?;
    cfg.max_steps = if ctx.scale.full { 120 } else { 60 };
    let max_steps = cfg.max_steps;
    let mut t = trainer_for(ctx, cfg, Some(base.as_ref()))?;
    t.keep_micro_grads = true;

    let mut samples: Vec<(f64, usize, usize)> = Vec::new(); // (consistency, τ*, stage)
    while t.adam_steps() < max_steps {
        match t.ffc.next() {
            FfDecision::Sgd => {
                t.sgd_step()?;
            }
            FfDecision::FastForward => {
                // consistency of the most recent global batch's micro grads
                let consistency = batch_consistency(&t.last_micro_grads);
                let stats = t.ff_stage()?;
                samples.push((consistency, stats.tau_star, stats.stage));
            }
        }
    }

    let xs: Vec<f64> = samples.iter().map(|(c, _, _)| *c).collect();
    let ys: Vec<f64> = samples.iter().map(|(_, t, _)| *t as f64).collect();
    let r = pearson(&xs, &ys);

    let rows: Vec<Json> = samples
        .iter()
        .map(|(c, tau, stage)| {
            Json::obj().set("stage", *stage).set("consistency", *c).set("tau_star", *tau)
        })
        .collect();
    let json = Json::obj()
        .set("id", "fig13")
        .set("samples", Json::Arr(rows))
        .set("pearson", r);

    let mut table = TextTable::new(&["stage", "batch grad consistency", "τ*"]);
    for (c, tau, stage) in &samples {
        table.row(&[stage.to_string(), format!("{c:.4}"), tau.to_string()]);
    }
    let text = format!(
        "Fig 13 — batch-wise gradient consistency vs optimal FF length\n\n{}\n\
         Pearson(consistency, τ*) = {r:+.3}\n\
         paper reading: no significant correlation — even broadly applicable\n\
         gradient directions may be useful only briefly.\n",
        table.render()
    );
    write_report(&ctx.reports_dir, "fig13", &json, &text)
}
