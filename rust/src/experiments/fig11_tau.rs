//! Fig 11 (Appendix B): the optimal number of FF steps (τ*) per stage as
//! training progresses — the paper finds τ* declines over training.

use anyhow::Result;

use crate::config::FfConfig;
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::metrics::write_report;
use crate::train::trainer::StopRule;
use crate::util::json::Json;

/// Kendall-style monotonicity score in [-1, 1] over (index, value) pairs.
fn trend(values: &[usize]) -> f64 {
    let n = values.len();
    if n < 2 {
        return 0.0;
    }
    let mut concordant = 0i64;
    let mut discordant = 0i64;
    for i in 0..n {
        for j in (i + 1)..n {
            match values[j].cmp(&values[i]) {
                std::cmp::Ordering::Greater => concordant += 1,
                std::cmp::Ordering::Less => discordant += 1,
                std::cmp::Ordering::Equal => {}
            }
        }
    }
    (concordant - discordant) as f64 / ((n * (n - 1) / 2) as f64)
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny";
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;
    let mut cfg = run_config(ctx, &artifact, "medical", FfConfig::default())?;
    // Long enough run to watch τ* decay over many stages.
    cfg.max_steps = if ctx.scale.full { 120 } else { 60 };
    let max_steps = cfg.max_steps;
    let mut t = trainer_for(ctx, cfg, Some(base.as_ref()))?;
    t.run(&StopRule::MaxSteps(max_steps))?;

    let taus: Vec<usize> = t.ffc.stages.iter().map(|s| s.tau_star).collect();
    let tr = trend(&taus);
    let rows: Vec<Json> = t
        .ffc
        .stages
        .iter()
        .map(|s| {
            Json::obj()
                .set("stage", s.stage)
                .set("at_step", s.at_step)
                .set("tau_star", s.tau_star)
                .set("baseline_loss", s.baseline_loss as f64)
                .set("final_loss", s.final_loss as f64)
        })
        .collect();
    let json = Json::obj()
        .set("id", "fig11")
        .set("stages", Json::Arr(rows))
        .set("trend", tr);

    let series: String = taus.iter().map(|t| format!("{t:>3}")).collect::<Vec<_>>().join(" ");
    let text = format!(
        "Fig 11 — optimal τ* per FF stage over training (medical, {model})\n\n\
         τ* by stage: [{series}]\n\
         monotonicity (Kendall τ over stage index): {tr:+.2}\n\
         paper reading: τ* declines as training continues — {}\n",
        if tr < 0.0 { "reproduced" } else { "NOT reproduced on this substrate" }
    );
    write_report(&ctx.reports_dir, "fig11", &json, &text)
}
