//! §5.1: "FF does not harm long-term accuracy" — finetune to convergence
//! with FF (switching permanently to Adam after 3 consecutive empty FF
//! stages), compare the converged loss and FLOPs against plain Adam run
//! for the same total optimizer-step budget. Paper: FF converges to a
//! slightly *better* loss while saving 56% of FLOPs.

use anyhow::Result;

use crate::config::FfConfig;
use crate::experiments::common::{pct_json, pct_or_na, run_config, saved_frac, trainer_for};
use crate::experiments::ExpContext;
use crate::metrics::write_report;
use crate::train::trainer::StopRule;
use crate::util::json::Json;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny"; // paper: Pythia-1.4B, medical task
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;
    let budget = if ctx.scale.full { 300 } else { 150 };

    // FF to convergence (patience-3 rule), then 6 more SGD steps (paper).
    let ff_cfg = run_config(ctx, &artifact, "medical",
        FfConfig { convergence_patience: Some(3), ..FfConfig::default() })?;
    let mut ff_t = trainer_for(ctx, ff_cfg, Some(base.as_ref()))?;
    let ff = ff_t.run(&StopRule::Convergence { max_steps: budget, tail: 6 })?;

    // Baseline: plain Adam for the same optimizer-step count FF used.
    let b_cfg = run_config(ctx, &artifact, "medical",
        FfConfig { enabled: false, ..FfConfig::default() })?;
    let mut b_t = trainer_for(ctx, b_cfg, Some(base.as_ref()))?;
    // Match the *effective training progress* rather than steps: run the
    // baseline until its test loss stops improving too (same budget cap).
    let baseline = b_t.run(&StopRule::MaxSteps(budget))?;

    let flops_saved = saved_frac(ff.flops.total() as f64, baseline.flops.total() as f64);
    let json = Json::obj()
        .set("id", "convergence")
        .set("ff_loss", Json::num_or_null(ff.final_test_loss as f64))
        .set("baseline_loss", Json::num_or_null(baseline.final_test_loss as f64))
        .set("ff_flops", ff.flops.total() as f64)
        .set("baseline_flops", baseline.flops.total() as f64)
        .set("flops_saved_pct", pct_json(flops_saved))
        .set("ff_adam_steps", ff.adam_steps)
        .set("ff_sim_steps", ff.sim_steps)
        .set("baseline_steps", baseline.adam_steps)
        .set("ff_converged", ff_t.ffc.is_permanently_off());

    let text = format!(
        "§5.1 — Fast Forward at loss convergence (medical, {model})\n\n\
         FF:       test loss {:.4} after {}+{} steps, {:.3e} FLOPs (converged: {})\n\
         baseline: test loss {:.4} after {} steps, {:.3e} FLOPs\n\
         FLOPs saved: {}  (paper: 56% with slightly better final loss)\n\
         final-loss delta (FF − baseline): {:+.4} (≤ 0 means FF no worse)\n",
        ff.final_test_loss,
        ff.adam_steps,
        ff.sim_steps,
        ff.flops.total() as f64,
        ff_t.ffc.is_permanently_off(),
        baseline.final_test_loss,
        baseline.adam_steps,
        baseline.flops.total() as f64,
        pct_or_na(flops_saved),
        ff.final_test_loss - baseline.final_test_loss,
    );
    write_report(&ctx.reports_dir, "convergence", &json, &text)
}
