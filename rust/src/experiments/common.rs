//! Shared experiment machinery: the paper's §4 "Training and Evaluation
//! Procedure" as a reusable pair-run (baseline Adam for N epochs → record
//! final test loss as target → FF run until matching it), with the shared
//! pretrained W0 guaranteeing both runs start identically.

use anyhow::Result;

use crate::config::{presets, FfConfig, TrainConfig};
use crate::experiments::ExpContext;
use crate::train::pretrain::ensure_pretrained;
use crate::train::trainer::{RunSummary, StopRule, Trainer};

/// Scaled-down corpus sizes per task for quick mode (full keeps presets).
pub fn train_examples_for(ctx: &ExpContext, task: &str) -> usize {
    let preset = presets::task_preset(task).map(|t| t.train_examples).unwrap_or(2048);
    if ctx.scale.full {
        preset
    } else {
        preset / 2
    }
}

/// Build the TrainConfig for one run of (artifact, task) under ctx scaling.
pub fn run_config(ctx: &ExpContext, artifact: &str, task: &str, ff: FfConfig) -> Result<TrainConfig> {
    let mut cfg = presets::train_config(artifact, task, ctx.scale.epochs)?;
    cfg.train_examples = train_examples_for(ctx, task);
    let steps_per_epoch = cfg.train_examples / cfg.global_batch;
    cfg.max_steps = ctx.scale.epochs * steps_per_epoch;
    if !ctx.scale.full {
        // quick scale: cap the per-cell budget so the whole grid runs in
        // minutes on one core (both runs of a pair see the same cap).
        cfg.max_steps = cfg.max_steps.min(128);
    }
    cfg.test_examples = ctx.scale.test_examples;
    cfg.ff = ff;
    Ok(cfg)
}

pub struct PairOutcome {
    pub baseline: RunSummary,
    pub ff: RunSummary,
    /// The FF trainer, for post-run analysis (stage stats, params, logs).
    pub ff_trainer: Trainer,
    pub baseline_trainer: Trainer,
}

impl PairOutcome {
    /// 1 − FF/baseline on chargeable FLOPs (paper Fig 2 y-axis).
    pub fn flops_saved(&self) -> f64 {
        1.0 - self.ff.flops.total() as f64 / self.baseline.flops.total() as f64
    }

    /// 1 − FF/baseline on train seconds (paper Fig 3 y-axis).
    pub fn time_saved(&self) -> f64 {
        1.0 - self.ff.train_seconds / self.baseline.train_seconds
    }
}

/// The paper's §4 protocol for one (model, task, mode) cell.
pub fn run_pair(ctx: &ExpContext, artifact: &str, model: &str, task: &str) -> Result<PairOutcome> {
    let base = ensure_pretrained(&ctx.rt, &ctx.artifacts_root, model, None)?;

    // Baseline: plain Adam for the full epoch budget.
    let cfg_b = run_config(ctx, artifact, task, FfConfig { enabled: false, ..FfConfig::default() })?;
    let max_steps = cfg_b.max_steps;
    let mut baseline_trainer = Trainer::new(&ctx.rt, &ctx.artifacts_root, cfg_b, Some(&base))?;
    let baseline = baseline_trainer.run(&StopRule::MaxSteps(max_steps))?;

    // FF: identical config + data, run to the baseline's final test loss.
    let cfg_f = run_config(ctx, artifact, task, FfConfig::default())?;
    let mut ff_trainer = Trainer::new(&ctx.rt, &ctx.artifacts_root, cfg_f, Some(&base))?;
    let ff = ff_trainer.run(&StopRule::TargetLoss {
        target: baseline.final_test_loss,
        // quick-scale losses move more per step than the paper's ε=1e-4
        eps: if ctx.scale.full { 1e-3 } else { 3e-3 },
        eval_every: ctx.scale.eval_every,
        max_steps: max_steps * 3,
    })?;
    // Both runs drove the same pipelined engine path (Trainer::run →
    // Engine::dispatch_step); surface how the readback ring behaved.
    crate::debug!(
        "[{model}/{task}] step pipeline: baseline [{}] vs ff [{}]",
        baseline_trainer.stream_stats().report(),
        ff_trainer.stream_stats().report(),
    );
    crate::info!(
        "[{model}/{task}] baseline {:.4} @{} steps vs FF {:.4} @{}+{} steps → {:.1}% FLOPs, {:.1}% time saved",
        baseline.final_test_loss,
        baseline.adam_steps,
        ff.final_test_loss,
        ff.adam_steps,
        ff.sim_steps,
        100.0 * (1.0 - ff.flops.total() as f64 / baseline.flops.total() as f64),
        100.0 * (1.0 - ff.train_seconds / baseline.train_seconds),
    );
    Ok(PairOutcome { baseline, ff, ff_trainer, baseline_trainer })
}

/// Artifact key for (model, mode, task-rank override).
pub fn artifact_key(model: &str, mode: &str, task: &str) -> String {
    // chat uses rank 64 in the paper (Table 3); our artifact grid carries
    // r8 for every model and r64 only for ff-tiny, so we keep r8 for the
    // grid experiments and exercise r64 in fig7's rank sweep.
    let _ = task;
    match mode {
        "lora" => format!("{model}_lora_r8"),
        "dora" => format!("{model}_dora_r8"),
        other => format!("{model}_{other}"),
    }
}
