//! Shared experiment machinery: the paper's §4 "Training and Evaluation
//! Procedure" as a reusable pair-run (baseline Adam for N epochs → record
//! final test loss as target → FF run until matching it), with the shared
//! pretrained W0 guaranteeing both runs start identically.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{presets, FfConfig, TrainConfig};
use crate::experiments::ExpContext;
use crate::model::tensor::Tensor;
use crate::train::trainer::{RunSummary, StopRule, Trainer};
use crate::util::json::Json;

/// Guarded saving ratio `1 − num/den`: `None` when the denominator is
/// zero or non-finite (degenerate quick-scale cells), where the raw
/// division would print ±inf/NaN percentages into reports.
pub fn saved_frac(num: f64, den: f64) -> Option<f64> {
    (den > 0.0 && den.is_finite()).then(|| 1.0 - num / den)
}

/// `Some(finite fraction)` → `"42.0%"`, else `"n/a"` (log lines).
pub fn pct_or_na(v: Option<f64>) -> String {
    match v {
        Some(x) if x.is_finite() => format!("{:.1}%", 100.0 * x),
        _ => "n/a".to_string(),
    }
}

/// `Some(finite fraction)` → percentage `Json::Num`, else `Json::Null`
/// (report rows; render back with [`pct_cell`]).
pub fn pct_json(v: Option<f64>) -> Json {
    match v {
        Some(x) if x.is_finite() => Json::Num(100.0 * x),
        _ => Json::Null,
    }
}

/// Table cell for a percentage written by [`pct_json`]: `"{:.1}"` for a
/// finite number, `"n/a"` for null/non-finite.
pub fn pct_cell(v: &Json) -> String {
    match v.as_f64() {
        Some(x) if x.is_finite() => format!("{x:.1}"),
        _ => "n/a".to_string(),
    }
}

/// Build a trainer through the context's shared [`crate::sched::ArtifactCache`]
/// so concurrent harness cells over the same artifact share one compiled
/// program set instead of each compiling their own.
pub fn trainer_for(
    ctx: &ExpContext,
    cfg: TrainConfig,
    base: Option<&BTreeMap<String, Tensor>>,
) -> Result<Trainer> {
    let art = ctx.artifacts.load(&ctx.rt, &cfg.artifact)?;
    Trainer::with_artifact(&ctx.rt, art, cfg, base)
}

/// Scaled-down corpus sizes per task for quick mode (full keeps presets).
pub fn train_examples_for(ctx: &ExpContext, task: &str) -> usize {
    let preset = presets::task_preset(task).map(|t| t.train_examples).unwrap_or(2048);
    if ctx.scale.full {
        preset
    } else {
        preset / 2
    }
}

/// Build the TrainConfig for one run of (artifact, task) under ctx scaling.
pub fn run_config(ctx: &ExpContext, artifact: &str, task: &str, ff: FfConfig) -> Result<TrainConfig> {
    let mut cfg = presets::train_config(artifact, task, ctx.scale.epochs)?;
    cfg.train_examples = train_examples_for(ctx, task);
    let steps_per_epoch = cfg.train_examples / cfg.global_batch;
    cfg.max_steps = ctx.scale.epochs * steps_per_epoch;
    if !ctx.scale.full {
        // quick scale: cap the per-cell budget so the whole grid runs in
        // minutes on one core (both runs of a pair see the same cap).
        cfg.max_steps = cfg.max_steps.min(128);
    }
    cfg.test_examples = ctx.scale.test_examples;
    cfg.ff = ff;
    Ok(cfg)
}

pub struct PairOutcome {
    pub baseline: RunSummary,
    pub ff: RunSummary,
    /// The FF trainer, for post-run analysis (stage stats, params, logs).
    pub ff_trainer: Trainer,
    pub baseline_trainer: Trainer,
}

impl PairOutcome {
    /// 1 − FF/baseline on chargeable FLOPs (paper Fig 2 y-axis). `None`
    /// when the baseline charged zero FLOPs (degenerate quick-scale cells)
    /// — the ratio would be ±inf/NaN, and reports must say `n/a`, not
    /// print garbage percentages.
    pub fn flops_saved(&self) -> Option<f64> {
        saved_frac(self.ff.flops.total() as f64, self.baseline.flops.total() as f64)
    }

    /// 1 − FF/baseline on train seconds (paper Fig 3 y-axis). `None` when
    /// the baseline's train time is zero or non-finite (sub-resolution
    /// quick-scale runs), for the same reason as [`PairOutcome::flops_saved`].
    pub fn time_saved(&self) -> Option<f64> {
        saved_frac(self.ff.train_seconds, self.baseline.train_seconds)
    }
}

/// The paper's §4 protocol for one (model, task, mode) cell.
///
/// The two legs are inherently **sequential**: the FF leg's stop rule is
/// `TargetLoss` at the baseline leg's final test loss, so the baseline
/// must finish first — there is no legal baseline∥FF overlap within one
/// pair. Concurrency across *cells* is what parallelizes the protocol:
/// grid harnesses (fig2/fig7/qa) fan whole `run_pair` cells out through
/// `ExpContext::pool`, so one cell's FF leg runs while another cell's
/// baseline leg is still training. `run_pair` itself is thread-safe (the
/// shared `W0` checkpoint build is serialized in `ensure_pretrained`).
pub fn run_pair(ctx: &ExpContext, artifact: &str, model: &str, task: &str) -> Result<PairOutcome> {
    // One Arc'd W0 per model per process — concurrent cells share it
    // instead of each re-reading the checkpoint from disk.
    let base = ctx.pretrained(model)?;

    // Baseline: plain Adam for the full epoch budget. Both legs go
    // through the context's artifact cache so concurrent cells share one
    // compiled program set per artifact.
    let cfg_b = run_config(ctx, artifact, task, FfConfig { enabled: false, ..FfConfig::default() })?;
    let max_steps = cfg_b.max_steps;
    let mut baseline_trainer = trainer_for(ctx, cfg_b, Some(base.as_ref()))?;
    let baseline = baseline_trainer.run(&StopRule::MaxSteps(max_steps))?;

    // FF: identical config + data, run to the baseline's final test loss.
    let cfg_f = run_config(ctx, artifact, task, FfConfig::default())?;
    let mut ff_trainer = trainer_for(ctx, cfg_f, Some(base.as_ref()))?;
    let ff = ff_trainer.run(&StopRule::TargetLoss {
        target: baseline.final_test_loss,
        // quick-scale losses move more per step than the paper's ε=1e-4
        eps: if ctx.scale.full { 1e-3 } else { 3e-3 },
        eval_every: ctx.scale.eval_every,
        max_steps: max_steps * 3,
    })?;
    // Both runs drove the same pipelined engine path (Trainer::run →
    // Engine::dispatch_step); surface how the readback ring behaved.
    crate::debug!(
        "[{model}/{task}] step pipeline: baseline [{}] vs ff [{}]",
        baseline_trainer.stream_stats().report(),
        ff_trainer.stream_stats().report(),
    );
    let outcome = PairOutcome { baseline, ff, ff_trainer, baseline_trainer };
    crate::info!(
        "[{model}/{task}] baseline {:.4} @{} steps vs FF {:.4} @{}+{} steps → {} FLOPs, {} time saved",
        outcome.baseline.final_test_loss,
        outcome.baseline.adam_steps,
        outcome.ff.final_test_loss,
        outcome.ff.adam_steps,
        outcome.ff.sim_steps,
        pct_or_na(outcome.flops_saved()),
        pct_or_na(outcome.time_saved()),
    );
    Ok(outcome)
}

/// Artifact key for (model, mode, task-rank override).
pub fn artifact_key(model: &str, mode: &str, task: &str) -> String {
    // chat uses rank 64 in the paper (Table 3); our artifact grid carries
    // r8 for every model and r64 only for ff-tiny, so we keep r8 for the
    // grid experiments and exercise r64 in fig7's rank sweep.
    let _ = task;
    match mode {
        "lora" => format!("{model}_lora_r8"),
        "dora" => format!("{model}_dora_r8"),
        other => format!("{model}_{other}"),
    }
}
