//! Fig 6: cosine similarity between the current gradient and all
//! previously saved gradients, for regular training vs FF training. The
//! paper finds FF *lowers* similarity with past gradients — having
//! accelerated along a direction, later steps stop searching it.

use anyhow::Result;

use crate::analysis::grads::GradHistory;
use crate::config::FfConfig;
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::ff::controller::FfDecision;
use crate::metrics::write_report;
use crate::util::json::Json;

fn series(ctx: &ExpContext, ff_on: bool, steps: usize) -> Result<(Vec<(usize, f64)>, f64)> {
    let model = "ff-tiny";
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;
    let ff = if ff_on { FfConfig::default() } else { FfConfig { enabled: false, ..FfConfig::default() } };
    let cfg = run_config(ctx, &artifact, "medical", ff)?;
    let mut t = trainer_for(ctx, cfg, Some(base.as_ref()))?;
    // The cosine history reads the mean gradient after every step; with
    // device-side accumulation that download only happens on request.
    t.keep_host_grads = true;

    let mut hist = GradHistory::new(2, 64);
    while t.adam_steps() < steps {
        match t.ffc.next() {
            FfDecision::Sgd => {
                t.sgd_step()?;
                let grads = t.last_grads.clone();
                hist.observe(t.adam_steps(), &grads);
            }
            FfDecision::FastForward => {
                t.ff_stage()?;
            }
        }
    }
    let mean_series: Vec<(usize, f64)> =
        hist.series.iter().map(|(s, m, _)| (*s, *m)).collect();
    let overall =
        mean_series.iter().map(|(_, m)| *m).sum::<f64>() / mean_series.len().max(1) as f64;
    Ok((mean_series, overall))
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let steps = if ctx.scale.full { 60 } else { 36 };
    let (reg, reg_mean) = series(ctx, false, steps)?;
    let (ffs, ff_mean) = series(ctx, true, steps)?;

    let to_json = |v: &[(usize, f64)]| {
        Json::Arr(v.iter().map(|(s, m)| Json::obj().set("step", *s).set("mean_cos", *m)).collect())
    };
    let json = Json::obj()
        .set("id", "fig6")
        .set("regular", to_json(&reg))
        .set("fast_forward", to_json(&ffs))
        .set("regular_mean", reg_mean)
        .set("ff_mean", ff_mean);

    let text = format!(
        "Fig 6 — cosine similarity of current gradient vs saved history\n\n\
         regular training: mean over run = {reg_mean:.4}\n\
         fast forward:     mean over run = {ff_mean:.4}\n\n\
         paper reading: FF leads to LOWER average similarity with previous\n\
         gradients ({}).\n",
        if ff_mean < reg_mean { "reproduced" } else { "NOT reproduced on this substrate" }
    );
    write_report(&ctx.reports_dir, "fig6", &json, &text)
}
