//! Fig 10 (Appendix B): val loss as a function of τ for the *first* FF
//! stage, probed for a fixed 100 simulated steps with no stop rule — the
//! paper finds the curve convex in τ, justifying first-increase stopping.

use anyhow::Result;

use crate::config::FfConfig;
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::metrics::write_report;
use crate::util::json::Json;

/// Count strict sign changes of the discrete slope — a convex curve has at
/// most one (decreasing → increasing).
fn slope_sign_changes(losses: &[f32]) -> usize {
    let slopes: Vec<f64> =
        losses.windows(2).map(|w| (w[1] - w[0]) as f64).collect();
    let mut changes = 0;
    let mut last = 0.0f64;
    for s in slopes {
        if s != 0.0 {
            if last != 0.0 && s.signum() != last.signum() {
                changes += 1;
            }
            last = s;
        }
    }
    changes
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny";
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;
    let cfg = run_config(ctx, &artifact, "chat", FfConfig::default())?;
    let warmup = cfg.ff.warmup_steps;
    let mut t = trainer_for(ctx, cfg, Some(base.as_ref()))?;
    for _ in 0..warmup {
        t.sgd_step()?;
    }
    let n_probe = 100; // paper's probe length
    let losses = t.ff_probe_fixed(n_probe)?;

    let argmin = losses
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    let changes = slope_sign_changes(&losses);

    let json = Json::obj()
        .set("id", "fig10")
        .set("losses", losses.iter().map(|l| *l as f64).collect::<Vec<f64>>())
        .set("tau_vertex", argmin)
        .set("slope_sign_changes", changes);

    // compact sparkline over τ
    let lo = losses.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = losses.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let bars = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let spark: String = losses
        .iter()
        .map(|l| bars[(((l - lo) / (hi - lo + 1e-9)) * 9.0).round() as usize])
        .collect();
    let text = format!(
        "Fig 10 — val loss vs τ for the first FF stage ({n_probe} probes, chat task)\n\n\
         loss(τ): [{spark}]\n\
         vertex at τ = {argmin}; loss {:.4} → {:.4} → {:.4} (τ=0 / vertex / τ={n_probe})\n\
         slope sign changes = {changes} (convex ⇒ ≤ 1): {}\n",
        losses[0],
        losses[argmin],
        losses[n_probe],
        if changes <= 1 { "convex (reproduced)" } else { "non-convex wiggle (see JSON)" }
    );
    write_report(&ctx.reports_dir, "fig10", &json, &text)
}
