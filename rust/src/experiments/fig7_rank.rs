//! Fig 7: total training FLOPs vs LoRA rank (1–64) on the clinical
//! (medical) task, baseline vs FF — the gray area between the curves is
//! the compute FF saves, which the paper finds *grows* with rank.
//! Also reproduces the §6.1 full-rank-LoRA note (r = d_model).

use anyhow::Result;

use crate::experiments::common::{pct_cell, pct_json, run_pair};
use crate::experiments::ExpContext;
use crate::metrics::{write_report, TextTable};
use crate::util::json::Json;

pub const RANKS: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny"; // paper: Pythia-1.4B
    // Each rank cell is an independent pair-run over its own artifact:
    // fan the sweep out through the scheduler (`--jobs N`; `--queue`
    // routes it through the run queue). Results come back in RANKS order
    // regardless of completion order, so the report is byte-identical at
    // any jobs level. W0 is pre-warmed once so workers share the
    // in-memory Arc'd copy read-only.
    ctx.pretrained(model)?;
    let cell_ctx = ctx.shared();
    let rows = ctx.scatter(RANKS.to_vec(), move |_i, rank| {
        let ctx = &cell_ctx;
        let artifact = format!("{model}_lora_r{rank}");
        let pair = run_pair(ctx, &artifact, model, "medical")?;
        Ok(Json::obj()
            .set("rank", rank)
            .set("baseline_flops", pair.baseline.flops.total() as f64)
            .set("ff_flops", pair.ff.flops.total() as f64)
            .set("flops_saved_pct", pct_json(pair.flops_saved()))
            .set("reached_target", pair.ff.reached_target)
            .set("full_rank", rank == 64)) // r64 == d_model for ff-tiny
    })?;

    let json = Json::obj().set("id", "fig7").set("rows", Json::Arr(rows.clone()));
    let mut table = TextTable::new(&["rank", "baseline FLOPs", "FF FLOPs", "saved %", "matched"]);
    for r in &rows {
        table.row(&[
            r.get("rank").as_i64().unwrap_or(0).to_string(),
            format!("{:.3e}", r.get("baseline_flops").as_f64().unwrap_or(0.0)),
            format!("{:.3e}", r.get("ff_flops").as_f64().unwrap_or(0.0)),
            pct_cell(r.get("flops_saved_pct")),
            r.get("reached_target").as_bool().unwrap_or(false).to_string(),
        ]);
    }
    // Null cells (degenerate baselines) must not count as 0.0 savings —
    // the trend verdict is only meaningful when both endpoints are real.
    let saved: Vec<Option<f64>> = rows
        .iter()
        .map(|r| r.get("flops_saved_pct").as_f64().filter(|v| v.is_finite()))
        .collect();
    let trend = match (saved.first().copied().flatten(), saved.last().copied().flatten()) {
        (Some(first), Some(last)) if last >= first => "non-decreasing (reproduced)",
        (Some(_), Some(_)) => "decreasing (NOT reproduced)",
        _ => "n/a (degenerate cells at this scale)",
    };
    let text = format!(
        "Fig 7 — total FLOPs vs LoRA rank, medical task on {model} (paper: Pythia-1.4B)\n\
         note: rank 64 == d_model for {model}, i.e. the paper's 'LoRA full rank'\n\
         setting (§6.1, paper reports 74% saved on Pythia-410m there).\n\n{}\n\
         paper reading: savings increase monotonically with rank — here: {trend}\n",
        table.render()
    );
    write_report(&ctx.reports_dir, "fig7", &json, &text)
}
