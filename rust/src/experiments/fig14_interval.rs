//! Fig 14 (Appendix D): how soon can we Fast Forward? τ* at the *second*
//! FF stage as a function of the SGD interval length T_interval ∈ 1..10
//! since the previous stage (medical task, smallest model).

use anyhow::Result;

use crate::config::FfConfig;
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::ff::controller::FfDecision;
use crate::metrics::{write_report, TextTable};
use crate::util::json::Json;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny";
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;

    let mut rows = Vec::new();
    for t_interval in 1..=10usize {
        let ff = FfConfig { t_interval, warmup_steps: 6, ..FfConfig::default() };
        let cfg = run_config(ctx, &artifact, "medical", ff)?;
        let mut t = trainer_for(ctx, cfg, Some(base.as_ref()))?;
        // drive until exactly two FF stages have run
        while t.ffc.n_stages() < 2 && t.adam_steps() < 100 {
            match t.ffc.next() {
                FfDecision::Sgd => {
                    t.sgd_step()?;
                }
                FfDecision::FastForward => {
                    t.ff_stage()?;
                }
            }
        }
        let second = t.ffc.stages.get(1);
        rows.push(
            Json::obj()
                .set("t_interval", t_interval)
                .set("tau_star_stage2", second.map(|s| s.tau_star as i64).unwrap_or(-1))
                .set("tau_star_stage1", t.ffc.stages.first().map(|s| s.tau_star as i64).unwrap_or(-1)),
        );
    }

    let json = Json::obj().set("id", "fig14").set("rows", Json::Arr(rows.clone()));
    let mut table = TextTable::new(&["T_interval", "τ* at stage 2", "τ* at stage 1"]);
    for r in &rows {
        table.row(&[
            r.get("t_interval").as_i64().unwrap_or(0).to_string(),
            r.get("tau_star_stage2").as_i64().unwrap_or(-1).to_string(),
            r.get("tau_star_stage1").as_i64().unwrap_or(-1).to_string(),
        ]);
    }
    let text = format!(
        "Fig 14 — optimal τ* at the second FF stage vs SGD interval length\n\
         (one interval step is equivalent to extending the previous stage)\n\n{}\n\
         paper reading: a handful of SGD steps (≈up to 4) extends the next\n\
         stage; even T_interval=1–2 already yields nonzero τ* — FF can start\n\
         benefiting almost immediately.\n",
        table.render()
    );
    write_report(&ctx.reports_dir, "fig14", &json, &text)
}
