//! Fig 4 / Fig 9 (Appendix A): the training trajectory — loss at every
//! step, with SGD steps (paper: red dots) and FF simulated steps (green
//! dots), against the vanilla Adam curve, on the chat task.

use anyhow::Result;

use crate::config::FfConfig;
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::metrics::{write_report, StepKind};
use crate::train::trainer::StopRule;
use crate::util::json::Json;

fn curve_for_model(ctx: &ExpContext, model: &str) -> Result<Json> {
    let base = ctx.pretrained(model)?;
    let artifact = format!("{model}_lora_r8");

    let mut series = Vec::new();
    for (label, ff) in [
        ("vanilla", FfConfig { enabled: false, ..FfConfig::default() }),
        ("fast_forward", FfConfig::default()),
    ] {
        let cfg = run_config(ctx, &artifact, "chat", ff)?;
        let max_steps = cfg.max_steps;
        let mut t = trainer_for(ctx, cfg, Some(base.as_ref()))?;
        t.run(&StopRule::MaxSteps(max_steps))?;
        let pts: Vec<Json> = t
            .log
            .records
            .iter()
            .map(|r| {
                Json::obj()
                    .set("step", r.step)
                    .set("loss", r.loss as f64)
                    .set("kind", match r.kind {
                        StepKind::Sgd => "sgd",
                        StepKind::FastForward => "ff",
                    })
                    .set("flops", r.flops as f64)
            })
            .collect();
        series.push(Json::obj().set("label", label).set("points", Json::Arr(pts)));
    }
    Ok(Json::obj().set("model", model).set("series", Json::Arr(series)))
}

fn render(models: &[Json]) -> String {
    let mut out = String::from(
        "Fig 4/9 — chat-task training curves; FF simulated steps marked 'F', SGD '.'\n",
    );
    for m in models {
        out.push_str(&format!("\nmodel {}:\n", m.get("model").as_str().unwrap_or("?")));
        for s in m.get("series").as_arr().unwrap_or(&[]) {
            let pts = s.get("points").as_arr().unwrap_or(&[]);
            let first = pts.first().map(|p| p.get("loss").as_f64().unwrap_or(0.0)).unwrap_or(0.0);
            let last = pts.last().map(|p| p.get("loss").as_f64().unwrap_or(0.0)).unwrap_or(0.0);
            let n_ff = pts.iter().filter(|p| p.get("kind").as_str() == Some("ff")).count();
            let marks: String = pts
                .iter()
                .map(|p| if p.get("kind").as_str() == Some("ff") { 'F' } else { '.' })
                .collect();
            out.push_str(&format!(
                "  {:<13} loss {first:.4} → {last:.4} over {} steps ({n_ff} simulated)\n    [{marks}]\n",
                s.get("label").as_str().unwrap_or("?"),
                pts.len(),
            ));
        }
    }
    out
}

pub fn run_fig4(ctx: &ExpContext) -> Result<()> {
    // Paper plots Pythia-6.9B ↔ ff-medium; in quick mode use the largest
    // model in scale.models.
    let model = if ctx.scale.models.iter().any(|m| m == "ff-medium") {
        "ff-medium".to_string()
    } else {
        ctx.scale.models.last().cloned().unwrap_or_else(|| "ff-tiny".into())
    };
    let m = curve_for_model(ctx, &model)?;
    let text = render(std::slice::from_ref(&m));
    let json = Json::obj().set("id", "fig4").set("models", Json::Arr(vec![m]));
    write_report(&ctx.reports_dir, "fig4", &json, &text)
}

pub fn run_fig9(ctx: &ExpContext) -> Result<()> {
    let mut models = Vec::new();
    for model in &ctx.scale.models {
        models.push(curve_for_model(ctx, model)?);
    }
    let text = render(&models);
    let json = Json::obj().set("id", "fig9").set("models", Json::Arr(models));
    write_report(&ctx.reports_dir, "fig9", &json, &text)
}
