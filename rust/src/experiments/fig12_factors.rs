//! Fig 12 (Appendix B): potential predictors of τ* — the norm (12a) and
//! condition number (12b) of the gradients right before each FF stage.
//! The paper finds both correlate with τ* but only through the confounder
//! of training time.

use std::sync::Arc;

use anyhow::Result;

use crate::config::FfConfig;
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::metrics::{write_report, TextTable};
use crate::train::trainer::StopRule;
use crate::util::json::Json;

/// Pearson correlation.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt() + 1e-300)
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny"; // paper: Pythia-1.4B, medical task
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;

    // The paper pools stages from across training; a single quick-scale
    // run yields only a handful. Run a small grid of seed replicas —
    // independent runs fanned out through the scheduler (pool, or run
    // queue under --queue) — and pool every stage into the correlation
    // estimates. Replica order is fixed, so the report is identical at
    // any `--jobs` level. The closure owns its captures (queue
    // submissions outlive this frame).
    let n_seeds: u64 = if ctx.scale.full { 3 } else { 2 };
    let cell_ctx = ctx.shared();
    let cell_artifact = artifact.clone();
    let cell_base = Arc::clone(&base);
    let per_seed = ctx.scatter((0..n_seeds).collect(), move |_i, k| {
        let ctx = &cell_ctx;
        let mut cfg = run_config(ctx, &cell_artifact, "medical", FfConfig::default())?;
        cfg.max_steps = if ctx.scale.full { 120 } else { 60 };
        cfg.seed = cfg.seed.wrapping_add(k);
        let max_steps = cfg.max_steps;
        let mut t = trainer_for(ctx, cfg.clone(), Some(cell_base.as_ref()))?;
        t.run(&StopRule::MaxSteps(max_steps))?;
        Ok((cfg.seed, t.ffc.stages.clone()))
    })?;

    let stages: Vec<(u64, crate::ff::controller::FfStageStats)> = per_seed
        .into_iter()
        .flat_map(|(seed, stages)| stages.into_iter().map(move |s| (seed, s)))
        .collect();
    let taus: Vec<f64> = stages.iter().map(|(_, s)| s.tau_star as f64).collect();
    let norms: Vec<f64> = stages.iter().map(|(_, s)| s.grad_norm).collect();
    let conds: Vec<f64> = stages.iter().map(|(_, s)| s.grad_cond).collect();
    let steps: Vec<f64> = stages.iter().map(|(_, s)| s.at_step as f64).collect();

    let r_norm = pearson(&norms, &taus);
    let r_cond = pearson(&conds, &taus);
    let r_step = pearson(&steps, &taus);

    let rows: Vec<Json> = stages
        .iter()
        .map(|(seed, s)| {
            Json::obj()
                .set("seed", *seed as i64)
                .set("stage", s.stage)
                .set("at_step", s.at_step)
                .set("tau_star", s.tau_star)
                .set("grad_norm", s.grad_norm)
                .set("grad_cond", s.grad_cond)
        })
        .collect();
    let json = Json::obj()
        .set("id", "fig12")
        .set("n_seeds", n_seeds as i64)
        .set("stages", Json::Arr(rows))
        .set("pearson_norm_tau", r_norm)
        .set("pearson_cond_tau", r_cond)
        .set("pearson_step_tau", r_step);

    let mut table = TextTable::new(&["seed", "stage", "at step", "τ*", "‖grad‖", "cond(grad)"]);
    for (seed, s) in &stages {
        table.row(&[
            seed.to_string(),
            s.stage.to_string(),
            s.at_step.to_string(),
            s.tau_star.to_string(),
            format!("{:.4}", s.grad_norm),
            format!("{:.1}", s.grad_cond),
        ]);
    }
    let text = format!(
        "Fig 12 — factors in the optimal FF step count (medical, {model}, {n_seeds} seeds)\n\n{}\n\
         Pearson(‖grad‖, τ*)   = {r_norm:+.3}   (12a)\n\
         Pearson(cond, τ*)     = {r_cond:+.3}   (12b)\n\
         Pearson(step, τ*)     = {r_step:+.3}   (the confounder)\n\n\
         paper reading: both factors correlate with τ* but neither adds\n\
         predictive power beyond the training timestep.\n",
        table.render()
    );
    write_report(&ctx.reports_dir, "fig12", &json, &text)
}
