//! Fig 12 (Appendix B): potential predictors of τ* — the norm (12a) and
//! condition number (12b) of the gradients right before each FF stage.
//! The paper finds both correlate with τ* but only through the confounder
//! of training time.

use anyhow::Result;

use crate::config::FfConfig;
use crate::experiments::common::run_config;
use crate::experiments::ExpContext;
use crate::metrics::{write_report, TextTable};
use crate::train::pretrain::ensure_pretrained;
use crate::train::trainer::{StopRule, Trainer};
use crate::util::json::Json;

/// Pearson correlation.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if n < 2.0 {
        return f64::NAN;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt() + 1e-300)
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny"; // paper: Pythia-1.4B, medical task
    let artifact = format!("{model}_lora_r8");
    let base = ensure_pretrained(&ctx.rt, &ctx.artifacts_root, model, None)?;
    let mut cfg = run_config(ctx, &artifact, "medical", FfConfig::default())?;
    cfg.max_steps = if ctx.scale.full { 120 } else { 60 };
    let max_steps = cfg.max_steps;
    let mut t = Trainer::new(&ctx.rt, &ctx.artifacts_root, cfg, Some(&base))?;
    t.run(&StopRule::MaxSteps(max_steps))?;

    let stages = &t.ffc.stages;
    let taus: Vec<f64> = stages.iter().map(|s| s.tau_star as f64).collect();
    let norms: Vec<f64> = stages.iter().map(|s| s.grad_norm).collect();
    let conds: Vec<f64> = stages.iter().map(|s| s.grad_cond).collect();
    let steps: Vec<f64> = stages.iter().map(|s| s.at_step as f64).collect();

    let r_norm = pearson(&norms, &taus);
    let r_cond = pearson(&conds, &taus);
    let r_step = pearson(&steps, &taus);

    let rows: Vec<Json> = stages
        .iter()
        .map(|s| {
            Json::obj()
                .set("stage", s.stage)
                .set("at_step", s.at_step)
                .set("tau_star", s.tau_star)
                .set("grad_norm", s.grad_norm)
                .set("grad_cond", s.grad_cond)
        })
        .collect();
    let json = Json::obj()
        .set("id", "fig12")
        .set("stages", Json::Arr(rows))
        .set("pearson_norm_tau", r_norm)
        .set("pearson_cond_tau", r_cond)
        .set("pearson_step_tau", r_step);

    let mut table = TextTable::new(&["stage", "at step", "τ*", "‖grad‖", "cond(grad)"]);
    for s in stages {
        table.row(&[
            s.stage.to_string(),
            s.at_step.to_string(),
            s.tau_star.to_string(),
            format!("{:.4}", s.grad_norm),
            format!("{:.1}", s.grad_cond),
        ]);
    }
    let text = format!(
        "Fig 12 — factors in the optimal FF step count (medical, {model})\n\n{}\n\
         Pearson(‖grad‖, τ*)   = {r_norm:+.3}   (12a)\n\
         Pearson(cond, τ*)     = {r_cond:+.3}   (12b)\n\
         Pearson(step, τ*)     = {r_step:+.3}   (the confounder)\n\n\
         paper reading: both factors correlate with τ* but neither adds\n\
         predictive power beyond the training timestep.\n",
        table.render()
    );
    write_report(&ctx.reports_dir, "fig12", &json, &text)
}
