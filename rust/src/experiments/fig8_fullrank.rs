//! Fig 8: Fast Forward fails for full-rank standard finetuning even when
//! restricted to the attention matrices — "each time we Fast Forward,
//! loss increases immediately at the first simulated step" (τ* = 0).

use anyhow::Result;

use crate::config::FfConfig;
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::ff::controller::FfDecision;
use crate::metrics::write_report;
use crate::util::json::Json;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny";
    let base = ctx.pretrained(model)?;

    let mut report_rows = Vec::new();
    let mut stages_summary = Vec::new();
    for (label, artifact) in [
        ("full_attn", format!("{model}_full_attn")),
        ("lora_r8 (control)", format!("{model}_lora_r8")),
    ] {
        let mut cfg = run_config(ctx, &artifact, "medical", FfConfig::default())?;
        // Each mode runs at its own well-tuned operating point, as in the
        // paper: full-rank attention trains fastest around lr 1.2e-2 on
        // this substrate (found by sweep — see EXPERIMENTS.md fig8 notes);
        // at that point its Adam steps reach the curvature scale and
        // extrapolation dies, which is the effect under test.
        if label.starts_with("full") {
            cfg.lr = 1.2e-2;
        }
        let steps = if ctx.scale.full { 40 } else { 24 };
        let mut t = trainer_for(ctx, cfg, Some(base.as_ref()))?;
        while t.adam_steps() < steps {
            match t.ffc.next() {
                FfDecision::Sgd => {
                    t.sgd_step()?;
                }
                FfDecision::FastForward => {
                    t.ff_stage()?;
                }
            }
        }
        let stages = &t.ffc.stages;
        let n = stages.len().max(1);
        let zero = stages.iter().filter(|s| s.tau_star == 0).count();
        let mean_tau =
            stages.iter().map(|s| s.tau_star as f64).sum::<f64>() / n as f64;
        stages_summary.push((label.to_string(), zero, stages.len(), mean_tau));
        report_rows.push(
            Json::obj()
                .set("mode", label)
                .set("stages", stages.len())
                .set("stages_tau_zero", zero)
                .set("mean_tau", mean_tau)
                .set(
                    "taus",
                    Json::Arr(stages.iter().map(|s| Json::from(s.tau_star as i64)).collect()),
                ),
        );
    }

    let json = Json::obj().set("id", "fig8").set("rows", Json::Arr(report_rows));
    let mut text = String::from(
        "Fig 8 — full-rank attention-only finetuning: FF stages die at τ=0\n\n",
    );
    for (label, zero, total, mean) in &stages_summary {
        text.push_str(&format!(
            "  {label:<18} {zero}/{total} stages rejected at the first simulated step; mean τ* = {mean:.2}\n"
        ));
    }
    let full = &stages_summary[0];
    let lora = &stages_summary[1];
    // Reproduction criterion: full-rank stages fizzle (mean τ* ≤ 1, i.e.
    // the search dies at or immediately after the first simulated step)
    // while low-rank extrapolates several steps.
    let reproduced = full.3 <= 1.5 && lora.3 > full.3;
    text.push_str(&format!(
        "\npaper reading: at full rank even one simulated step increases loss,\n\
         while low-rank FF extrapolates productively — {}\n",
        if reproduced { "reproduced" } else { "NOT reproduced on this substrate" }
    ));
    write_report(&ctx.reports_dir, "fig8", &json, &text)
}
