//! FF policy × optimizer backend × submission-mode grid (PR 10).
//!
//! The paper's protocol fixes one trigger rule (every `T_interval` Adam
//! steps, §3) and one optimizer (Adam). This harness sweeps the pluggable
//! pieces against each other: every [`FfPolicyKind`] (interval /
//! loss-slope / cosine) crossed with every [`OptimBackend`] (plain Adam
//! vs the LoFT-style moment-realigning variant), each cell run twice —
//! once as a normal **batch** queue submission racing to the plain-Adam
//! target loss, and once as a **streaming** submission
//! ([`RunQueue::submit_stream`]) whose tenant feeds the same number of
//! examples in chunks. Per cell the report records optimizer + simulated
//! steps, chargeable FLOPs, and host↔device bytes; the streaming twin
//! additionally records whether it stayed bit-identical to its batch
//! sibling (same trajectory, only the arrival pattern differs).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::{FfConfig, FfPolicyKind, OptimBackend};
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::metrics::{write_report, TextTable};
use crate::sched::{join_all, ArtifactCache, RunOutput, RunQueue, RunSpec};
use crate::train::trainer::StopRule;
use crate::util::json::Json;

/// One (policy, backend) cell's spec with the given stop rule. Identical
/// config across the cell's batch and streaming twins — only the stop
/// rule (target-loss race vs fed-examples bound) differs.
fn cell_spec(
    ctx: &ExpContext,
    artifact: &str,
    base: &Arc<std::collections::BTreeMap<String, crate::model::tensor::Tensor>>,
    kind: FfPolicyKind,
    backend: OptimBackend,
    stop: StopRule,
) -> Result<RunSpec> {
    let mut cfg = run_config(ctx, artifact, "medical", FfConfig {
        policy: kind,
        ..FfConfig::default()
    })?;
    cfg.backend = backend;
    Ok(RunSpec {
        label: format!("{}/{}", kind.as_str(), backend.as_str()),
        cfg,
        stop,
        base: Some(Arc::clone(base)),
        drain_interval: None,
    })
}

fn row_json(
    policy: FfPolicyKind,
    backend: OptimBackend,
    mode: &str,
    out: &RunOutput,
) -> Json {
    let t = &out.summary.transfers;
    Json::obj()
        .set("policy", policy.as_str())
        .set("backend", backend.as_str())
        .set("mode", mode)
        .set("adam_steps", out.summary.adam_steps)
        .set("sim_steps", out.summary.sim_steps)
        .set("flops", out.summary.flops.total() as f64)
        .set("uploaded_bytes", t.uploaded_bytes as f64)
        .set("downloaded_bytes", t.downloaded_bytes as f64)
        .set("donated_bytes", t.donated_bytes as f64)
        .set("ff_stages", out.stages.len())
        .set("final_loss", Json::num_or_null(out.summary.final_test_loss as f64))
        .set("reached_target", out.summary.reached_target)
}

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny"; // the sweep is about scheduling, not scale
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;

    // Target: the §4 baseline — plain Adam (interval policy is irrelevant
    // with FF off), full epoch budget, direct trainer.
    let cfg_b = run_config(ctx, &artifact, "medical",
        FfConfig { enabled: false, ..FfConfig::default() })?;
    let budget = cfg_b.max_steps;
    let global_batch = cfg_b.global_batch;
    let mut bt = trainer_for(ctx, cfg_b, Some(base.as_ref()))?;
    let baseline = bt.run(&StopRule::MaxSteps(budget))?;
    drop(bt);
    let target = baseline.final_test_loss;
    let eps = if ctx.scale.full { 1e-3 } else { 3e-3 };
    crate::info!("[policies] plain-Adam target loss {target:.4} after {budget} steps");

    let mut cells: Vec<(FfPolicyKind, OptimBackend)> = Vec::new();
    for kind in FfPolicyKind::ALL {
        for backend in [OptimBackend::Adam, OptimBackend::Loft] {
            cells.push((kind, backend));
        }
    }

    // Every cell goes through the serving-shaped scheduler: batch legs as
    // plain queue submissions, streaming legs via `submit_stream`.
    let cache = Arc::new(ArtifactCache::new(ctx.artifacts_root.clone()));
    let q = RunQueue::new(ctx.jobs);

    // Wave 1 — batch legs, fanned out: race each policy/backend pair to
    // the baseline's target loss.
    let mut handles = Vec::new();
    for &(kind, backend) in &cells {
        let spec = cell_spec(ctx, &artifact, &base, kind, backend, StopRule::TargetLoss {
            target,
            eps,
            eval_every: ctx.scale.eval_every,
            max_steps: budget * 2,
        })?;
        handles.push(q.submit_run(&ctx.rt, &cache, spec, 0, "policy-grid")?);
    }
    let mut batch = Vec::with_capacity(cells.len());
    for (r, &(kind, backend)) in join_all(handles)?.into_iter().zip(&cells) {
        batch.push(r.done().ok_or_else(|| {
            anyhow!("batch cell {}/{} was cancelled", kind.as_str(), backend.as_str())
        })?);
    }

    // Wave 2 — streaming twins: same config, but the data arrives in
    // chunks through the tenant-held StreamHandle. Each twin's example
    // budget mirrors the steps its batch sibling actually took, so the
    // two trajectories are comparable step for step.
    let mut stream_handles = Vec::new();
    for (out, &(kind, backend)) in batch.iter().zip(&cells) {
        let steps = out.summary.adam_steps.max(1);
        let spec =
            cell_spec(ctx, &artifact, &base, kind, backend, StopRule::MaxSteps(steps))?;
        let (h, stream) = q.submit_stream(&ctx.rt, &cache, spec, 0, "policy-grid")?;
        // Three uneven chunks, then finish — enough to exercise the
        // starved-hold → feed → resume path without pretending to be a
        // real ingestion pipeline.
        let total = (steps * global_batch) as u64;
        let chunk = (total / 3).max(1);
        let mut fed = 0u64;
        while fed < total {
            let n = chunk.min(total - fed);
            stream.feed(n);
            fed += n;
        }
        stream.finish();
        stream_handles.push(h);
    }
    let mut streamed = Vec::with_capacity(cells.len());
    for (r, &(kind, backend)) in join_all(stream_handles)?.into_iter().zip(&cells) {
        streamed.push(r.done().ok_or_else(|| {
            anyhow!("stream cell {}/{} was cancelled", kind.as_str(), backend.as_str())
        })?);
    }

    // Report: one row per (cell, mode); streaming rows carry the
    // bit-identity verdict against their batch sibling.
    let mut rows = Vec::new();
    let mut table = TextTable::new(&[
        "policy", "backend", "mode", "steps (adam+sim)", "MFLOPs", "MB moved", "loss", "note",
    ]);
    for (i, &(kind, backend)) in cells.iter().enumerate() {
        for (mode, out) in [("batch", &batch[i]), ("stream", &streamed[i])] {
            let mut row = row_json(kind, backend, mode, out);
            let note = if mode == "batch" {
                if out.summary.reached_target { "target met" } else { "budget hit" }.to_string()
            } else {
                let same = batch[i].bit_identical(out);
                row = row.set("matches_batch", same);
                if same { "bit==batch".to_string() } else { "DIVERGED from batch".to_string() }
            };
            let t = &out.summary.transfers;
            table.row(&[
                kind.as_str().to_string(),
                backend.as_str().to_string(),
                mode.to_string(),
                format!("{}+{}", out.summary.adam_steps, out.summary.sim_steps),
                format!("{:.1}", out.summary.flops.total() as f64 / 1e6),
                format!("{:.2}", (t.uploaded_bytes + t.downloaded_bytes) as f64 / 1e6),
                format!("{:.4}", out.summary.final_test_loss),
                note,
            ]);
            rows.push(row);
        }
    }

    let json = Json::obj()
        .set("id", "policies")
        .set("model", model)
        .set("task", "medical")
        .set("target_loss", Json::num_or_null(target as f64))
        .set("baseline_steps", budget)
        .set("rows", Json::Arr(rows));
    let text = format!(
        "FF policies × optimizer backends × {{batch, streaming}} (ff-tiny/medical)\n\
         plain-Adam target loss {target:.4} after {budget} steps; batch legs race the\n\
         target, streaming twins replay the same step budget from chunked feeds\n\n{}",
        table.render()
    );
    write_report(&ctx.reports_dir, "policies", &json, &text)
}
