//! Fig 5: test loss on the plane intersecting the pretrained model W0 and
//! the two finetuned models W_SGD (plain Adam) and W_FF (Fast Forward).
//! The paper reads this plane as "roughly convex, with FF finding a
//! flatter point central to its basin".

use anyhow::Result;

use crate::analysis::plane::{plane_grid, PlaneBasis};
use crate::config::FfConfig;
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::metrics::write_report;
use crate::train::trainer::StopRule;
use crate::util::json::Json;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny";
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;

    // Train the two anchors on the medical task.
    let cfg_sgd = run_config(ctx, &artifact, "medical",
        FfConfig { enabled: false, ..FfConfig::default() })?;
    let steps = cfg_sgd.max_steps;
    let mut t_sgd = trainer_for(ctx, cfg_sgd, Some(base.as_ref()))?;
    t_sgd.run(&StopRule::MaxSteps(steps))?;

    let cfg_ff = run_config(ctx, &artifact, "medical", FfConfig::default())?;
    let mut t_ff = trainer_for(ctx, cfg_ff, Some(base.as_ref()))?;
    t_ff.run(&StopRule::MaxSteps(steps))?;

    let w0 = t_sgd.w0_trainables.clone();
    let w_sgd = t_sgd.trainables()?;
    let w_ff = t_ff.trainables()?;
    let basis = PlaneBasis::new(&w0, &w_sgd, &w_ff)?;

    // Grid in plane coordinates (units of ‖W_FF − W0‖, paper's axis scale).
    let ticks: Vec<f64> = (-2..=6).map(|i| i as f64 * 0.33).collect();
    let pts = plane_grid(&basis, &ticks, &ticks, |w| t_ff.eval_test_at(w))?;

    let rows: Vec<Json> = pts
        .iter()
        .map(|p| {
            Json::obj()
                .set("alpha", p.alpha)
                .set("beta", p.beta)
                .set("loss", p.loss as f64)
        })
        .collect();
    let json = Json::obj()
        .set("id", "fig5")
        .set("unit_norm", basis.unit)
        .set("sgd_coords", vec![basis.sgd_coords.0, basis.sgd_coords.1])
        .set("ff_coords", vec![basis.ff_coords.0, basis.ff_coords.1])
        .set("grid", Json::Arr(rows));

    // ASCII heat map: rows = β (descending), cols = α.
    let mut text = String::from(
        "Fig 5 — test loss on the plane through W0 (origin), W_SGD, W_FF\n\
         axis unit = ‖W_FF − W0‖; darker glyph = lower loss\n\n",
    );
    let lo = pts.iter().map(|p| p.loss).fold(f32::INFINITY, f32::min);
    let hi = pts.iter().map(|p| p.loss).fold(f32::NEG_INFINITY, f32::max);
    let glyphs = ['@', '#', '+', '-', '.', ' '];
    let n = ticks.len();
    for (bi, b) in ticks.iter().enumerate().rev() {
        let mut line = format!("β={b:+.2} ");
        for ai in 0..n {
            let p = &pts[bi * n + ai];
            let t = ((p.loss - lo) / (hi - lo + 1e-9)).clamp(0.0, 1.0);
            let g = glyphs[(t * (glyphs.len() - 1) as f32).round() as usize];
            line.push(g);
            line.push(g);
        }
        text.push_str(&line);
        text.push('\n');
    }
    text.push_str(&format!(
        "\nanchors: W0 at (0,0); W_SGD at ({:.2},{:.2}); W_FF at ({:.2},{:.2})\n\
         loss range [{lo:.4}, {hi:.4}]\n\
         paper reading: surface roughly convex on this plane; FF travels a\n\
         similar distance but sits flatter/more central in the basin.\n",
        basis.sgd_coords.0, basis.sgd_coords.1, basis.ff_coords.0, basis.ff_coords.1
    ));
    write_report(&ctx.reports_dir, "fig5", &json, &text)
}
