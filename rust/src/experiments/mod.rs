//! Experiment harnesses: one module per paper figure/table (see DESIGN.md
//! experiment index). Every harness writes `reports/<id>.{json,txt}` with
//! the same rows/series the paper plots, and EXPERIMENTS.md records
//! paper-vs-measured.

pub mod common;
pub mod convergence;
pub mod fig10_convexity;
pub mod fig11_tau;
pub mod fig12_factors;
pub mod fig13_consistency;
pub mod fig14_interval;
pub mod fig2_flops;
pub mod fig4_curves;
pub mod fig5_plane;
pub mod fig6_cosine;
pub mod fig7_rank;
pub mod fig8_fullrank;
pub mod qa_benchmark;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::Result;

use crate::model::tensor::Tensor;
use crate::runtime::Runtime;
use crate::sched::{ArtifactCache, WorkerPool};
use crate::train::pretrain::ensure_pretrained;

/// Scale knobs: `quick` (default; minutes on one core) vs `full`
/// (the complete model grid and 5-epoch protocol).
#[derive(Debug, Clone)]
pub struct Scale {
    pub full: bool,
    /// Baseline epochs (paper: 5).
    pub epochs: usize,
    /// Models in grid experiments.
    pub models: Vec<String>,
    /// Held-out test examples used for target matching (paper: 1000).
    pub test_examples: usize,
    /// Test-loss check cadence for the FF run (adam steps).
    pub eval_every: usize,
}

impl Scale {
    pub fn quick() -> Scale {
        Scale {
            full: false,
            epochs: 2,
            models: vec!["ff-tiny".into(), "ff-small".into()],
            test_examples: 128,
            eval_every: 4,
        }
    }

    pub fn full() -> Scale {
        Scale {
            full: true,
            epochs: 5,
            models: vec!["ff-tiny".into(), "ff-small".into(), "ff-medium".into(), "ff-large".into()],
            test_examples: 512,
            eval_every: 4,
        }
    }
}

/// One pretrained parameter map, shared read-only across harness cells.
type W0Map = Arc<BTreeMap<String, Tensor>>;
/// Per-model cache slot: locked independently of the map and of every
/// other model's slot, so one model's first-touch build never blocks
/// another model's *cached read*. (First-touch builds of different
/// models still serialize on the process-wide `PRETRAIN_BUILD` lock
/// inside `ensure_pretrained` — deliberately, for determinism.)
type W0Slot = Arc<Mutex<Option<W0Map>>>;

pub struct ExpContext {
    pub rt: Arc<Runtime>,
    pub artifacts_root: PathBuf,
    /// Shared per-key `Arc<Artifact>`s: concurrent harness cells over the
    /// same artifact reuse one compiled program set
    /// (`experiments::common::trainer_for`).
    pub artifacts: ArtifactCache,
    pub reports_dir: PathBuf,
    pub scale: Scale,
    /// Effective worker width for grid-shaped harnesses (`--jobs N`;
    /// 1 = inline; always 1 in builds without the `xla-shared-client`
    /// feature — see `crate::sched`, §Thread-safety gate). Independent
    /// cells fan out through [`ExpContext::pool`]; results are
    /// submission-ordered, so reports are byte-identical at any level.
    pub jobs: usize,
    /// In-memory W0 cache: one `Arc`'d parameter map per model, so N
    /// concurrent cells share one copy instead of each re-reading and
    /// re-allocating the checkpoint from disk.
    w0: Mutex<BTreeMap<String, W0Slot>>,
}

impl ExpContext {
    pub fn new(
        artifacts_root: PathBuf,
        reports_dir: PathBuf,
        scale: Scale,
        jobs: usize,
    ) -> Result<ExpContext> {
        Ok(ExpContext {
            rt: Runtime::cpu()?,
            artifacts: ArtifactCache::new(artifacts_root.clone()),
            artifacts_root,
            reports_dir,
            scale,
            jobs: WorkerPool::new(jobs).jobs(),
            w0: Mutex::new(BTreeMap::new()),
        })
    }

    /// The worker pool grid harnesses fan out through.
    pub fn pool(&self) -> WorkerPool {
        WorkerPool::new(self.jobs)
    }

    /// The pretrained W0 for `model`, shared read-only across harness
    /// cells: built (or loaded from the checkpoint cache) on first touch,
    /// then served as one `Arc` per process. The map lock is held only to
    /// fetch the model's entry; the build runs under that *entry's* lock,
    /// so concurrent first-touches of the same model still build exactly
    /// once while other models' *cached reads* proceed unblocked.
    /// (First-touch *builds* of different models still serialize, on the
    /// process-wide lock inside `ensure_pretrained`.) A failed build
    /// leaves the slot empty, so a later caller retries.
    pub fn pretrained(&self, model: &str) -> Result<W0Map> {
        let entry: W0Slot = {
            let mut map = self.w0.lock().unwrap_or_else(PoisonError::into_inner);
            Arc::clone(map.entry(model.to_string()).or_default())
        };
        let mut slot = entry.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(b) = slot.as_ref() {
            return Ok(Arc::clone(b));
        }
        let built = Arc::new(ensure_pretrained(&self.rt, &self.artifacts_root, model, None)?);
        *slot = Some(Arc::clone(&built));
        Ok(built)
    }
}

pub type ExpFn = fn(&ExpContext) -> Result<()>;

/// Registry mapping experiment ids to harnesses (DESIGN.md experiment index).
pub fn registry() -> Vec<(&'static str, &'static str, ExpFn)> {
    vec![
        ("fig2a", "% FLOPs saved, FF-LoRA vs 5-epoch Adam (models × tasks)", fig2_flops::run_fig2a),
        ("fig2b", "% FLOPs saved, FF-DoRA vs 5-epoch Adam (models × tasks)", fig2_flops::run_fig2b),
        ("fig3", "% train time saved, FF-LoRA (models × tasks)", fig2_flops::run_fig3),
        ("fig4", "loss curve with SGD/FF markers vs vanilla (chat task)", fig4_curves::run_fig4),
        ("fig9", "fig4 across every grid model (Appendix A)", fig4_curves::run_fig9),
        ("fig5", "test-loss plane through W0, W_SGD, W_FF", fig5_plane::run),
        ("fig6", "gradient cosine similarity vs history, FF vs regular", fig6_cosine::run),
        ("fig7", "total FLOPs vs LoRA rank 1–64 (+ full-rank LoRA note)", fig7_rank::run),
        ("fig8", "full-rank attention-only FF fails (loss ↑ at τ=1)", fig8_fullrank::run),
        ("fig10", "val loss vs τ for the first FF stage (convexity)", fig10_convexity::run),
        ("fig11", "optimal τ* vs FF stage index", fig11_tau::run),
        ("fig12", "τ* vs gradient norm / condition number", fig12_factors::run),
        ("fig13", "batch-wise gradient consistency vs τ*", fig13_consistency::run),
        ("fig14", "τ* at 2nd FF stage vs T_interval 1–10 (Appendix D)", fig14_interval::run),
        ("convergence", "§5.1: FF to convergence — no long-term harm", convergence::run),
        ("qa", "§5.2: few-shot QA accuracy, FF vs regular", qa_benchmark::run),
    ]
}

pub fn find(id: &str) -> Option<(&'static str, &'static str, ExpFn)> {
    registry().into_iter().find(|(name, _, _)| *name == id)
}
