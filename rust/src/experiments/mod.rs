//! Experiment harnesses: one module per paper figure/table (see DESIGN.md
//! experiment index). Every harness writes `reports/<id>.{json,txt}` with
//! the same rows/series the paper plots, and EXPERIMENTS.md records
//! paper-vs-measured.

pub mod common;
pub mod convergence;
pub mod fig10_convexity;
pub mod fig11_tau;
pub mod fig12_factors;
pub mod fig13_consistency;
pub mod fig14_interval;
pub mod fig2_flops;
pub mod fig4_curves;
pub mod fig5_plane;
pub mod fig6_cosine;
pub mod fig7_rank;
pub mod fig8_fullrank;
pub mod policy_grid;
pub mod qa_benchmark;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, PoisonError, Weak};

use anyhow::{anyhow, Result};

use crate::model::tensor::Tensor;
use crate::runtime::Runtime;
use crate::sched::{ArtifactCache, RunQueue, WorkerPool};
use crate::train::pretrain::ensure_pretrained;

/// Scale knobs: `quick` (default; minutes on one core) vs `full`
/// (the complete model grid and 5-epoch protocol).
#[derive(Debug, Clone)]
pub struct Scale {
    pub full: bool,
    /// Baseline epochs (paper: 5).
    pub epochs: usize,
    /// Models in grid experiments.
    pub models: Vec<String>,
    /// Held-out test examples used for target matching (paper: 1000).
    pub test_examples: usize,
    /// Test-loss check cadence for the FF run (adam steps).
    pub eval_every: usize,
}

impl Scale {
    pub fn quick() -> Scale {
        Scale {
            full: false,
            epochs: 2,
            models: vec!["ff-tiny".into(), "ff-small".into()],
            test_examples: 128,
            eval_every: 4,
        }
    }

    pub fn full() -> Scale {
        Scale {
            full: true,
            epochs: 5,
            models: vec!["ff-tiny".into(), "ff-small".into(), "ff-medium".into(), "ff-large".into()],
            test_examples: 512,
            eval_every: 4,
        }
    }
}

/// One pretrained parameter map, shared read-only across harness cells.
type W0Map = Arc<BTreeMap<String, Tensor>>;
/// Per-model cache slot: locked independently of the map and of every
/// other model's slot, so one model's first-touch build never blocks
/// another model's *cached read*. (First-touch builds of different
/// models still serialize on the process-wide `PRETRAIN_BUILD` lock
/// inside `ensure_pretrained` — deliberately, for determinism.)
type W0Slot = Arc<Mutex<Option<W0Map>>>;

/// Shared body of the two cfg-split [`ExpContext::scatter`] variants —
/// they differ only in trait bounds (the thread-safety gate adds `Send`/
/// `Sync`), so the routing logic lives once here and cannot diverge
/// between builds.
macro_rules! scatter_via_queue {
    ($ctx:expr, $items:expr, $f:expr) => {{
        let q = RunQueue::new($ctx.jobs);
        let f = Arc::new($f);
        let mut handles = Vec::new();
        let mut index_of: BTreeMap<u64, usize> = BTreeMap::new();
        for (i, item) in $items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let h = q
                .submit("grid", 0, move |_| f(i, item))
                .expect("grid queue sets no capacity or quota: admission cannot fail");
            index_of.insert(h.seq(), i);
            handles.push(h);
        }
        // Stream outcomes in *completion* order and scatter them back
        // into submission-indexed slots. Fail-fast matches
        // `WorkerPool::scatter`: the first failed cell cancels every
        // sibling the moment it streams out — still-queued cells stop
        // outright; cells already mid-training finish (the grid closure
        // has no hook into its trainers' cancel flags) and their results
        // are discarded. The stream keeps draining after the cancel so
        // the queue is quiescent before returning. (Inline-drain builds
        // run cells inside `next_completion` itself — same loop, equally
        // fail-fast.)
        let mut slots: Vec<Option<_>> = (0..handles.len()).map(|_| None).collect();
        let mut first_err: Option<anyhow::Error> = None;
        let mut saw_cancelled = false;
        for c in q.completions() {
            let c = match c {
                Ok(c) => c,
                Err(e) => {
                    // the stream itself failed (shutdown race): cancel
                    // what's left and surface the error
                    for h in &handles {
                        h.cancel();
                    }
                    first_err.get_or_insert(e);
                    break;
                }
            };
            let i = index_of[&c.seq];
            match c.result {
                Ok(r) => match r.done() {
                    Some(x) => slots[i] = Some(x),
                    None => saw_cancelled = true,
                },
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e.context(format!("grid cell {i}")));
                        for h in &handles {
                            h.cancel();
                        }
                    }
                }
            }
        }
        if let Some(e) = first_err {
            Err(e)
        } else if saw_cancelled || slots.iter().any(|s| s.is_none()) {
            // no real failure, yet a cell was cancelled out from under
            // the grid — surface it rather than return a short vector
            Err(anyhow!("grid cell was cancelled before completing"))
        } else {
            Ok(slots.into_iter().flatten().collect())
        }
    }};
}

pub struct ExpContext {
    pub rt: Arc<Runtime>,
    pub artifacts_root: PathBuf,
    /// Shared per-key `Arc<Artifact>`s: concurrent harness cells over the
    /// same artifact reuse one compiled program set
    /// (`experiments::common::trainer_for`).
    pub artifacts: ArtifactCache,
    pub reports_dir: PathBuf,
    pub scale: Scale,
    /// Effective worker width for grid-shaped harnesses (`--jobs N`;
    /// 1 = inline; always 1 in builds without the `xla-shared-client`
    /// feature — see `crate::sched`, §Thread-safety gate). Independent
    /// cells fan out through [`ExpContext::pool`]; results are
    /// submission-ordered, so reports are byte-identical at any level.
    pub jobs: usize,
    /// Route grid fan-outs through the long-lived multi-tenant
    /// [`RunQueue`] instead of a per-batch [`WorkerPool`] (`--queue` on
    /// the experiment CLI) — exercises the serving-shaped scheduler path
    /// end-to-end (completion-order streaming included); returned
    /// results stay submission-ordered and byte-identical.
    pub use_queue: bool,
    /// In-memory W0 cache: one `Arc`'d parameter map per model, so N
    /// concurrent cells share one copy instead of each re-reading and
    /// re-allocating the checkpoint from disk.
    w0: Mutex<BTreeMap<String, W0Slot>>,
    /// Back-reference to the owning `Arc` (contexts are always
    /// `Arc`-owned, see [`ExpContext::new`]): what [`ExpContext::shared`]
    /// upgrades so queue-routed grid closures can own the context.
    self_ref: Weak<ExpContext>,
}

impl ExpContext {
    pub fn new(
        artifacts_root: PathBuf,
        reports_dir: PathBuf,
        scale: Scale,
        jobs: usize,
        use_queue: bool,
    ) -> Result<Arc<ExpContext>> {
        let rt = Runtime::cpu()?;
        Ok(Arc::new_cyclic(|weak| ExpContext {
            rt,
            artifacts: ArtifactCache::new(artifacts_root.clone()),
            artifacts_root,
            reports_dir,
            scale,
            jobs: WorkerPool::new(jobs).jobs(),
            use_queue,
            w0: Mutex::new(BTreeMap::new()),
            self_ref: weak.clone(),
        }))
    }

    /// The worker pool grid harnesses fan out through.
    pub fn pool(&self) -> WorkerPool {
        WorkerPool::new(self.jobs)
    }

    /// This context as an owning handle — always available because
    /// [`ExpContext::new`] only ever hands out `Arc`s. Queue-routed grid
    /// closures capture this (submissions to the long-lived [`RunQueue`]
    /// must own everything they touch).
    pub fn shared(&self) -> Arc<ExpContext> {
        self.self_ref.upgrade().expect("ExpContext is always Arc-owned")
    }

    /// Fan independent grid cells out: through the long-lived
    /// multi-tenant [`RunQueue`] when `--queue` is set (the
    /// serving-shaped path — submissions under tenant `"grid"`, equal
    /// priority, outcomes streamed in completion order and scattered
    /// back into submission-indexed slots), otherwise through a
    /// per-batch [`WorkerPool::scatter`]. Both routes return results in
    /// submission order, so reports are byte-identical whichever
    /// scheduler ran them. Queue submissions must own their captures
    /// (`'static`): closures clone [`ExpContext::shared`] instead of
    /// borrowing the context.
    #[cfg(feature = "xla-shared-client")]
    pub fn scatter<T, R, F>(&self, items: Vec<T>, f: F) -> Result<Vec<R>>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(usize, T) -> Result<R> + Send + Sync + 'static,
    {
        if !self.use_queue {
            return self.pool().scatter(items, f);
        }
        scatter_via_queue!(self, items, f)
    }

    /// Inline-drain variant (no `xla-shared-client` feature, hence no
    /// `Send` bounds): identical routing and ordering contract — see the
    /// gated variant above and `crate::sched`, §Thread-safety gate.
    #[cfg(not(feature = "xla-shared-client"))]
    pub fn scatter<T, R, F>(&self, items: Vec<T>, f: F) -> Result<Vec<R>>
    where
        T: 'static,
        R: 'static,
        F: Fn(usize, T) -> Result<R> + 'static,
    {
        if !self.use_queue {
            return self.pool().scatter(items, f);
        }
        scatter_via_queue!(self, items, f)
    }

    /// The pretrained W0 for `model`, shared read-only across harness
    /// cells: built (or loaded from the checkpoint cache) on first touch,
    /// then served as one `Arc` per process. The map lock is held only to
    /// fetch the model's entry; the build runs under that *entry's* lock,
    /// so concurrent first-touches of the same model still build exactly
    /// once while other models' *cached reads* proceed unblocked.
    /// (First-touch *builds* of different models still serialize, on the
    /// process-wide lock inside `ensure_pretrained`.) A failed build
    /// leaves the slot empty, so a later caller retries.
    pub fn pretrained(&self, model: &str) -> Result<W0Map> {
        let entry: W0Slot = {
            let mut map = self.w0.lock().unwrap_or_else(PoisonError::into_inner);
            Arc::clone(map.entry(model.to_string()).or_default())
        };
        let mut slot = entry.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(b) = slot.as_ref() {
            return Ok(Arc::clone(b));
        }
        let built = Arc::new(ensure_pretrained(&self.rt, &self.artifacts_root, model, None)?);
        *slot = Some(Arc::clone(&built));
        Ok(built)
    }
}

/// Build the cross-host grid manifest (`crate::sched::shard`) for this
/// scale: models × tasks × FF on/off, one [`crate::sched::shard::CellSpec`]
/// per run, with the same quick/full scaling the in-process grid harnesses
/// apply ([`common::run_config`]). Written by `experiment --emit-manifest`,
/// consumed by `--manifest F --shard i/N` on each host.
pub fn grid_manifest(
    scale: &Scale,
    name: &str,
) -> Result<crate::sched::shard::GridManifest> {
    use crate::config::presets;
    let mut cells = Vec::new();
    for model in &scale.models {
        for task in presets::TASKS.iter() {
            for ff in [false, true] {
                let artifact = common::artifact_key(model, "lora", task);
                let mut cfg = presets::train_config(&artifact, task, scale.epochs)?;
                if !scale.full {
                    cfg.train_examples /= 2;
                }
                let steps_per_epoch = cfg.train_examples / cfg.global_batch;
                cfg.max_steps = scale.epochs * steps_per_epoch;
                if !scale.full {
                    cfg.max_steps = cfg.max_steps.min(128);
                }
                cfg.test_examples = scale.test_examples;
                cfg.ff.enabled = ff;
                let index = cells.len();
                let label = format!("{model}/{task}/{}", if ff { "ff" } else { "base" });
                cells.push(crate::sched::shard::CellSpec { index, label, cfg });
            }
        }
    }
    Ok(crate::sched::shard::GridManifest { name: name.to_string(), cells })
}

pub type ExpFn = fn(&ExpContext) -> Result<()>;

/// Registry mapping experiment ids to harnesses (DESIGN.md experiment index).
pub fn registry() -> Vec<(&'static str, &'static str, ExpFn)> {
    vec![
        ("fig2a", "% FLOPs saved, FF-LoRA vs 5-epoch Adam (models × tasks)", fig2_flops::run_fig2a),
        ("fig2b", "% FLOPs saved, FF-DoRA vs 5-epoch Adam (models × tasks)", fig2_flops::run_fig2b),
        ("fig3", "% train time saved, FF-LoRA (models × tasks)", fig2_flops::run_fig3),
        ("fig4", "loss curve with SGD/FF markers vs vanilla (chat task)", fig4_curves::run_fig4),
        ("fig9", "fig4 across every grid model (Appendix A)", fig4_curves::run_fig9),
        ("fig5", "test-loss plane through W0, W_SGD, W_FF", fig5_plane::run),
        ("fig6", "gradient cosine similarity vs history, FF vs regular", fig6_cosine::run),
        ("fig7", "total FLOPs vs LoRA rank 1–64 (+ full-rank LoRA note)", fig7_rank::run),
        ("fig8", "full-rank attention-only FF fails (loss ↑ at τ=1)", fig8_fullrank::run),
        ("fig10", "val loss vs τ for the first FF stage (convexity)", fig10_convexity::run),
        ("fig11", "optimal τ* vs FF stage index", fig11_tau::run),
        ("fig12", "τ* vs gradient norm / condition number", fig12_factors::run),
        ("fig13", "batch-wise gradient consistency vs τ*", fig13_consistency::run),
        ("fig14", "τ* at 2nd FF stage vs T_interval 1–10 (Appendix D)", fig14_interval::run),
        ("convergence", "§5.1: FF to convergence — no long-term harm", convergence::run),
        ("qa", "§5.2: few-shot QA accuracy, FF vs regular", qa_benchmark::run),
        ("policies", "FF policies × optimizer backends × {batch, streaming} grid", policy_grid::run),
    ]
}

pub fn find(id: &str) -> Option<(&'static str, &'static str, ExpFn)> {
    registry().into_iter().find(|(name, _, _)| *name == id)
}
