//! §5.2: "FF does not harm performance on a standard benchmark" — two
//! medical-finetuned models (regular vs FF) scored on the synthetic
//! few-shot QA benchmark (PubMedQA substitute). Paper: 49.75% (regular)
//! vs 50.95% (FF) — i.e. parity; both near the 3-way-guessing floor
//! because the eval is out-of-distribution for next-token finetuning.

use anyhow::Result;

use crate::config::FfConfig;
use crate::eval::qa::{qa_accuracy, QaBenchmark};
use crate::experiments::common::{run_config, trainer_for};
use crate::experiments::ExpContext;
use crate::metrics::write_report;
use crate::train::trainer::StopRule;
use crate::util::json::Json;

pub fn run(ctx: &ExpContext) -> Result<()> {
    let model = "ff-tiny"; // paper: Llama-3 8B, medical task
    let artifact = format!("{model}_lora_r8");
    let base = ctx.pretrained(model)?;
    let n_items = if ctx.scale.full { 500 } else { 150 }; // paper: 1000

    // The two legs (regular vs FF finetune, then QA scoring) share nothing
    // but the read-only W0 — fan them out through the scheduler (pool, or
    // run queue under --queue). The result vector stays [regular, ff] by
    // submission order; the closure owns its captures.
    let cell_ctx = ctx.shared();
    let cell_artifact = artifact.clone();
    let cell_base = std::sync::Arc::clone(&base);
    let accs = ctx.scatter(vec![false, true], move |_i, ff_on| {
        let ctx = &cell_ctx;
        let ff = if ff_on {
            FfConfig::default()
        } else {
            FfConfig { enabled: false, ..FfConfig::default() }
        };
        let cfg = run_config(ctx, &cell_artifact, "medical", ff)?;
        let steps = cfg.max_steps;
        let seq_len = 64;
        let mut t = trainer_for(ctx, cfg, Some(cell_base.as_ref()))?;
        t.run(&StopRule::MaxSteps(steps))?;

        let bench = QaBenchmark::generate(512, seq_len, n_items, 0x9a);
        qa_accuracy(&bench, |ex| {
            // score through the trainer's eval machinery one example at a time
            t.eval_example_loss(ex)
        })
    })?;

    let json = Json::obj()
        .set("id", "qa")
        .set("regular_acc", accs[0])
        .set("ff_acc", accs[1])
        .set("n_items", n_items)
        .set("chance", 1.0 / 3.0);
    let text = format!(
        "§5.2 — few-shot QA accuracy (synthetic PubMedQA substitute, {n_items} items)\n\n\
         regular-trained: {:.2}%\n\
         FF-trained:      {:.2}%\n\
         3-way chance:    33.33%\n\
         paper: 49.75% vs 50.95% on PubMedQA — the claim under test is\n\
         *parity* between regular and FF training: |Δ| = {:.2} pts\n",
        100.0 * accs[0],
        100.0 * accs[1],
        100.0 * (accs[1] - accs[0]).abs()
    );
    write_report(&ctx.reports_dir, "qa", &json, &text)
}
