//! Pluggable Fast Forward trigger policies (ROADMAP "scenario diversity").
//!
//! The paper's closing analysis asks *when* to Fast Forward; this module
//! makes that a first-class axis. A [`FfPolicy`] owns every scheduling
//! counter and answers [`FfPolicy::next`]; the [`super::FfController`]
//! wrapper owns the stage history and the public trainer-facing surface.
//!
//! Three policies ship:
//!   * [`IntervalPolicy`] — the paper's fixed/adaptive T_interval
//!     controller, bit-identical to the pre-policy `FfController` (the
//!     legacy automaton is replicated in this module's tests and fuzzed
//!     against it; `selftest --policies` additionally proves seeded
//!     end-to-end runs bit-identical).
//!   * [`LossSlopePolicy`] — fire when the tiny-val loss slope over a
//!     window flattens below a threshold (SGD has stopped making fast
//!     progress, so extrapolation is worth probing).
//!   * [`CosinePolicy`] — fire when consecutive Δ_W directions' cosine
//!     similarity exceeds a threshold (paper Fig 6: FF works because
//!     successive low-rank updates align; once they do, jump).
//!
//! Policies declare which signals they need via the `wants_*` gates; the
//! trainer only pays for an extra tiny-val eval or a Δ_W download when the
//! active policy asks. `IntervalPolicy` asks for nothing, which is what
//! makes its bit-identity to the old controller structural rather than
//! incidental.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use super::controller::{FfDecision, FfStageStats};
use crate::config::{FfConfig, FfPolicyKind};
use crate::model::tensor::{cosine_similarity, Tensor};

/// A policy's schedule position, snapshotted for park/resume
/// (`train::checkpoint::ParkState`). Tagged per policy: restoring a
/// snapshot into a different policy kind is a hard error (the resume-time
/// `FfConfig` fingerprint check catches this earlier with a better
/// message; the tag is the last line of defense). Large state — the
/// cosine policy's previous Δ_W — rides separately through
/// [`FfPolicy::aux_state`] so the position stays a small header field.
#[derive(Debug, Clone, PartialEq)]
pub enum FfPosition {
    Interval {
        sgd_since_ff: usize,
        total_sgd: usize,
        interval: usize,
        consecutive_failures: usize,
        permanently_off: bool,
    },
    LossSlope {
        sgd_since_ff: usize,
        total_sgd: usize,
        consecutive_failures: usize,
        permanently_off: bool,
        /// Tiny-val losses observed since the last FF stage, oldest first.
        window: Vec<f32>,
    },
    Cosine {
        sgd_since_ff: usize,
        total_sgd: usize,
        consecutive_failures: usize,
        permanently_off: bool,
        /// Most recent consecutive-Δ_W cosine (valid iff `has_cosine`).
        last_cosine: f64,
        has_cosine: bool,
    },
}

impl Default for FfPosition {
    fn default() -> Self {
        FfPosition::Interval {
            sgd_since_ff: 0,
            total_sgd: 0,
            interval: 0,
            consecutive_failures: 0,
            permanently_off: false,
        }
    }
}

impl FfPosition {
    pub fn kind(&self) -> FfPolicyKind {
        match self {
            FfPosition::Interval { .. } => FfPolicyKind::Interval,
            FfPosition::LossSlope { .. } => FfPolicyKind::LossSlope,
            FfPosition::Cosine { .. } => FfPolicyKind::Cosine,
        }
    }

    pub fn total_sgd(&self) -> usize {
        match self {
            FfPosition::Interval { total_sgd, .. }
            | FfPosition::LossSlope { total_sgd, .. }
            | FfPosition::Cosine { total_sgd, .. } => *total_sgd,
        }
    }
}

/// The FF trigger contract. Implementations own *when* to Fast Forward;
/// the trainer owns *how* (line search over Δ_W).
///
/// Observation gates (`wants_val_loss` / `wants_delta`) default to off:
/// a policy that never asks imposes zero extra evals or transfers on the
/// step loop. The trainer queries the gates each SGD step and feeds only
/// the requested signals.
pub trait FfPolicy: std::fmt::Debug + Send {
    /// Decide the next action from the current position.
    fn next(&self) -> FfDecision;
    /// Record a completed SGD step.
    fn on_sgd_step(&mut self);
    /// Record a completed FF stage (applies the §5.1 convergence rule).
    fn on_ff_stage(&mut self, stats: &FfStageStats);
    /// Snapshot the schedule position for park/resume.
    fn position(&self) -> FfPosition;
    /// Restore a snapshot. Fails on a policy-kind mismatch; clamps any
    /// config-bounded field (e.g. the interval) into the *current*
    /// config's legal range.
    fn restore_position(&mut self, p: &FfPosition) -> Result<()>;
    /// Current nominal SGD interval between stages (reporting only for
    /// non-interval policies).
    fn interval(&self) -> usize;
    /// §5.1 convergence rule has permanently disabled FF.
    fn is_permanently_off(&self) -> bool;

    /// Wants a tiny-val loss after each SGD step.
    fn wants_val_loss(&self) -> bool {
        false
    }
    /// Wants the Δ_W of each SGD step.
    fn wants_delta(&self) -> bool {
        false
    }
    fn observe_val_loss(&mut self, _loss: f32) {}
    fn observe_delta(&mut self, _delta: &[Tensor]) {}

    /// Bulk tensor state to park alongside the position (checkpoint
    /// payload group `fa/`), e.g. the cosine policy's previous Δ_W.
    fn aux_state(&self) -> Vec<Tensor> {
        Vec::new()
    }
    fn restore_aux(&mut self, _aux: &[Tensor]) -> Result<()> {
        Ok(())
    }
}

/// Build the policy selected by `cfg.policy`.
pub fn make_policy(cfg: &FfConfig) -> Box<dyn FfPolicy> {
    match cfg.policy {
        FfPolicyKind::Interval => Box::new(IntervalPolicy::new(cfg.clone())),
        FfPolicyKind::LossSlope => Box::new(LossSlopePolicy::new(cfg.clone())),
        FfPolicyKind::Cosine => Box::new(CosinePolicy::new(cfg.clone())),
    }
}

/// §5.1 convergence rule, shared by every policy: `patience` consecutive
/// stages with τ* = 0 permanently disable FF; any productive stage resets
/// the streak.
fn apply_patience(
    cfg: &FfConfig,
    stats: &FfStageStats,
    consecutive_failures: &mut usize,
    permanently_off: &mut bool,
) {
    if stats.tau_star == 0 {
        *consecutive_failures += 1;
        if let Some(patience) = cfg.convergence_patience {
            if *consecutive_failures >= patience {
                *permanently_off = true;
                crate::info!(
                    "FF permanently off after {} consecutive empty stages (§5.1 rule)",
                    *consecutive_failures
                );
            }
        }
    } else {
        *consecutive_failures = 0;
    }
}

// ---------------------------------------------------------------------------
// IntervalPolicy — the paper's controller, verbatim.
// ---------------------------------------------------------------------------

/// The paper Fig 1 schedule: warmup, then FF every `interval` SGD steps,
/// with the §7-future-work adaptive interval and the §5.1 convergence
/// rule. Decision logic is copied verbatim from the pre-policy
/// `FfController`; the fuzz test below drives it against a replica of the
/// legacy automaton to keep it bit-identical.
#[derive(Debug)]
pub struct IntervalPolicy {
    cfg: FfConfig,
    sgd_since_ff: usize,
    total_sgd: usize,
    /// Current interval (== cfg.t_interval unless adaptive).
    interval: usize,
    consecutive_failures: usize,
    permanently_off: bool,
}

impl IntervalPolicy {
    pub fn new(cfg: FfConfig) -> IntervalPolicy {
        let interval = cfg.t_interval;
        IntervalPolicy {
            cfg,
            sgd_since_ff: 0,
            total_sgd: 0,
            interval,
            consecutive_failures: 0,
            permanently_off: false,
        }
    }
}

impl FfPolicy for IntervalPolicy {
    /// FF requires: enabled, not disabled by the convergence rule, warmup
    /// complete, a full interval of SGD steps since the last stage (so
    /// Δ_W reflects a *recent* optimizer step).
    fn next(&self) -> FfDecision {
        if !self.cfg.enabled || self.permanently_off {
            return FfDecision::Sgd;
        }
        if self.total_sgd < self.cfg.warmup_steps {
            return FfDecision::Sgd;
        }
        if self.sgd_since_ff >= self.interval {
            FfDecision::FastForward
        } else {
            FfDecision::Sgd
        }
    }

    fn on_sgd_step(&mut self) {
        self.total_sgd += 1;
        self.sgd_since_ff += 1;
    }

    fn on_ff_stage(&mut self, stats: &FfStageStats) {
        self.sgd_since_ff = 0;
        apply_patience(&self.cfg, stats, &mut self.consecutive_failures, &mut self.permanently_off);
        if self.cfg.adaptive_interval {
            // §7 future work: productive stages → FF sooner; fizzles →
            // later. The interval is clamped to [1, 4·t_interval]: it can
            // never shrink below one SGD step (Δ_W must reflect at least
            // one fresh optimizer step between stages) and growth is
            // capped so a long fizzle streak cannot push FF out of a run
            // entirely before the §5.1 convergence rule gets to decide.
            if stats.tau_star >= 4 {
                self.interval = (self.interval.saturating_sub(1)).max(1);
            } else if stats.tau_star == 0 {
                self.interval = (self.interval + 2).min(4 * self.cfg.t_interval);
            }
        }
    }

    fn position(&self) -> FfPosition {
        FfPosition::Interval {
            sgd_since_ff: self.sgd_since_ff,
            total_sgd: self.total_sgd,
            interval: self.interval,
            consecutive_failures: self.consecutive_failures,
            permanently_off: self.permanently_off,
        }
    }

    fn restore_position(&mut self, p: &FfPosition) -> Result<()> {
        let FfPosition::Interval {
            sgd_since_ff,
            total_sgd,
            interval,
            consecutive_failures,
            permanently_off,
        } = *p
        else {
            bail!("cannot restore a {:?} snapshot into an interval policy", p.kind());
        };
        self.sgd_since_ff = sgd_since_ff;
        self.total_sgd = total_sgd;
        // Clamp into the *current* config's legal range: a snapshot taken
        // under a different `t_interval` (legacy park files predate the
        // fingerprint check) must not run outside [1, 4·t_interval].
        self.interval = interval.clamp(1, (4 * self.cfg.t_interval).max(1));
        self.consecutive_failures = consecutive_failures;
        self.permanently_off = permanently_off;
        Ok(())
    }

    fn interval(&self) -> usize {
        self.interval
    }

    fn is_permanently_off(&self) -> bool {
        self.permanently_off
    }
}

// ---------------------------------------------------------------------------
// LossSlopePolicy — fire when the tiny-val loss curve flattens.
// ---------------------------------------------------------------------------

/// Fire FF when SGD progress stalls: after warmup, once `slope_window`
/// consecutive tiny-val losses show a per-step relative improvement below
/// `slope_threshold`, the next decision is FastForward. The window clears
/// on every FF stage so a fresh interval of real SGD evidence accumulates
/// before the next trigger.
#[derive(Debug)]
pub struct LossSlopePolicy {
    cfg: FfConfig,
    sgd_since_ff: usize,
    total_sgd: usize,
    consecutive_failures: usize,
    permanently_off: bool,
    /// Per-SGD-step tiny-val losses since the last stage, oldest first.
    window: VecDeque<f32>,
}

impl LossSlopePolicy {
    pub fn new(cfg: FfConfig) -> LossSlopePolicy {
        LossSlopePolicy {
            cfg,
            sgd_since_ff: 0,
            total_sgd: 0,
            consecutive_failures: 0,
            permanently_off: false,
            window: VecDeque::new(),
        }
    }

    /// A slope needs two points; treat degenerate configs as window 2.
    fn window_cap(&self) -> usize {
        self.cfg.slope_window.max(2)
    }

    /// Relative per-step improvement over the full window, or `None`
    /// until the window is full. Positive = still improving; at or below
    /// `slope_threshold` the curve has flattened (or worsened) and FF is
    /// worth probing.
    fn rel_slope(&self) -> Option<f32> {
        let cap = self.window_cap();
        if self.window.len() < cap {
            return None;
        }
        let first = *self.window.front().unwrap();
        let last = *self.window.back().unwrap();
        let denom = (cap - 1) as f32 * last.abs().max(1e-8);
        Some((first - last) / denom)
    }
}

impl FfPolicy for LossSlopePolicy {
    fn next(&self) -> FfDecision {
        if !self.cfg.enabled || self.permanently_off {
            return FfDecision::Sgd;
        }
        if self.total_sgd < self.cfg.warmup_steps || self.sgd_since_ff == 0 {
            return FfDecision::Sgd;
        }
        match self.rel_slope() {
            Some(slope) if slope < self.cfg.slope_threshold => FfDecision::FastForward,
            _ => FfDecision::Sgd,
        }
    }

    fn on_sgd_step(&mut self) {
        self.total_sgd += 1;
        self.sgd_since_ff += 1;
    }

    fn on_ff_stage(&mut self, stats: &FfStageStats) {
        self.sgd_since_ff = 0;
        self.window.clear();
        apply_patience(&self.cfg, stats, &mut self.consecutive_failures, &mut self.permanently_off);
    }

    fn position(&self) -> FfPosition {
        FfPosition::LossSlope {
            sgd_since_ff: self.sgd_since_ff,
            total_sgd: self.total_sgd,
            consecutive_failures: self.consecutive_failures,
            permanently_off: self.permanently_off,
            window: self.window.iter().copied().collect(),
        }
    }

    fn restore_position(&mut self, p: &FfPosition) -> Result<()> {
        let FfPosition::LossSlope {
            sgd_since_ff,
            total_sgd,
            consecutive_failures,
            permanently_off,
            ref window,
        } = *p
        else {
            bail!("cannot restore a {:?} snapshot into a loss-slope policy", p.kind());
        };
        self.sgd_since_ff = sgd_since_ff;
        self.total_sgd = total_sgd;
        self.consecutive_failures = consecutive_failures;
        self.permanently_off = permanently_off;
        self.window = window.iter().copied().collect();
        // Keep only the newest entries if the configured window shrank.
        while self.window.len() > self.window_cap() {
            self.window.pop_front();
        }
        Ok(())
    }

    fn interval(&self) -> usize {
        self.cfg.t_interval
    }

    fn is_permanently_off(&self) -> bool {
        self.permanently_off
    }

    fn wants_val_loss(&self) -> bool {
        self.cfg.enabled && !self.permanently_off
    }

    fn observe_val_loss(&mut self, loss: f32) {
        self.window.push_back(loss);
        while self.window.len() > self.window_cap() {
            self.window.pop_front();
        }
    }
}

// ---------------------------------------------------------------------------
// CosinePolicy — fire when consecutive Δ_W directions align.
// ---------------------------------------------------------------------------

/// Fire FF when successive optimizer steps agree on a direction: the
/// cosine similarity between the latest Δ_W and the previous one reaching
/// `cosine_threshold` is exactly the regime in which the paper's
/// line-search extrapolation pays off (Fig 6). Uses
/// [`crate::model::tensor::cosine_similarity`] over the
/// [`crate::optim::delta::DeltaTracker`]-style per-step deltas the trainer
/// feeds through [`FfPolicy::observe_delta`].
#[derive(Debug)]
pub struct CosinePolicy {
    cfg: FfConfig,
    sgd_since_ff: usize,
    total_sgd: usize,
    consecutive_failures: usize,
    permanently_off: bool,
    /// Δ_W of the previous SGD step (parked via `aux_state`).
    prev_delta: Option<Vec<Tensor>>,
    last_cosine: f64,
    has_cosine: bool,
}

impl CosinePolicy {
    pub fn new(cfg: FfConfig) -> CosinePolicy {
        CosinePolicy {
            cfg,
            sgd_since_ff: 0,
            total_sgd: 0,
            consecutive_failures: 0,
            permanently_off: false,
            prev_delta: None,
            last_cosine: 0.0,
            has_cosine: false,
        }
    }

    pub fn last_cosine(&self) -> Option<f64> {
        self.has_cosine.then_some(self.last_cosine)
    }
}

impl FfPolicy for CosinePolicy {
    fn next(&self) -> FfDecision {
        if !self.cfg.enabled || self.permanently_off {
            return FfDecision::Sgd;
        }
        if self.total_sgd < self.cfg.warmup_steps || self.sgd_since_ff == 0 {
            return FfDecision::Sgd;
        }
        if self.has_cosine && self.last_cosine >= self.cfg.cosine_threshold {
            FfDecision::FastForward
        } else {
            FfDecision::Sgd
        }
    }

    fn on_sgd_step(&mut self) {
        self.total_sgd += 1;
        self.sgd_since_ff += 1;
    }

    fn on_ff_stage(&mut self, stats: &FfStageStats) {
        self.sgd_since_ff = 0;
        // The stage jumped the weights: the pre-stage Δ_W no longer
        // describes the local direction. Start over.
        self.prev_delta = None;
        self.last_cosine = 0.0;
        self.has_cosine = false;
        apply_patience(&self.cfg, stats, &mut self.consecutive_failures, &mut self.permanently_off);
    }

    fn position(&self) -> FfPosition {
        FfPosition::Cosine {
            sgd_since_ff: self.sgd_since_ff,
            total_sgd: self.total_sgd,
            consecutive_failures: self.consecutive_failures,
            permanently_off: self.permanently_off,
            last_cosine: self.last_cosine,
            has_cosine: self.has_cosine,
        }
    }

    fn restore_position(&mut self, p: &FfPosition) -> Result<()> {
        let FfPosition::Cosine {
            sgd_since_ff,
            total_sgd,
            consecutive_failures,
            permanently_off,
            last_cosine,
            has_cosine,
        } = *p
        else {
            bail!("cannot restore a {:?} snapshot into a cosine policy", p.kind());
        };
        self.sgd_since_ff = sgd_since_ff;
        self.total_sgd = total_sgd;
        self.consecutive_failures = consecutive_failures;
        self.permanently_off = permanently_off;
        self.last_cosine = last_cosine;
        self.has_cosine = has_cosine;
        Ok(())
    }

    fn interval(&self) -> usize {
        self.cfg.t_interval
    }

    fn is_permanently_off(&self) -> bool {
        self.permanently_off
    }

    fn wants_delta(&self) -> bool {
        self.cfg.enabled && !self.permanently_off
    }

    fn observe_delta(&mut self, delta: &[Tensor]) {
        if let Some(prev) = &self.prev_delta {
            self.last_cosine = cosine_similarity(prev, delta);
            self.has_cosine = true;
        }
        self.prev_delta = Some(delta.to_vec());
    }

    fn aux_state(&self) -> Vec<Tensor> {
        self.prev_delta.clone().unwrap_or_default()
    }

    fn restore_aux(&mut self, aux: &[Tensor]) -> Result<()> {
        self.prev_delta = if aux.is_empty() { None } else { Some(aux.to_vec()) };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(stage: usize, tau: usize) -> FfStageStats {
        FfStageStats {
            stage,
            at_step: 0,
            tau_star: tau,
            probes: tau + 1,
            baseline_loss: 1.0,
            final_loss: 0.9,
            grad_norm: 0.0,
            grad_cond: 0.0,
        }
    }

    /// Verbatim replica of the pre-policy `FfController` decision
    /// automaton (PR ≤ 9), kept here as the bit-identity oracle for
    /// `IntervalPolicy`.
    struct LegacyController {
        cfg: FfConfig,
        sgd_since_ff: usize,
        total_sgd: usize,
        interval: usize,
        consecutive_failures: usize,
        permanently_off: bool,
    }

    impl LegacyController {
        fn new(cfg: FfConfig) -> LegacyController {
            let interval = cfg.t_interval;
            LegacyController {
                cfg,
                sgd_since_ff: 0,
                total_sgd: 0,
                interval,
                consecutive_failures: 0,
                permanently_off: false,
            }
        }

        fn next(&self) -> FfDecision {
            if !self.cfg.enabled || self.permanently_off {
                return FfDecision::Sgd;
            }
            if self.total_sgd < self.cfg.warmup_steps {
                return FfDecision::Sgd;
            }
            if self.sgd_since_ff >= self.interval {
                FfDecision::FastForward
            } else {
                FfDecision::Sgd
            }
        }

        fn on_sgd_step(&mut self) {
            self.total_sgd += 1;
            self.sgd_since_ff += 1;
        }

        fn on_ff_stage(&mut self, s: &FfStageStats) {
            self.sgd_since_ff = 0;
            if s.tau_star == 0 {
                self.consecutive_failures += 1;
                if let Some(p) = self.cfg.convergence_patience {
                    if self.consecutive_failures >= p {
                        self.permanently_off = true;
                    }
                }
            } else {
                self.consecutive_failures = 0;
            }
            if self.cfg.adaptive_interval {
                if s.tau_star >= 4 {
                    self.interval = (self.interval.saturating_sub(1)).max(1);
                } else if s.tau_star == 0 {
                    self.interval = (self.interval + 2).min(4 * self.cfg.t_interval);
                }
            }
        }
    }

    #[test]
    fn interval_policy_matches_legacy_controller_exhaustively() {
        // Fuzz the new policy against the legacy automaton over seeded
        // τ* sequences across every schedule-shaping config axis.
        let mut lcg = 0x2545F491_u64;
        let mut rand = move |m: usize| {
            lcg = lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((lcg >> 33) as usize) % m
        };
        for adaptive in [false, true] {
            for patience in [None, Some(2), Some(4)] {
                for t_interval in [1usize, 2, 5] {
                    let cfg = FfConfig {
                        t_interval,
                        warmup_steps: 3,
                        adaptive_interval: adaptive,
                        convergence_patience: patience,
                        ..FfConfig::default()
                    };
                    let mut legacy = LegacyController::new(cfg.clone());
                    let mut policy = IntervalPolicy::new(cfg);
                    for step in 0..400 {
                        assert_eq!(
                            legacy.next(),
                            policy.next(),
                            "diverged at step {step} (adaptive={adaptive}, patience={patience:?}, t={t_interval})"
                        );
                        if legacy.next() == FfDecision::FastForward {
                            let s = stats(step, rand(7));
                            legacy.on_ff_stage(&s);
                            policy.on_ff_stage(&s);
                            assert_eq!(legacy.interval, policy.interval());
                            assert_eq!(legacy.permanently_off, policy.is_permanently_off());
                        } else {
                            legacy.on_sgd_step();
                            policy.on_sgd_step();
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn interval_restore_clamps_into_current_config_range() {
        // A snapshot taken under t_interval=10 (interval grew to 40)
        // restored into a t_interval=2 policy must clamp to [1, 8].
        let mut p = IntervalPolicy::new(FfConfig { t_interval: 2, ..FfConfig::default() });
        p.restore_position(&FfPosition::Interval {
            sgd_since_ff: 1,
            total_sgd: 9,
            interval: 40,
            consecutive_failures: 0,
            permanently_off: false,
        })
        .unwrap();
        assert_eq!(p.interval(), 8);
        p.restore_position(&FfPosition::Interval {
            sgd_since_ff: 1,
            total_sgd: 9,
            interval: 0,
            consecutive_failures: 0,
            permanently_off: false,
        })
        .unwrap();
        assert_eq!(p.interval(), 1);
    }

    #[test]
    fn restore_rejects_cross_policy_snapshots() {
        let cfg = FfConfig::default();
        let slope_pos = LossSlopePolicy::new(cfg.clone()).position();
        let err = IntervalPolicy::new(cfg.clone()).restore_position(&slope_pos).unwrap_err();
        assert!(err.to_string().contains("interval policy"), "{err}");
        let interval_pos = IntervalPolicy::new(cfg.clone()).position();
        assert!(LossSlopePolicy::new(cfg.clone()).restore_position(&interval_pos).is_err());
        assert!(CosinePolicy::new(cfg).restore_position(&interval_pos).is_err());
    }

    fn slope_cfg() -> FfConfig {
        FfConfig {
            policy: FfPolicyKind::LossSlope,
            warmup_steps: 2,
            slope_window: 4,
            slope_threshold: 1e-2,
            ..FfConfig::default()
        }
    }

    #[test]
    fn loss_slope_fires_only_when_the_curve_flattens() {
        let mut p = LossSlopePolicy::new(slope_cfg());
        // Steeply improving losses: never fires even with a full window.
        for i in 0..6 {
            p.on_sgd_step();
            p.observe_val_loss(2.0 - 0.3 * i as f32);
            assert_eq!(p.next(), FfDecision::Sgd, "fired while improving at step {i}");
        }
        // Flat losses: the window refills with zero slope → fire.
        for _ in 0..4 {
            assert!(p.wants_val_loss());
            p.on_sgd_step();
            p.observe_val_loss(0.5);
        }
        assert_eq!(p.next(), FfDecision::FastForward);
        // A stage clears the window: needs fresh evidence before refiring.
        p.on_ff_stage(&stats(0, 3));
        assert_eq!(p.next(), FfDecision::Sgd);
    }

    #[test]
    fn loss_slope_respects_warmup_and_disabled() {
        let mut p = LossSlopePolicy::new(FfConfig { warmup_steps: 50, ..slope_cfg() });
        for _ in 0..10 {
            p.on_sgd_step();
            p.observe_val_loss(1.0);
        }
        assert_eq!(p.next(), FfDecision::Sgd, "warmup must gate the trigger");
        let mut off = LossSlopePolicy::new(FfConfig { enabled: false, ..slope_cfg() });
        assert!(!off.wants_val_loss(), "disabled policy must not request evals");
        for _ in 0..10 {
            off.on_sgd_step();
            off.observe_val_loss(1.0);
        }
        assert_eq!(off.next(), FfDecision::Sgd);
    }

    #[test]
    fn loss_slope_position_round_trips() {
        let mut a = LossSlopePolicy::new(slope_cfg());
        for i in 0..3 {
            a.on_sgd_step();
            a.observe_val_loss(1.0 - 0.1 * i as f32);
        }
        let pos = a.position();
        let mut b = LossSlopePolicy::new(slope_cfg());
        b.restore_position(&pos).unwrap();
        assert_eq!(b.position(), pos);
        // Identical observations from here on keep the automata in lock-step.
        for i in 0..8 {
            assert_eq!(a.next(), b.next(), "diverged at step {i}");
            a.on_sgd_step();
            b.on_sgd_step();
            a.observe_val_loss(0.5);
            b.observe_val_loss(0.5);
        }
        assert_eq!(a.position(), b.position());
    }

    fn cosine_cfg() -> FfConfig {
        FfConfig {
            policy: FfPolicyKind::Cosine,
            warmup_steps: 2,
            cosine_threshold: 0.9,
            ..FfConfig::default()
        }
    }

    fn delta(xs: &[f32]) -> Vec<Tensor> {
        vec![Tensor::from_vec(&[xs.len()], xs.to_vec())]
    }

    #[test]
    fn cosine_fires_on_aligned_deltas_only() {
        let mut p = CosinePolicy::new(cosine_cfg());
        p.on_sgd_step();
        p.observe_delta(&delta(&[1.0, 0.0]));
        p.on_sgd_step();
        // Orthogonal step: cosine 0 → keep stepping.
        p.observe_delta(&delta(&[0.0, 1.0]));
        assert_eq!(p.next(), FfDecision::Sgd);
        assert_eq!(p.last_cosine().unwrap(), 0.0);
        // Parallel step: cosine 1 → fire.
        p.on_sgd_step();
        p.observe_delta(&delta(&[0.0, 2.0]));
        assert_eq!(p.next(), FfDecision::FastForward);
        // A stage resets the direction memory.
        p.on_ff_stage(&stats(0, 2));
        assert!(p.last_cosine().is_none());
        assert_eq!(p.next(), FfDecision::Sgd);
    }

    #[test]
    fn cosine_position_and_aux_round_trip() {
        let mut a = CosinePolicy::new(cosine_cfg());
        a.on_sgd_step();
        a.observe_delta(&delta(&[1.0, 2.0]));
        a.on_sgd_step();
        a.observe_delta(&delta(&[1.0, 1.9]));
        let pos = a.position();
        let aux = a.aux_state();
        assert_eq!(aux.len(), 1, "prev Δ_W must park through aux_state");
        let mut b = CosinePolicy::new(cosine_cfg());
        b.restore_position(&pos).unwrap();
        b.restore_aux(&aux).unwrap();
        assert_eq!(b.position(), pos);
        // Same next observation → same cosine → same decisions.
        a.on_sgd_step();
        b.on_sgd_step();
        a.observe_delta(&delta(&[1.0, 1.95]));
        b.observe_delta(&delta(&[1.0, 1.95]));
        assert_eq!(a.next(), b.next());
        assert_eq!(a.position(), b.position());
    }

    #[test]
    fn patience_rule_is_shared_across_policies() {
        let cfg = FfConfig { convergence_patience: Some(2), ..cosine_cfg() };
        let mut p = CosinePolicy::new(cfg.clone());
        p.on_ff_stage(&stats(0, 0));
        assert!(!p.is_permanently_off());
        p.on_ff_stage(&stats(1, 0));
        assert!(p.is_permanently_off());
        assert!(!p.wants_delta(), "a dead policy must stop requesting Δ_W");
        let mut s = LossSlopePolicy::new(FfConfig { convergence_patience: Some(2), ..slope_cfg() });
        s.on_ff_stage(&stats(0, 0));
        s.on_ff_stage(&stats(1, 0));
        assert!(s.is_permanently_off());
        assert!(!s.wants_val_loss());
    }

    #[test]
    fn make_policy_dispatches_on_config() {
        for kind in FfPolicyKind::ALL {
            let cfg = FfConfig { policy: kind, ..FfConfig::default() };
            let p = make_policy(&cfg);
            assert_eq!(p.position().kind(), kind);
        }
    }
}
