//! The Fast Forward schedule controller (paper Fig 1):
//!
//! ```text
//!  warmup ─► SGD × T_interval ─► FF stage ─► SGD × T_interval ─► FF …
//! ```
//!
//! The controller owns *when* to Fast Forward; the trainer owns *how*
//! (line search over Δ_W). Since PR 10 the "when" is pluggable: the
//! controller is a thin wrapper holding the stage history and delegating
//! every scheduling decision to the [`FfPolicy`] selected by
//! `FfConfig::policy` (`super::policy` — interval, loss-slope, cosine).
//! The default [`super::policy::IntervalPolicy`] reproduces the pre-PR-10
//! controller bit-for-bit, including:
//!   * the §5.1 convergence rule — after `convergence_patience` consecutive
//!     FF stages with τ* = 0, Fast Forward is permanently disabled;
//!   * the §7-future-work adaptive interval — shrink T_interval while FF
//!     stages are productive, grow it when they fizzle (ablation bench).

use crate::config::FfConfig;
use crate::model::tensor::Tensor;

use super::policy::{make_policy, FfPolicy, FfPosition};

/// What the trainer should do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfDecision {
    /// Run a regular Adam SGD step.
    Sgd,
    /// Run a Fast Forward stage now.
    FastForward,
}

/// Outcome summary of one FF stage, fed back into the controller and kept
/// for the Fig 11/12/13/14 analyses.
#[derive(Debug, Clone)]
pub struct FfStageStats {
    /// Index of this stage (0-based) over the run.
    pub stage: usize,
    /// Adam step count when the stage ran.
    pub at_step: usize,
    pub tau_star: usize,
    pub probes: usize,
    pub baseline_loss: f32,
    pub final_loss: f32,
    /// ‖Δ_W‖ and gradient stats recorded just before the stage (Fig 12).
    pub grad_norm: f64,
    pub grad_cond: f64,
}

#[derive(Debug)]
pub struct FfController {
    policy: Box<dyn FfPolicy>,
    pub stages: Vec<FfStageStats>,
}

impl FfController {
    pub fn new(cfg: FfConfig) -> FfController {
        FfController { policy: make_policy(&cfg), stages: Vec::new() }
    }

    pub fn interval(&self) -> usize {
        self.policy.interval()
    }

    pub fn is_permanently_off(&self) -> bool {
        self.policy.is_permanently_off()
    }

    /// Decide the next action (delegates to the active policy).
    pub fn next(&self) -> FfDecision {
        self.policy.next()
    }

    /// Record a completed SGD step.
    pub fn on_sgd_step(&mut self) {
        self.policy.on_sgd_step();
    }

    /// Record a completed FF stage; the policy applies its convergence /
    /// adaptation rules, the controller keeps the history.
    pub fn on_ff_stage(&mut self, stats: FfStageStats) {
        self.policy.on_ff_stage(&stats);
        self.stages.push(stats);
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Snapshot the schedule position for park/resume.
    pub fn position(&self) -> FfPosition {
        self.policy.position()
    }

    /// Restore a snapshotted schedule position (the inverse of
    /// [`FfController::position`]). Fails on a policy-kind mismatch
    /// (a snapshot is only meaningful under the policy that took it —
    /// the resume path also checks the full `FfConfig` fingerprint) and
    /// clamps config-bounded fields into the current config's range.
    pub fn restore_position(&mut self, p: &FfPosition) -> anyhow::Result<()> {
        self.policy.restore_position(p)
    }

    /// Does the active policy want a tiny-val loss after each SGD step?
    pub fn wants_val_loss(&self) -> bool {
        self.policy.wants_val_loss()
    }

    /// Does the active policy want each SGD step's Δ_W?
    pub fn wants_delta(&self) -> bool {
        self.policy.wants_delta()
    }

    pub fn observe_val_loss(&mut self, loss: f32) {
        self.policy.observe_val_loss(loss);
    }

    pub fn observe_delta(&mut self, delta: &[Tensor]) {
        self.policy.observe_delta(delta);
    }

    /// Bulk tensor state to park alongside the position (`fa/` payload
    /// group in the checkpoint).
    pub fn aux_state(&self) -> Vec<Tensor> {
        self.policy.aux_state()
    }

    pub fn restore_aux(&mut self, aux: &[Tensor]) -> anyhow::Result<()> {
        self.policy.restore_aux(aux)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(stage: usize, tau: usize) -> FfStageStats {
        FfStageStats {
            stage,
            at_step: 0,
            tau_star: tau,
            probes: tau + 1,
            baseline_loss: 1.0,
            final_loss: 0.9,
            grad_norm: 0.0,
            grad_cond: 0.0,
        }
    }

    fn cfg() -> FfConfig {
        FfConfig { warmup_steps: 3, t_interval: 2, ..FfConfig::default() }
    }

    #[test]
    fn position_round_trip_reproduces_the_decision_sequence() {
        // drive a controller mid-schedule, snapshot, restore into a fresh
        // one, then check both make identical decisions from there on
        let mut a = FfController::new(cfg());
        for _ in 0..4 {
            if a.next() == FfDecision::FastForward {
                a.on_ff_stage(stats(a.n_stages(), 2));
            } else {
                a.on_sgd_step();
            }
        }
        let pos = a.position();
        let mut b = FfController::new(cfg());
        b.restore_position(&pos).unwrap();
        assert_eq!(b.position(), pos);
        for i in 0..12 {
            assert_eq!(a.next(), b.next(), "decision diverged at step {i}");
            if a.next() == FfDecision::FastForward {
                a.on_ff_stage(stats(a.n_stages(), 0));
                b.on_ff_stage(stats(b.n_stages(), 0));
            } else {
                a.on_sgd_step();
                b.on_sgd_step();
            }
        }
        assert_eq!(a.position(), b.position());
    }

    #[test]
    fn warmup_then_interval_schedule() {
        let mut c = FfController::new(cfg());
        // warmup: 3 SGD steps, no FF even though interval elapsed
        for _ in 0..3 {
            assert_eq!(c.next(), FfDecision::Sgd);
            c.on_sgd_step();
        }
        // after warmup the accumulated interval triggers FF
        assert_eq!(c.next(), FfDecision::FastForward);
        c.on_ff_stage(stats(0, 5));
        // then T_interval SGD steps before the next stage
        assert_eq!(c.next(), FfDecision::Sgd);
        c.on_sgd_step();
        assert_eq!(c.next(), FfDecision::Sgd);
        c.on_sgd_step();
        assert_eq!(c.next(), FfDecision::FastForward);
    }

    #[test]
    fn disabled_controller_never_fast_forwards() {
        let mut c = FfController::new(FfConfig { enabled: false, ..cfg() });
        for _ in 0..20 {
            assert_eq!(c.next(), FfDecision::Sgd);
            c.on_sgd_step();
        }
    }

    #[test]
    fn default_controller_requests_no_policy_signals() {
        // The default IntervalPolicy must impose zero extra evals or
        // Δ_W downloads — this is what makes its bit-identity to the
        // pre-policy controller structural.
        let c = FfController::new(cfg());
        assert!(!c.wants_val_loss());
        assert!(!c.wants_delta());
        assert!(c.aux_state().is_empty());
    }

    #[test]
    fn convergence_patience_disables_ff() {
        let mut c = FfController::new(FfConfig {
            convergence_patience: Some(3),
            ..cfg()
        });
        for _ in 0..3 {
            c.on_sgd_step();
        }
        for i in 0..3 {
            assert_eq!(c.next(), FfDecision::FastForward, "stage {i}");
            c.on_ff_stage(stats(i, 0)); // empty stage
            for _ in 0..2 {
                c.on_sgd_step();
            }
        }
        assert!(c.is_permanently_off());
        assert_eq!(c.next(), FfDecision::Sgd);
    }

    #[test]
    fn success_resets_failure_count() {
        let mut c = FfController::new(FfConfig {
            convergence_patience: Some(2),
            ..cfg()
        });
        for _ in 0..3 {
            c.on_sgd_step();
        }
        c.on_ff_stage(stats(0, 0));
        c.on_ff_stage(stats(1, 3)); // success resets
        c.on_ff_stage(stats(2, 0));
        assert!(!c.is_permanently_off());
        c.on_ff_stage(stats(3, 0));
        assert!(c.is_permanently_off());
    }

    #[test]
    fn adaptive_interval_shrinks_and_grows() {
        let mut c = FfController::new(FfConfig {
            adaptive_interval: true,
            t_interval: 6,
            ..FfConfig::default()
        });
        assert_eq!(c.interval(), 6);
        c.on_ff_stage(stats(0, 10));
        assert_eq!(c.interval(), 5); // productive → sooner
        c.on_ff_stage(stats(1, 0));
        c.on_ff_stage(stats(2, 0));
        assert_eq!(c.interval(), 9); // fizzles → later
        for i in 0..40 {
            c.on_ff_stage(stats(3 + i, 0));
        }
        assert!(c.interval() <= 24); // bounded
    }

    #[test]
    fn adaptive_interval_never_shrinks_below_one() {
        // A long streak of highly productive stages drives the interval
        // down, but never below one SGD step between stages — and an
        // interval of 1 stays 1 rather than bouncing back up.
        let mut c = FfController::new(FfConfig {
            adaptive_interval: true,
            t_interval: 3,
            ..FfConfig::default()
        });
        for i in 0..20 {
            c.on_ff_stage(stats(i, 10));
            assert!(c.interval() >= 1, "interval hit {} at stage {i}", c.interval());
        }
        assert_eq!(c.interval(), 1);
        c.on_ff_stage(stats(20, 10));
        assert_eq!(c.interval(), 1, "floor must be stable, not oscillating");
    }

    #[test]
    fn adaptive_interval_growth_is_capped_at_4x_base() {
        for t_interval in [1usize, 2, 6] {
            let mut c = FfController::new(FfConfig {
                adaptive_interval: true,
                t_interval,
                ..FfConfig::default()
            });
            for i in 0..100 {
                c.on_ff_stage(stats(i, 0));
                assert!(
                    c.interval() <= 4 * t_interval,
                    "interval {} exceeds cap {} (base {t_interval})",
                    c.interval(),
                    4 * t_interval
                );
            }
            assert_eq!(c.interval(), 4 * t_interval, "cap is reached exactly");
        }
    }

    #[test]
    fn mid_tau_stages_leave_adaptive_interval_unchanged() {
        // τ* in 1..=3 is neither "productive" (≥4) nor a fizzle (0):
        // the interval must hold steady.
        let mut c = FfController::new(FfConfig {
            adaptive_interval: true,
            t_interval: 5,
            ..FfConfig::default()
        });
        for i in 0..10 {
            c.on_ff_stage(stats(i, 1 + (i % 3)));
        }
        assert_eq!(c.interval(), 5);
    }

    #[test]
    fn convergence_rule_still_fires_with_adaptive_interval_on() {
        // §5.1: consecutive empty stages permanently disable FF even while
        // the adaptive rule is simultaneously growing the interval.
        let mut c = FfController::new(FfConfig {
            adaptive_interval: true,
            t_interval: 2,
            warmup_steps: 0,
            convergence_patience: Some(3),
            ..FfConfig::default()
        });
        for i in 0..3 {
            assert!(!c.is_permanently_off(), "disabled too early at stage {i}");
            c.on_ff_stage(stats(i, 0));
        }
        assert!(c.is_permanently_off());
        assert_eq!(c.next(), FfDecision::Sgd);
        // further stats must not resurrect FF, whatever the interval says
        c.on_ff_stage(stats(3, 10));
        assert!(c.is_permanently_off());
        for _ in 0..50 {
            c.on_sgd_step();
            assert_eq!(c.next(), FfDecision::Sgd);
        }
    }
}
