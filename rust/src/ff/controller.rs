//! The Fast Forward schedule controller (paper Fig 1):
//!
//! ```text
//!  warmup ─► SGD × T_interval ─► FF stage ─► SGD × T_interval ─► FF …
//! ```
//!
//! The controller owns *when* to Fast Forward; the trainer owns *how*
//! (line search over Δ_W). It also implements:
//!   * the §5.1 convergence rule — after `convergence_patience` consecutive
//!     FF stages with τ* = 0, Fast Forward is permanently disabled;
//!   * the §7-future-work adaptive interval — shrink T_interval while FF
//!     stages are productive, grow it when they fizzle (ablation bench).

use crate::config::FfConfig;

/// What the trainer should do next.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FfDecision {
    /// Run a regular Adam SGD step.
    Sgd,
    /// Run a Fast Forward stage now.
    FastForward,
}

/// Outcome summary of one FF stage, fed back into the controller and kept
/// for the Fig 11/12/13/14 analyses.
#[derive(Debug, Clone)]
pub struct FfStageStats {
    /// Index of this stage (0-based) over the run.
    pub stage: usize,
    /// Adam step count when the stage ran.
    pub at_step: usize,
    pub tau_star: usize,
    pub probes: usize,
    pub baseline_loss: f32,
    pub final_loss: f32,
    /// ‖Δ_W‖ and gradient stats recorded just before the stage (Fig 12).
    pub grad_norm: f64,
    pub grad_cond: f64,
}

/// The controller's schedule position, snapshotted for park/resume
/// (`train::checkpoint::ParkState`). Captures every private scheduling
/// counter — restoring it into a fresh controller with the same
/// `FfConfig` reproduces the exact decision sequence, so a resumed run's
/// FF stages land on the same steps as an uninterrupted one. `stages`
/// history rides separately (it is already public on the controller).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FfPosition {
    pub sgd_since_ff: usize,
    pub total_sgd: usize,
    pub interval: usize,
    pub consecutive_failures: usize,
    pub permanently_off: bool,
}

#[derive(Debug)]
pub struct FfController {
    cfg: FfConfig,
    sgd_since_ff: usize,
    total_sgd: usize,
    /// Current interval (== cfg.t_interval unless adaptive).
    interval: usize,
    consecutive_failures: usize,
    permanently_off: bool,
    pub stages: Vec<FfStageStats>,
}

impl FfController {
    pub fn new(cfg: FfConfig) -> FfController {
        let interval = cfg.t_interval;
        FfController {
            cfg,
            sgd_since_ff: 0,
            total_sgd: 0,
            interval,
            consecutive_failures: 0,
            permanently_off: false,
            stages: Vec::new(),
        }
    }

    pub fn interval(&self) -> usize {
        self.interval
    }

    pub fn is_permanently_off(&self) -> bool {
        self.permanently_off
    }

    /// Decide the next action. FF requires: enabled, not disabled by the
    /// convergence rule, warmup complete, a full interval of SGD steps run
    /// since the last stage (so Δ_W reflects a *recent* optimizer step).
    pub fn next(&self) -> FfDecision {
        if !self.cfg.enabled || self.permanently_off {
            return FfDecision::Sgd;
        }
        if self.total_sgd < self.cfg.warmup_steps {
            return FfDecision::Sgd;
        }
        if self.sgd_since_ff >= self.interval {
            FfDecision::FastForward
        } else {
            FfDecision::Sgd
        }
    }

    /// Record a completed SGD step.
    pub fn on_sgd_step(&mut self) {
        self.total_sgd += 1;
        self.sgd_since_ff += 1;
    }

    /// Record a completed FF stage; applies the convergence + adaptive rules.
    pub fn on_ff_stage(&mut self, stats: FfStageStats) {
        self.sgd_since_ff = 0;
        if stats.tau_star == 0 {
            self.consecutive_failures += 1;
            if let Some(patience) = self.cfg.convergence_patience {
                if self.consecutive_failures >= patience {
                    self.permanently_off = true;
                    crate::info!(
                        "FF permanently off after {} consecutive empty stages (§5.1 rule)",
                        self.consecutive_failures
                    );
                }
            }
        } else {
            self.consecutive_failures = 0;
        }
        if self.cfg.adaptive_interval {
            // §7 future work: productive stages → FF sooner; fizzles →
            // later. The interval is clamped to [1, 4·t_interval]: it can
            // never shrink below one SGD step (Δ_W must reflect at least
            // one fresh optimizer step between stages) and growth is
            // capped so a long fizzle streak cannot push FF out of a run
            // entirely before the §5.1 convergence rule gets to decide.
            if stats.tau_star >= 4 {
                self.interval = (self.interval.saturating_sub(1)).max(1);
            } else if stats.tau_star == 0 {
                self.interval = (self.interval + 2).min(4 * self.cfg.t_interval);
            }
        }
        self.stages.push(stats);
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// Snapshot the schedule position for park/resume.
    pub fn position(&self) -> FfPosition {
        FfPosition {
            sgd_since_ff: self.sgd_since_ff,
            total_sgd: self.total_sgd,
            interval: self.interval,
            consecutive_failures: self.consecutive_failures,
            permanently_off: self.permanently_off,
        }
    }

    /// Restore a snapshotted schedule position (the inverse of
    /// [`FfController::position`]). The controller keeps its own `cfg`:
    /// a resume is only meaningful with the same `FfConfig` the position
    /// was taken under.
    pub fn restore_position(&mut self, p: FfPosition) {
        self.sgd_since_ff = p.sgd_since_ff;
        self.total_sgd = p.total_sgd;
        self.interval = p.interval;
        self.consecutive_failures = p.consecutive_failures;
        self.permanently_off = p.permanently_off;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(stage: usize, tau: usize) -> FfStageStats {
        FfStageStats {
            stage,
            at_step: 0,
            tau_star: tau,
            probes: tau + 1,
            baseline_loss: 1.0,
            final_loss: 0.9,
            grad_norm: 0.0,
            grad_cond: 0.0,
        }
    }

    fn cfg() -> FfConfig {
        FfConfig { warmup_steps: 3, t_interval: 2, ..FfConfig::default() }
    }

    #[test]
    fn position_round_trip_reproduces_the_decision_sequence() {
        // drive a controller mid-schedule, snapshot, restore into a fresh
        // one, then check both make identical decisions from there on
        let mut a = FfController::new(cfg());
        for _ in 0..4 {
            if a.next() == FfDecision::FastForward {
                a.on_ff_stage(stats(a.n_stages(), 2));
            } else {
                a.on_sgd_step();
            }
        }
        let pos = a.position();
        let mut b = FfController::new(cfg());
        b.restore_position(pos);
        assert_eq!(b.position(), pos);
        for i in 0..12 {
            assert_eq!(a.next(), b.next(), "decision diverged at step {i}");
            if a.next() == FfDecision::FastForward {
                a.on_ff_stage(stats(a.n_stages(), 0));
                b.on_ff_stage(stats(b.n_stages(), 0));
            } else {
                a.on_sgd_step();
                b.on_sgd_step();
            }
        }
        assert_eq!(a.position(), b.position());
    }

    #[test]
    fn warmup_then_interval_schedule() {
        let mut c = FfController::new(cfg());
        // warmup: 3 SGD steps, no FF even though interval elapsed
        for _ in 0..3 {
            assert_eq!(c.next(), FfDecision::Sgd);
            c.on_sgd_step();
        }
        // after warmup the accumulated interval triggers FF
        assert_eq!(c.next(), FfDecision::FastForward);
        c.on_ff_stage(stats(0, 5));
        // then T_interval SGD steps before the next stage
        assert_eq!(c.next(), FfDecision::Sgd);
        c.on_sgd_step();
        assert_eq!(c.next(), FfDecision::Sgd);
        c.on_sgd_step();
        assert_eq!(c.next(), FfDecision::FastForward);
    }

    #[test]
    fn disabled_controller_never_fast_forwards() {
        let mut c = FfController::new(FfConfig { enabled: false, ..cfg() });
        for _ in 0..20 {
            assert_eq!(c.next(), FfDecision::Sgd);
            c.on_sgd_step();
        }
    }

    #[test]
    fn convergence_patience_disables_ff() {
        let mut c = FfController::new(FfConfig {
            convergence_patience: Some(3),
            ..cfg()
        });
        for _ in 0..3 {
            c.on_sgd_step();
        }
        for i in 0..3 {
            assert_eq!(c.next(), FfDecision::FastForward, "stage {i}");
            c.on_ff_stage(stats(i, 0)); // empty stage
            for _ in 0..2 {
                c.on_sgd_step();
            }
        }
        assert!(c.is_permanently_off());
        assert_eq!(c.next(), FfDecision::Sgd);
    }

    #[test]
    fn success_resets_failure_count() {
        let mut c = FfController::new(FfConfig {
            convergence_patience: Some(2),
            ..cfg()
        });
        for _ in 0..3 {
            c.on_sgd_step();
        }
        c.on_ff_stage(stats(0, 0));
        c.on_ff_stage(stats(1, 3)); // success resets
        c.on_ff_stage(stats(2, 0));
        assert!(!c.is_permanently_off());
        c.on_ff_stage(stats(3, 0));
        assert!(c.is_permanently_off());
    }

    #[test]
    fn adaptive_interval_shrinks_and_grows() {
        let mut c = FfController::new(FfConfig {
            adaptive_interval: true,
            t_interval: 6,
            ..FfConfig::default()
        });
        assert_eq!(c.interval(), 6);
        c.on_ff_stage(stats(0, 10));
        assert_eq!(c.interval(), 5); // productive → sooner
        c.on_ff_stage(stats(1, 0));
        c.on_ff_stage(stats(2, 0));
        assert_eq!(c.interval(), 9); // fizzles → later
        for i in 0..40 {
            c.on_ff_stage(stats(3 + i, 0));
        }
        assert!(c.interval() <= 24); // bounded
    }

    #[test]
    fn adaptive_interval_never_shrinks_below_one() {
        // A long streak of highly productive stages drives the interval
        // down, but never below one SGD step between stages — and an
        // interval of 1 stays 1 rather than bouncing back up.
        let mut c = FfController::new(FfConfig {
            adaptive_interval: true,
            t_interval: 3,
            ..FfConfig::default()
        });
        for i in 0..20 {
            c.on_ff_stage(stats(i, 10));
            assert!(c.interval() >= 1, "interval hit {} at stage {i}", c.interval());
        }
        assert_eq!(c.interval(), 1);
        c.on_ff_stage(stats(20, 10));
        assert_eq!(c.interval(), 1, "floor must be stable, not oscillating");
    }

    #[test]
    fn adaptive_interval_growth_is_capped_at_4x_base() {
        for t_interval in [1usize, 2, 6] {
            let mut c = FfController::new(FfConfig {
                adaptive_interval: true,
                t_interval,
                ..FfConfig::default()
            });
            for i in 0..100 {
                c.on_ff_stage(stats(i, 0));
                assert!(
                    c.interval() <= 4 * t_interval,
                    "interval {} exceeds cap {} (base {t_interval})",
                    c.interval(),
                    4 * t_interval
                );
            }
            assert_eq!(c.interval(), 4 * t_interval, "cap is reached exactly");
        }
    }

    #[test]
    fn mid_tau_stages_leave_adaptive_interval_unchanged() {
        // τ* in 1..=3 is neither "productive" (≥4) nor a fizzle (0):
        // the interval must hold steady.
        let mut c = FfController::new(FfConfig {
            adaptive_interval: true,
            t_interval: 5,
            ..FfConfig::default()
        });
        for i in 0..10 {
            c.on_ff_stage(stats(i, 1 + (i % 3)));
        }
        assert_eq!(c.interval(), 5);
    }

    #[test]
    fn convergence_rule_still_fires_with_adaptive_interval_on() {
        // §5.1: consecutive empty stages permanently disable FF even while
        // the adaptive rule is simultaneously growing the interval.
        let mut c = FfController::new(FfConfig {
            adaptive_interval: true,
            t_interval: 2,
            warmup_steps: 0,
            convergence_patience: Some(3),
            ..FfConfig::default()
        });
        for i in 0..3 {
            assert!(!c.is_permanently_off(), "disabled too early at stage {i}");
            c.on_ff_stage(stats(i, 0));
        }
        assert!(c.is_permanently_off());
        assert_eq!(c.next(), FfDecision::Sgd);
        // further stats must not resurrect FF, whatever the interval says
        c.on_ff_stage(stats(3, 10));
        assert!(c.is_permanently_off());
        for _ in 0..50 {
            c.on_sgd_step();
            assert_eq!(c.next(), FfDecision::Sgd);
        }
    }
}
