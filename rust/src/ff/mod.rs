//! Fast Forward (paper §3): the controller that alternates regular Adam
//! SGD intervals with line-search extrapolation stages, and the line
//! search itself.

pub mod controller;
pub mod line_search;
pub mod policy;

pub use controller::{FfController, FfDecision, FfStageStats};
pub use line_search::{line_search, LineSearchResult};
pub use policy::{make_policy, CosinePolicy, FfPolicy, FfPosition, IntervalPolicy, LossSlopePolicy};
