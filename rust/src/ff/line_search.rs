//! The Fast Forward line search (paper §3):
//!
//! > The direction Δ_W is used to iteratively update W_t. In the τ-th Fast
//! > Forward step, the updated weight matrix is given by W_t + τΔ_W. The
//! > recursive updates continue until the model's loss on a small
//! > validation set stops improving. When a Fast Forward step causes this
//! > validation loss to increase, the Fast Forward stage concludes.
//!
//! Generic over a [`SearchTarget`] so the same search drives the real
//! ParamSet+PJRT path in the trainer, host-only unit tests, and the Fig 10
//! convexity probe.

use anyhow::Result;

/// The state a line search extrapolates: `apply` moves W by +Δ, `revert`
/// by −Δ, `eval` measures the tiny-validation-set loss at the current W.
///
/// `begin` runs once before the first simulated step. Targets backed by
/// the pipelined step engine use it to drain the deferred-readback ring —
/// a line search moves W host-side, so every dispatched optimizer step
/// must retire first (see `docs/step-pipeline.md`). The default is a
/// no-op for host-only targets.
pub trait SearchTarget {
    fn begin(&mut self) -> Result<()> {
        Ok(())
    }
    fn apply(&mut self) -> Result<()>;
    fn revert(&mut self) -> Result<()>;
    fn eval(&mut self) -> Result<f32>;
}

#[derive(Debug, Clone)]
pub struct LineSearchResult {
    /// Number of simulated steps *kept* (τ*). 0 = the very first simulated
    /// step already increased val loss (the Fig 8 full-rank failure mode).
    pub tau_star: usize,
    /// Validation-loss evaluations performed (each costs one val forward).
    pub probes: usize,
    /// Val loss at entry (τ=0).
    pub baseline_loss: f32,
    /// Val loss at the kept endpoint.
    pub final_loss: f32,
    /// Loss at each probed τ = 1, 2, … (including the rejected last one).
    pub losses: Vec<f32>,
}

impl LineSearchResult {
    pub fn improved(&self) -> bool {
        self.tau_star > 0
    }
}

/// Run the FF line search. `baseline` is the val loss at τ=0 (the caller
/// usually already has it); `max_tau` bounds runaway extrapolation.
/// Postcondition: the target's W sits at `W_t + τ*·Δ`.
pub fn line_search(
    target: &mut impl SearchTarget,
    baseline: f32,
    max_tau: usize,
) -> Result<LineSearchResult> {
    line_search_thresholded(target, baseline, max_tau, 0.0)
}

/// Like [`line_search`] but requiring each kept step to improve the val
/// loss by at least `min_rel` relative to the best so far (0 = paper rule).
pub fn line_search_thresholded(
    target: &mut impl SearchTarget,
    baseline: f32,
    max_tau: usize,
    min_rel: f32,
) -> Result<LineSearchResult> {
    target.begin()?;
    let mut best = baseline;
    let mut losses = Vec::new();
    let mut tau = 0usize;
    while tau < max_tau {
        target.apply()?;
        let loss = target.eval()?;
        losses.push(loss);
        if !loss.is_finite() || loss >= best * (1.0 - min_rel) {
            // this simulated step made things worse — undo it and stop
            target.revert()?;
            break;
        }
        best = loss;
        tau += 1;
    }
    Ok(LineSearchResult {
        tau_star: tau,
        probes: losses.len(),
        baseline_loss: baseline,
        final_loss: best,
        losses,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Quadratic val loss in τ: L(τ) = (τ − vertex)² + 1.
    struct Quad {
        tau: i64,
        vertex: f64,
        nan: bool,
    }

    impl Quad {
        fn new(vertex: f64) -> Quad {
            Quad { tau: 0, vertex, nan: false }
        }

        fn loss(&self) -> f32 {
            ((self.tau as f64 - self.vertex).powi(2) + 1.0) as f32
        }
    }

    impl SearchTarget for Quad {
        fn apply(&mut self) -> Result<()> {
            self.tau += 1;
            Ok(())
        }
        fn revert(&mut self) -> Result<()> {
            self.tau -= 1;
            Ok(())
        }
        fn eval(&mut self) -> Result<f32> {
            Ok(if self.nan { f32::NAN } else { self.loss() })
        }
    }

    #[test]
    fn stops_at_vertex_of_convex_loss() {
        let mut q = Quad::new(7.3);
        let base = q.loss();
        let r = line_search(&mut q, base, 100).unwrap();
        assert_eq!(r.tau_star, 7);
        assert!(r.improved());
        // probes = kept steps + the one rejected probe
        assert_eq!(r.probes, 8);
        assert!(r.final_loss < r.baseline_loss);
        // postcondition: target parked at τ*
        assert_eq!(q.tau, 7);
    }

    #[test]
    fn immediate_increase_gives_tau_zero() {
        // vertex at 0 ⇒ the first simulated step already worsens loss —
        // exactly the paper's full-rank failure (Fig 8).
        let mut q = Quad::new(0.0);
        let base = q.loss();
        let r = line_search(&mut q, base, 100).unwrap();
        assert_eq!(r.tau_star, 0);
        assert!(!r.improved());
        assert_eq!(r.probes, 1);
        assert_eq!(r.final_loss, r.baseline_loss);
        assert_eq!(q.tau, 0);
    }

    #[test]
    fn respects_max_tau_bound() {
        let mut q = Quad::new(1000.0);
        let base = q.loss();
        let r = line_search(&mut q, base, 10).unwrap();
        assert_eq!(r.tau_star, 10);
        assert_eq!(r.probes, 10);
    }

    #[test]
    fn plateau_counts_as_stop() {
        struct Flat;
        impl SearchTarget for Flat {
            fn apply(&mut self) -> Result<()> {
                Ok(())
            }
            fn revert(&mut self) -> Result<()> {
                Ok(())
            }
            fn eval(&mut self) -> Result<f32> {
                Ok(1.0)
            }
        }
        let r = line_search(&mut Flat, 1.0, 50).unwrap();
        assert_eq!(r.tau_star, 0);
    }

    #[test]
    fn begin_runs_once_before_the_first_apply() {
        struct Tracked {
            inner: Quad,
            begun: usize,
            applied_before_begin: bool,
        }
        impl SearchTarget for Tracked {
            fn begin(&mut self) -> Result<()> {
                self.begun += 1;
                Ok(())
            }
            fn apply(&mut self) -> Result<()> {
                if self.begun == 0 {
                    self.applied_before_begin = true;
                }
                self.inner.apply()
            }
            fn revert(&mut self) -> Result<()> {
                self.inner.revert()
            }
            fn eval(&mut self) -> Result<f32> {
                self.inner.eval()
            }
        }
        let mut t = Tracked { inner: Quad::new(3.0), begun: 0, applied_before_begin: false };
        let base = t.inner.loss();
        let r = line_search(&mut t, base, 10).unwrap();
        assert_eq!(r.tau_star, 3);
        assert_eq!(t.begun, 1, "begin is a once-per-search boundary hook");
        assert!(!t.applied_before_begin, "W must not move before begin()");
    }

    #[test]
    fn nan_loss_stops_and_reverts() {
        let mut q = Quad::new(50.0);
        q.nan = true;
        let base = 1.0;
        let r = line_search(&mut q, base, 50).unwrap();
        assert_eq!(r.tau_star, 0);
        assert_eq!(q.tau, 0, "must revert the NaN step");
    }
}
