//! `fastforward` — leader entrypoint / CLI.
//!
//! Subcommands:
//!   train        one training run (artifact × task, FF on/off)
//!   experiment   run one paper-figure harness (or --all)
//!   queue        long-lived multi-tenant run queue: submit a manifest of
//!                runs (priorities + tenants), stream results in
//!                completion order, print per-tenant accounting
//!   pretrain     (re)build the cached W0 checkpoint for a model
//!   list         artifacts, experiments, presets
//!   selftest     fast end-to-end smoke check of the whole stack
//!
//! Examples:
//!   fastforward experiment fig2a
//!   fastforward experiment --all --full
//!   fastforward experiment fig7 --jobs 4 --queue
//!   fastforward train --artifact ff-tiny_lora_r8 --task medical --epochs 2
//!   fastforward train --artifact ff-tiny_lora_r8 --task medical --no-ff
//!   fastforward train --artifact ff-tiny_lora_r8 --task medical --runs 4 --jobs 4
//!   fastforward queue --manifest runs.txt --jobs 4

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::process::ExitCode;
use std::sync::Arc;

use fastforward::config::{presets, FfConfig};
use fastforward::experiments::{self, ExpContext, Scale};
use fastforward::model::tensor::Tensor;
use fastforward::runtime::{ArtifactIndex, Runtime};
use fastforward::sched::shard::{self as grid, GridLock, GridManifest};
use fastforward::sched::{self, ArtifactCache, RunQueue, RunResult, RunSpec, WorkerPool};
use fastforward::store::ArtifactStore;
use fastforward::train::pretrain::ensure_pretrained;
use fastforward::train::trainer::{StopRule, Trainer};
use fastforward::util::args::Args;
use fastforward::{info, warn_};

fn main() -> ExitCode {
    fastforward::util::logging::init();
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Model name encoded in an artifact key (`ff-tiny_lora_r8` → `ff-tiny`)
/// — the single place the key naming scheme is parsed.
fn model_of(artifact: &str) -> &str {
    artifact.split('_').next().unwrap_or("ff-tiny")
}

fn usage() -> &'static str {
    "usage: fastforward <train|experiment|queue|pretrain|list|selftest> [options]\n\
     common options: --artifacts DIR (default ./artifacts) --reports DIR (default ./reports)\n\
     train:      --artifact KEY --task medical|instruct|chat [--epochs N] [--no-ff]\n\
                 [--steps N] [--seed S] [--t-interval N] [--adaptive] [--no-pretrain]\n\
                 [--runs K] [--jobs N]   (K seed-replica runs on N scheduler workers;\n\
                 --jobs only applies when --runs > 1)\n\
     experiment: <id>|--all [--full] [--jobs N] [--queue]   (ids: fastforward list\n\
                 --experiments; --queue routes grid cells through the run queue;\n\
                 --policies is shorthand for the 'policies' id: FF trigger\n\
                 policies × optimizer backends × batch/streaming grid)\n\
                 --emit-manifest [--full] [--name NAME]   write a versioned grid\n\
                 manifest plus a .lock pinning artifact content hashes\n\
                 --manifest FILE [--shard i/N] [--store DIR] [--jobs N]   run the\n\
                 manifest (or one round-robin slice); a .lock next to the\n\
                 manifest pins hashes (mismatch fails fast); --store shares AOT\n\
                 bundles + W0 checkpoints across hosts (docs/artifact-store.md)\n\
                 --merge FILE...   fold shard reports (files or shard dirs) into\n\
                 the canonical report, byte-identical to an unsharded run\n\
     queue:      --manifest FILE [--jobs N]   (long-lived multi-tenant run queue:\n\
                 submissions pop by priority, fair-share within a class; results\n\
                 stream in completion order; per-tenant runs/steps/FLOPs/exact-\n\
                 bytes accounting. manifest lines: tenant priority artifact task\n\
                 steps seed on|off)\n\
     pretrain:   --model NAME [--steps N]\n\
     selftest:   [--jobs N] [--queue] [--churn] [--shard] [--policies]   (N > 1\n\
                 exercises the concurrent scheduler; --queue adds run-queue legs:\n\
                 priorities, cancel, tenant totals, and batched same-artifact\n\
                 packing vs solo bit-identity; --churn adds the deterministic\n\
                 churn storm plus quantum park/resume accounting, and implies\n\
                 --queue; --shard adds the cross-host grid leg: 2 shards + store\n\
                 vs unsharded, merged report byte-identical, warm shard all store\n\
                 hits; --policies adds the FF-policy leg: per-policy park/resume\n\
                 bit-identity, IntervalPolicy == legacy controller path, LoFT\n\
                 backend, and streaming-run byte accounting)\n\
     note: --jobs > 1 needs a build with --features xla-shared-client (pinned,\n\
           audited xla rev — see rust/XLA_AUDIT); otherwise the pool runs\n\
           sequentially and the queue drains inline at join, in priority order\n"
}

fn run() -> anyhow::Result<()> {
    let mut args = Args::from_env().map_err(|e| anyhow::anyhow!(e))?;
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let reports = PathBuf::from(args.opt_or("reports", "reports"));

    match args.subcommand.clone().as_deref() {
        Some("train") => cmd_train(&mut args, artifacts),
        Some("experiment") => cmd_experiment(&mut args, artifacts, reports),
        Some("queue") => cmd_queue(&mut args, artifacts),
        Some("pretrain") => cmd_pretrain(&mut args, artifacts),
        Some("list") => cmd_list(&mut args, artifacts),
        Some("selftest") => cmd_selftest(&mut args, artifacts),
        _ => {
            print!("{}", usage());
            Ok(())
        }
    }
}

fn cmd_train(args: &mut Args, artifacts: PathBuf) -> anyhow::Result<()> {
    let artifact = args
        .opt("artifact")
        .ok_or_else(|| anyhow::anyhow!("--artifact required (see: fastforward list)"))?;
    let task = args.opt_or("task", "medical");
    let epochs = args.opt_usize("epochs", 2).map_err(|e| anyhow::anyhow!(e))?;
    let no_ff = args.flag("no-ff");
    let adaptive = args.flag("adaptive");
    let no_pretrain = args.flag("no-pretrain");
    let seed = args.opt_u64("seed", 0x5eed).map_err(|e| anyhow::anyhow!(e))?;
    let t_interval = args.opt_usize("t-interval", 6).map_err(|e| anyhow::anyhow!(e))?;
    let steps_override = args.opt_usize("steps", 0).map_err(|e| anyhow::anyhow!(e))?;
    let runs = args.opt_usize("runs", 1).map_err(|e| anyhow::anyhow!(e))?.max(1);
    let jobs = args.opt_usize("jobs", 1).map_err(|e| anyhow::anyhow!(e))?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let mut cfg = presets::train_config(&artifact, &task, epochs)?;
    cfg.seed = seed;
    cfg.ff = FfConfig {
        enabled: !no_ff,
        t_interval,
        adaptive_interval: adaptive,
        ..FfConfig::default()
    };
    if steps_override > 0 {
        cfg.max_steps = steps_override;
    }
    let max_steps = cfg.max_steps;

    let rt = Runtime::cpu()?;
    let model = model_of(&artifact).to_string();
    let base = if no_pretrain {
        None
    } else {
        Some(ensure_pretrained(&rt, &artifacts, &model, None)?)
    };

    if runs > 1 {
        // Seed-replica fan-out: `--runs K` independent runs (seeds
        // seed..seed+K−1) on `--jobs N` scheduler workers, results in
        // submission (seed) order.
        let base = base.map(std::sync::Arc::new);
        let specs: Vec<RunSpec> = (0..runs as u64)
            .map(|k| {
                let mut c = cfg.clone();
                c.seed = seed.wrapping_add(k);
                RunSpec {
                    label: format!("seed{}", c.seed),
                    cfg: c,
                    stop: StopRule::MaxSteps(max_steps),
                    base: base.clone(),
                    drain_interval: None,
                }
            })
            .collect();
        let pool = WorkerPool::new(jobs);
        if jobs > pool.jobs() {
            warn_!(
                "--jobs {jobs} requested, but this build has no thread fan-out \
                 (xla-shared-client feature off — see rust/XLA_AUDIT); runs \
                 execute sequentially"
            );
        }
        info!(
            "training {artifact} on {task}: {runs} seed replicas × {max_steps} steps on {} worker(s), FF={}",
            pool.jobs(),
            !no_ff
        );
        let cache = ArtifactCache::new(artifacts);
        let batch = pool.run_all(&rt, &cache, specs)?;
        for o in &batch.outputs {
            println!(
                "{:<10} test loss {:.4} | {} adam + {} simulated steps | {:.3e} FLOPs | {:.1}s",
                o.label,
                o.summary.final_test_loss,
                o.summary.adam_steps,
                o.summary.sim_steps,
                o.summary.flops.total() as f64,
                o.seconds
            );
        }
        println!(
            "batch: {} runs, {} adam steps in {:.1}s wall | host↔device {}",
            batch.outputs.len(),
            batch.total_adam_steps(),
            batch.wall_seconds,
            batch.transfers.report()
        );
        return Ok(());
    }

    if jobs > 1 {
        warn_!(
            "--jobs {jobs} has no effect on a single run — it schedules \
             seed replicas; add --runs K (K > 1) to fan out"
        );
    }
    let mut t = Trainer::new(&rt, &artifacts, cfg, base.as_ref())?;
    info!("training {artifact} on {task}: {max_steps} optimizer steps, FF={}", !no_ff);
    let sum = t.run(&StopRule::MaxSteps(max_steps))?;
    println!(
        "done: test loss {:.4} | {} adam + {} simulated steps | {:.3e} FLOPs | {:.1}s train time",
        sum.final_test_loss,
        sum.adam_steps,
        sum.sim_steps,
        sum.flops.total() as f64,
        sum.train_seconds
    );
    println!("host↔device: {}", sum.transfers.report());
    println!("step pipeline: {}", t.stream_stats().report());
    for s in &t.ffc.stages {
        println!(
            "  ff stage {:>2} @step {:>4}: τ*={:<3} val {:.4}→{:.4}",
            s.stage, s.at_step, s.tau_star, s.baseline_loss, s.final_loss
        );
    }
    Ok(())
}

fn cmd_experiment(args: &mut Args, artifacts: PathBuf, reports: PathBuf) -> anyhow::Result<()> {
    let all = args.flag("all");
    let full = args.flag("full");
    let use_queue = args.flag("queue");
    let jobs = args.opt_usize("jobs", 1).map_err(|e| anyhow::anyhow!(e))?;
    let emit_manifest = args.flag("emit-manifest");
    let grid_name = args.opt("name");
    let manifest_path = args.opt("manifest").map(PathBuf::from);
    let shard_slice = args.opt("shard");
    let store_dir = args.opt("store").map(PathBuf::from);
    // `--merge a.json b.json` parses as opt("merge")=a.json + positional
    // b.json; a bare trailing `--merge` parses as a flag.
    let merge_head = args.opt("merge");
    let merge = merge_head.is_some() || args.flag("merge");
    // `--policies` is CLI sugar for the registry id of the same name.
    let policies = args.flag("policies");
    let id = args
        .positional
        .first()
        .cloned()
        .or_else(|| policies.then(|| "policies".to_string()));
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    if merge {
        return cmd_grid_merge(merge_head, &args.positional, &reports);
    }
    if emit_manifest {
        return cmd_grid_emit(grid_name, full, &artifacts, &reports);
    }
    if let Some(mpath) = manifest_path {
        return cmd_grid_run(&mpath, shard_slice.as_deref(), store_dir, &artifacts, &reports, jobs);
    }
    anyhow::ensure!(shard_slice.is_none(), "--shard needs --manifest FILE");
    anyhow::ensure!(store_dir.is_none(), "--store applies to --manifest grid runs");

    let scale = if full { Scale::full() } else { Scale::quick() };
    let ctx = ExpContext::new(artifacts, reports, scale, jobs, use_queue)?;
    if jobs > ctx.jobs {
        warn_!(
            "--jobs {jobs} requested, but this build has no thread fan-out \
             (xla-shared-client feature off — see rust/XLA_AUDIT); grid cells \
             run sequentially"
        );
    }
    if ctx.jobs > 1 {
        info!("grid harnesses fan out on {} scheduler workers (--jobs)", ctx.jobs);
    }
    if use_queue {
        info!("grid cells route through the multi-tenant run queue (--queue)");
    }
    if all {
        let mut failed = Vec::new();
        for (name, desc, f) in experiments::registry() {
            info!("=== experiment {name}: {desc}");
            if let Err(e) = f(&ctx) {
                warn_!("experiment {name} failed: {e:#}");
                failed.push(name);
            }
        }
        anyhow::ensure!(failed.is_empty(), "failed experiments: {failed:?}");
        return Ok(());
    }
    let id = id.ok_or_else(|| anyhow::anyhow!("experiment id required (or --all)"))?;
    let (_, desc, f) = experiments::find(&id)
        .ok_or_else(|| anyhow::anyhow!("unknown experiment '{id}' (see: fastforward list --experiments)"))?;
    info!("experiment {id}: {desc}");
    f(&ctx)
}

/// `experiment --emit-manifest`: write the versioned grid manifest for the
/// selected scale plus its lockfile (every artifact pinned to its canonical
/// content hash) under the reports dir, ready to ship to other hosts.
fn cmd_grid_emit(
    name: Option<String>,
    full: bool,
    artifacts: &Path,
    reports: &Path,
) -> anyhow::Result<()> {
    let name = name.unwrap_or_else(|| if full { "full" } else { "quick" }.to_string());
    let scale = if full { Scale::full() } else { Scale::quick() };
    let manifest = experiments::grid_manifest(&scale, &name)?;
    let mpath = reports.join(format!("grid-{name}.manifest.json"));
    manifest.save(&mpath)?;
    let lock = GridLock::emit(&manifest, artifacts)?;
    let lpath = GridLock::lock_path(&mpath);
    lock.save(&lpath)?;
    println!(
        "manifest: {} ({} cells, format v{})",
        mpath.display(),
        manifest.cells.len(),
        grid::GRID_FORMAT_VERSION
    );
    println!("lockfile: {} ({} artifact pins)", lpath.display(), lock.artifacts.len());
    println!(
        "run a slice with: fastforward experiment --manifest {} --shard i/N [--store DIR]",
        mpath.display()
    );
    Ok(())
}

/// `experiment --manifest FILE [--shard i/N] [--store DIR]`: run the whole
/// manifest or one round-robin slice of it, resolving artifacts and W0
/// through the content-addressed store when one is given.
fn cmd_grid_run(
    mpath: &Path,
    shard: Option<&str>,
    store_dir: Option<PathBuf>,
    artifacts: &Path,
    reports: &Path,
    jobs: usize,
) -> anyhow::Result<()> {
    let manifest = GridManifest::load(mpath)?;
    let lpath = GridLock::lock_path(mpath);
    let lock = if lpath.exists() { Some(GridLock::load(&lpath)?) } else { None };
    match &lock {
        Some(l) => info!("lockfile {}: {} artifact pin(s)", lpath.display(), l.artifacts.len()),
        None => warn_!(
            "no lockfile next to {} — artifact content hashes are unpinned",
            mpath.display()
        ),
    }
    let shard = shard.map(grid::parse_shard).transpose()?;
    let store = store_dir.map(ArtifactStore::open).transpose()?.map(Arc::new);
    let rt = Runtime::cpu()?;
    let outcome =
        grid::run_grid(&rt, artifacts, store, &manifest, lock.as_ref(), shard, reports, jobs)?;
    println!(
        "grid '{}': {} cell(s) → {}",
        manifest.name,
        outcome.cells_run,
        outcome.report_path.display()
    );
    if let Some(s) = &outcome.store {
        println!("{}", s.report());
    }
    Ok(())
}

/// `experiment --merge FILE...`: fold shard reports (files, or shard dirs
/// holding one) into the canonical grid report.
fn cmd_grid_merge(head: Option<String>, rest: &[String], reports: &Path) -> anyhow::Result<()> {
    let mut files = Vec::new();
    for f in head.iter().chain(rest.iter()) {
        let p = PathBuf::from(f);
        files.push(if p.is_dir() { grid::shard_report_file(&p)? } else { p });
    }
    anyhow::ensure!(!files.is_empty(), "--merge wants shard report files (or shard dirs)");
    let merged = grid::merge_shards(&files, reports)?;
    println!("merged {} shard report(s) → {}", files.len(), merged.display());
    Ok(())
}

/// One parsed manifest line of the `queue` subcommand.
struct QueuedRun {
    tenant: String,
    priority: i32,
    artifact: String,
    task: String,
    steps: usize,
    seed: u64,
    ff: bool,
}

fn parse_manifest(text: &str) -> anyhow::Result<Vec<QueuedRun>> {
    let mut out = Vec::new();
    for (no, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        anyhow::ensure!(
            f.len() == 7,
            "manifest line {}: expected 7 fields \
             (tenant priority artifact task steps seed on|off), got {}",
            no + 1,
            f.len()
        );
        let ff = match f[6] {
            "on" => true,
            "off" => false,
            other => anyhow::bail!("manifest line {}: ff must be on|off, got '{other}'", no + 1),
        };
        out.push(QueuedRun {
            tenant: f[0].to_string(),
            priority: f[1]
                .parse()
                .map_err(|_| anyhow::anyhow!("line {}: bad priority '{}'", no + 1, f[1]))?,
            artifact: f[2].to_string(),
            task: f[3].to_string(),
            steps: f[4]
                .parse()
                .map_err(|_| anyhow::anyhow!("line {}: bad steps '{}'", no + 1, f[4]))?,
            seed: f[5]
                .parse()
                .map_err(|_| anyhow::anyhow!("line {}: bad seed '{}'", no + 1, f[5]))?,
            ff,
        });
    }
    anyhow::ensure!(!out.is_empty(), "manifest has no runs");
    Ok(out)
}

fn cmd_queue(args: &mut Args, artifacts: PathBuf) -> anyhow::Result<()> {
    let manifest = args.opt("manifest").ok_or_else(|| {
        anyhow::anyhow!(
            "--manifest FILE required (lines: tenant priority artifact task steps seed on|off)"
        )
    })?;
    let jobs = args.opt_usize("jobs", sched::default_jobs()).map_err(|e| anyhow::anyhow!(e))?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let runs = parse_manifest(&std::fs::read_to_string(&manifest)?)?;
    let rt = Runtime::cpu()?;
    // Pre-build each distinct model's W0 once, sequentially, so queued
    // runs share the in-memory Arc instead of racing the build lock.
    let mut bases: BTreeMap<String, Arc<BTreeMap<String, Tensor>>> = BTreeMap::new();
    for r in &runs {
        let model = model_of(&r.artifact).to_string();
        if let std::collections::btree_map::Entry::Vacant(slot) = bases.entry(model) {
            let base = Arc::new(ensure_pretrained(&rt, &artifacts, slot.key(), None)?);
            slot.insert(base);
        }
    }
    let cache = Arc::new(ArtifactCache::new(artifacts));
    let q = RunQueue::new(jobs);
    info!(
        "queue: {} submissions, {} worker(s){}",
        runs.len(),
        jobs,
        if sched::threads_enabled() {
            ""
        } else {
            " (no thread fan-out in this build: inline drain, priority order)"
        }
    );
    let mut labels: BTreeMap<u64, String> = BTreeMap::new();
    for (i, r) in runs.into_iter().enumerate() {
        let base = bases.get(model_of(&r.artifact)).cloned();
        let mut cfg = presets::train_config(&r.artifact, &r.task, 1)?;
        cfg.seed = r.seed;
        cfg.ff = FfConfig { enabled: r.ff, ..FfConfig::default() };
        let label = format!("{}/{}#{i}", r.tenant, r.artifact);
        let spec = RunSpec {
            label: label.clone(),
            cfg,
            stop: StopRule::MaxSteps(r.steps),
            base,
            drain_interval: None,
        };
        let h = q.submit_run(&rt, &cache, spec, r.priority, &r.tenant)?;
        labels.insert(h.seq(), label);
    }
    // Stream results in completion order: each run prints the moment it
    // finishes — a fast high-priority run never waits behind an earlier,
    // slower submission's join.
    let mut failed = 0usize;
    for c in q.completions() {
        let c = c?;
        let label = labels
            .remove(&c.seq)
            .unwrap_or_else(|| format!("{}#{}", c.tenant, c.seq));
        match c.result {
            Ok(RunResult::Done(o)) => println!(
                "done      {label}: test loss {:.4} | {} adam + {} sim steps | {:.1}s",
                o.summary.final_test_loss, o.summary.adam_steps, o.summary.sim_steps, o.seconds
            ),
            Ok(RunResult::Cancelled(Some(o))) => println!(
                "cancelled {label}: stopped at step boundary after {} adam steps",
                o.summary.adam_steps
            ),
            Ok(RunResult::Cancelled(None)) => {
                println!("cancelled {label}: never started");
            }
            Err(e) => {
                failed += 1;
                println!("FAILED    {label}: {e:#}");
            }
        }
    }
    println!("per-tenant accounting:");
    for (name, t) in q.tenants() {
        println!(
            "  {name}: {} submitted, {} done, {} cancelled, {} failed | \
             {} adam + {} sim steps, {} FF stages | {:.3e} FLOPs | {:.1}s \
             worker time | {}",
            t.submitted,
            t.completed,
            t.cancelled,
            t.failed,
            t.adam_steps,
            t.sim_steps,
            t.ff_stages,
            t.flops as f64,
            t.seconds,
            t.transfers.report()
        );
    }
    anyhow::ensure!(failed == 0, "{failed} queued run(s) failed");
    Ok(())
}

fn cmd_pretrain(args: &mut Args, artifacts: PathBuf) -> anyhow::Result<()> {
    let model = args.opt_or("model", "ff-tiny");
    let steps = args.opt_usize("steps", 0).map_err(|e| anyhow::anyhow!(e))?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let rt = Runtime::cpu()?;
    let ckpt = fastforward::train::pretrain::checkpoint_path(&artifacts, &model);
    if ckpt.exists() {
        std::fs::remove_file(&ckpt)?;
        info!("removed cached {}", ckpt.display());
    }
    let steps = if steps > 0 { Some(steps) } else { None };
    ensure_pretrained(&rt, &artifacts, &model, steps)?;
    println!("pretrained checkpoint: {}", ckpt.display());
    Ok(())
}

fn cmd_list(args: &mut Args, artifacts: PathBuf) -> anyhow::Result<()> {
    let experiments_only = args.flag("experiments");
    let presets_only = args.flag("presets");
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    if !experiments_only && !presets_only {
        match ArtifactIndex::load(&artifacts) {
            Ok(idx) => {
                println!("artifacts ({}):", idx.entries.len());
                for e in &idx.entries {
                    println!(
                        "  {:<28} {:>10} params {:>9} trainable",
                        e.key, e.n_params, e.n_trainable
                    );
                }
            }
            Err(e) => warn_!("no artifact index: {e}"),
        }
        println!("\nmodels (paper substitutes):");
        for m in presets::GRID_MODELS.iter().chain(["ff-xl"].iter()) {
            let mc = presets::model(m)?;
            println!(
                "  {:<10} {:>10} params  ↔ {}",
                m,
                mc.n_params(),
                presets::paper_model(m)
            );
        }
    }
    if presets_only {
        println!("task presets (paper Tables 1–3, scaled — see DESIGN.md):");
        for t in presets::TASKS {
            let p = presets::task_preset(t)?;
            println!(
                "  {:<9} lr={:<8} global_batch={:<4} lora_r={:<3} examples={}",
                t, p.lr, p.global_batch, p.lora_rank, p.train_examples
            );
        }
    }
    if !presets_only {
        println!("\nexperiments:");
        for (name, desc, _) in experiments::registry() {
            println!("  {name:<12} {desc}");
        }
    }
    Ok(())
}

fn cmd_selftest(args: &mut Args, artifacts: PathBuf) -> anyhow::Result<()> {
    let requested = args.opt_usize("jobs", 2).map_err(|e| anyhow::anyhow!(e))?.max(1);
    let with_churn = args.flag("churn");
    let with_queue = args.flag("queue") || with_churn;
    let with_shard = args.flag("shard");
    let with_policies = args.flag("policies");
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let mut total = if with_churn {
        8
    } else if with_queue {
        7
    } else {
        5
    };
    if with_policies {
        total += 1;
    }
    if with_shard {
        total += 1;
    }
    // The scheduler gate is part of the banner so degraded (sequential)
    // CI runs are visible in the logs, not silently green.
    println!(
        "selftest: scheduler thread fan-out {}",
        if sched::threads_enabled() {
            "ENABLED (xla-shared-client feature)"
        } else {
            "disabled (xla-shared-client off — pool and queue run sequentially)"
        }
    );
    let rt = Runtime::cpu()?;
    println!("[1/{total}] artifact index + manifest cross-check");
    let idx = ArtifactIndex::load(&artifacts)?;
    let man = idx.manifest("ff-tiny_lora_r8")?;
    println!("      ok: {} artifacts, checked '{}'", idx.entries.len(), man.key);

    println!("[2/{total}] pretrain (cached) + 12 SGD steps");
    let base = ensure_pretrained(&rt, &artifacts, "ff-tiny", Some(60))?;
    let mut cfg = presets::train_config("ff-tiny_lora_r8", "medical", 1)?;
    cfg.train_examples = 256;
    cfg.test_examples = 64;
    cfg.ff = FfConfig { warmup_steps: 4, t_interval: 4, ..FfConfig::default() };
    let mut t = Trainer::new(&rt, &artifacts, cfg, Some(&base))?;
    // compare held-out loss before/after (per-batch train loss is noisy)
    let first = t.eval_test()?;
    for _ in 0..12 {
        t.sgd_step()?;
    }
    let last = t.eval_test()?;
    anyhow::ensure!(last < first, "test loss did not decrease ({first} → {last})");
    println!("      ok: test loss {first:.4} → {last:.4}");

    println!("[3/{total}] fast-forward stage");
    let stats = t.ff_stage()?;
    println!(
        "      ok: τ*={} probes={} val {:.4}→{:.4}",
        stats.tau_star, stats.probes, stats.baseline_loss, stats.final_loss
    );

    println!("[4/{total}] pallas artifact parity");
    let art = fastforward::runtime::Artifact::load(&rt, &artifacts.join("ff-tiny_lora_r8_pallas"))?;
    anyhow::ensure!(art.manifest.config.use_pallas);
    art.program("eval_loss")?;
    println!("      ok: pallas eval_loss compiled");

    let pool = WorkerPool::new(requested);
    let jobs = pool.jobs();
    if requested > jobs {
        // Gated build: both batches run sequentially, so this leg checks
        // the pool path end-to-end plus *rerun* determinism over the
        // shared artifact/W0 caches (the bug class the checkpoint
        // temp-then-rename fix closed) — not cross-thread determinism,
        // which needs the xla-shared-client feature.
        println!(
            "[5/{total}] scheduler rerun determinism — NOTE: built without the \
             xla-shared-client feature, --jobs {requested} degrades to \
             sequential execution (see rust/XLA_AUDIT)"
        );
    } else {
        println!("[5/{total}] concurrent scheduler determinism ({jobs} worker(s) vs 1)");
    }
    let base = std::sync::Arc::new(base);
    let specs = |tag: &str| -> Vec<RunSpec> {
        (0..2u64)
            .map(|k| {
                let mut c = presets::train_config("ff-tiny_lora_r8", "medical", 1).unwrap();
                c.train_examples = 256;
                c.test_examples = 32;
                c.seed = 0x5eed + k;
                c.ff = FfConfig { enabled: false, ..FfConfig::default() };
                RunSpec {
                    label: format!("{tag}/seed{}", c.seed),
                    cfg: c,
                    stop: StopRule::MaxSteps(4),
                    base: Some(std::sync::Arc::clone(&base)),
                    drain_interval: None,
                }
            })
            .collect()
    };
    let cache = Arc::new(ArtifactCache::new(artifacts.clone()));
    let seq = WorkerPool::new(1).run_all(&rt, &cache, specs("seq"))?;
    let par = pool.run_all(&rt, &cache, specs("par"))?;
    for (a, b) in seq.outputs.iter().zip(par.outputs.iter()) {
        anyhow::ensure!(
            a.bit_identical(b),
            "scheduler changed a run's losses: {} vs {}",
            a.label,
            b.label
        );
    }
    println!(
        "      ok: {} runs bit-identical at jobs=1 and jobs={jobs} ({:.1}s vs {:.1}s wall)",
        seq.outputs.len(),
        seq.wall_seconds,
        par.wall_seconds
    );

    if with_queue {
        println!(
            "[6/{total}] multi-tenant run queue: priorities, cancel, join, \
             exact tenant accounting"
        );
        let before = rt.stats.snapshot();
        // Paused queue: submit a cold backlog (two tenants, mixed
        // priorities, one cancel victim), cancel before releasing so
        // cancel-before-start is deterministic in every build.
        let q = RunQueue::new_paused(requested);
        let queue_specs = specs("queue");
        let mut handles = Vec::new();
        for (i, spec) in queue_specs.into_iter().enumerate() {
            let (tenant, priority) = if i == 0 { ("alice", 0) } else { ("bob", 1) };
            handles.push(q.submit_run(&rt, &cache, spec, priority, tenant)?);
        }
        let victim_spec = {
            let mut s = specs("victim");
            s.truncate(1);
            s.remove(0)
        };
        let victim = q.submit_run(&rt, &cache, victim_spec, 5, "alice")?;
        victim.cancel();
        q.release();
        anyhow::ensure!(
            victim.join()?.is_cancelled(),
            "cancelled-before-start submission must join as Cancelled"
        );
        let mut outputs = Vec::new();
        for h in handles {
            match h.join()? {
                RunResult::Done(o) => outputs.push(o),
                RunResult::Cancelled(_) => anyhow::bail!("queue leg run came back cancelled"),
            }
        }
        // Bit-identical to the pool's sequential batch for equal specs,
        // and per-run exact meters equal too (per-engine metering).
        for (a, b) in seq.outputs.iter().zip(outputs.iter()) {
            anyhow::ensure!(
                a.bit_identical(b),
                "queue changed a run's losses: {} vs {}",
                a.label,
                b.label
            );
            anyhow::ensure!(
                a.summary.transfers == b.summary.transfers,
                "per-run exact meters diverged between pool and queue: {}",
                b.label
            );
        }
        // Tenant byte totals sum exactly to the global meter delta over
        // the queue section (the queue is quiescent at both endpoints).
        let delta = rt.stats.snapshot().since(&before);
        let mut summed = fastforward::runtime::TransferSnapshot::default();
        for t in q.tenants().values() {
            summed = summed.plus(&t.transfers);
        }
        anyhow::ensure!(
            summed == delta,
            "tenant transfer totals ({summed:?}) != global delta ({delta:?})"
        );
        let alice = q.tenant("alice");
        anyhow::ensure!(alice.cancelled == 1, "alice's victim must count as cancelled");
        println!(
            "      ok: {} queued runs bit-identical to the pool, victim cancelled \
             before start, tenant bytes sum exactly to the global delta ({})",
            outputs.len(),
            delta.report()
        );

        // Batched packing leg: K packable runs through one *_batched{K}
        // group must reproduce solo results bit-for-bit and slice the
        // group's transfer bytes exactly (docs/transfer-contract.md §5).
        let art = cache.load(&rt, "ff-tiny_lora_r8")?;
        let sizes = art.manifest.batched_group_sizes();
        if sizes.is_empty() {
            println!(
                "[7/{total}] batched packing: SKIPPED (artifacts predate *_batched \
                 programs — re-run make artifacts)"
            );
        } else {
            let k = sizes[0];
            println!(
                "[7/{total}] batched packing: {k} runs → one *_batched{k} group \
                 (bit-identity + per-run meter slices)"
            );
            let packable = |tag: &str| -> Vec<RunSpec> {
                (0..k as u64)
                    .map(|i| {
                        let mut c =
                            presets::train_config("ff-tiny_lora_r8", "medical", 1).unwrap();
                        c.train_examples = 256;
                        c.test_examples = 32;
                        c.global_batch = 8; // == micro_batch: one micro per step
                        c.seed = 0xbead + i;
                        c.ff = FfConfig { enabled: false, ..FfConfig::default() };
                        RunSpec {
                            label: format!("{tag}/seed{}", c.seed),
                            cfg: c,
                            stop: StopRule::MaxSteps(3),
                            base: Some(std::sync::Arc::clone(&base)),
                            drain_interval: None,
                        }
                    })
                    .collect()
            };
            let solo_q = RunQueue::new(1);
            let mut solo_handles = Vec::new();
            for s in packable("solo") {
                solo_handles.push(solo_q.submit_run(&rt, &cache, s, 0, "t")?);
            }
            let mut solo = Vec::new();
            for h in solo_handles {
                match h.join()? {
                    RunResult::Done(o) => solo.push(o),
                    RunResult::Cancelled(_) => anyhow::bail!("solo reference cancelled"),
                }
            }
            // One worker and a paused queue: all K are waiting when the
            // first pops, so the pack always forms at full size.
            let before = rt.stats.snapshot();
            let pq = RunQueue::new_paused(1);
            let mut handles = Vec::new();
            for s in packable("packed") {
                handles.push(pq.submit_run_packable(&rt, &cache, s, 0, "t")?);
            }
            pq.release();
            let mut packed = Vec::new();
            for h in handles {
                match h.join()? {
                    RunResult::Done(o) => packed.push(o),
                    RunResult::Cancelled(_) => anyhow::bail!("packed member cancelled"),
                }
            }
            let delta = rt.stats.snapshot().since(&before);
            for (a, b) in solo.iter().zip(packed.iter()) {
                anyhow::ensure!(
                    a.bit_identical(b),
                    "batched packing changed losses: {} vs {}",
                    a.label,
                    b.label
                );
            }
            let mut summed = fastforward::runtime::TransferSnapshot::default();
            for p in &packed {
                summed = summed.plus(&p.summary.transfers);
            }
            anyhow::ensure!(
                (summed.uploaded_bytes, summed.downloaded_bytes, summed.donated_bytes)
                    == (delta.uploaded_bytes, delta.downloaded_bytes, delta.donated_bytes),
                "member byte slices ({summed:?}) != global delta ({delta:?})"
            );
            let solo_up: usize = solo.iter().map(|s| s.summary.transfers.uploaded_bytes).sum();
            anyhow::ensure!(
                delta.uploaded_bytes < solo_up,
                "packed group moved {} uploaded bytes, not fewer than {} across \
                 {k} solo runs — packing did not share the frozen base",
                delta.uploaded_bytes,
                solo_up
            );
            println!(
                "      ok: {k} packed runs bit-identical to solo; member bytes sum \
                 exactly to the global delta ({})",
                delta.report()
            );
        }
    }

    if with_churn {
        println!(
            "[8/{total}] queue churn: seeded storm (exactly-once, deterministic \
             event log) + quantum park/resume accounting"
        );
        // Phase (a): closure storm. 2000 tiny submissions across 8
        // tenants with mixed priorities and ~10% cancelled while queued,
        // against a paused-then-released queue. Every handle must settle
        // exactly once, tenant counters must balance, and the same seed
        // must reproduce the same event log (sorted under thread
        // fan-out, where interleaving — but never the event *set* — may
        // vary).
        let storm = |seed: u64| -> anyhow::Result<(Vec<String>, usize, usize)> {
            const TENANTS: [&str; 8] = ["t0", "t1", "t2", "t3", "t4", "t5", "t6", "t7"];
            const SUBS: usize = 2000;
            let mut rng = fastforward::util::rng::Rng::new(seed);
            let q: RunQueue<usize> = RunQueue::new_paused(requested);
            let log = Arc::new(std::sync::Mutex::new(Vec::<String>::new()));
            let mut handles = Vec::new();
            for i in 0..SUBS {
                let tenant = TENANTS[rng.below(TENANTS.len())];
                let priority = rng.below(4) as i32;
                let log = Arc::clone(&log);
                let h = q
                    .submit(tenant, priority, move |_| {
                        log.lock().unwrap().push(format!("p{priority} {tenant} run{i}"));
                        Ok(i)
                    })
                    .map_err(|e| anyhow::anyhow!("storm submission {i} rejected: {e}"))?;
                if rng.below(10) == 0 {
                    h.cancel(); // while paused: deterministic cancel-before-start
                }
                handles.push((i, h));
            }
            q.release();
            let (mut done, mut cancelled) = (0usize, 0usize);
            for (i, h) in handles {
                match h.join()? {
                    RunResult::Done(v) => {
                        anyhow::ensure!(v == i, "cross-delivery: submission {i} returned {v}");
                        done += 1;
                    }
                    RunResult::Cancelled(_) => cancelled += 1,
                }
            }
            anyhow::ensure!(done + cancelled == SUBS, "lost submissions: {done}+{cancelled}");
            anyhow::ensure!(q.live() == 0, "queue not quiescent after all joins");
            let (mut sub, mut comp, mut canc, mut picked) = (0u64, 0u64, 0u64, 0u64);
            for t in q.tenants().values() {
                anyhow::ensure!(
                    t.completed + t.cancelled + t.failed == t.submitted,
                    "tenant counters do not balance: {t:?}"
                );
                sub += t.submitted;
                comp += t.completed;
                canc += t.cancelled;
                picked += t.picked;
            }
            anyhow::ensure!(
                sub == SUBS as u64 && comp == done as u64 && canc == cancelled as u64,
                "global counters ({sub}/{comp}/{canc}) != join tallies ({SUBS}/{done}/{cancelled})"
            );
            anyhow::ensure!(picked == comp, "closure jobs never park: picked must equal completed");
            let mut events = Arc::try_unwrap(log)
                .map_err(|_| anyhow::anyhow!("storm log still shared after all joins"))?
                .into_inner()
                .unwrap();
            anyhow::ensure!(
                events.len() == done,
                "event log ({}) != completions ({done})",
                events.len()
            );
            if sched::threads_enabled() {
                events.sort();
            }
            Ok((events, done, cancelled))
        };
        let (ev1, done, cancelled) = storm(0xc4a2_2024)?;
        let (ev2, ..) = storm(0xc4a2_2024)?;
        if ev1 != ev2 {
            eprintln!("--- churn storm event log, first run ---");
            for e in &ev1 {
                eprintln!("{e}");
            }
            eprintln!("--- churn storm event log, second run ---");
            for e in &ev2 {
                eprintln!("{e}");
            }
            anyhow::bail!("same-seed churn storms produced different event logs");
        }
        println!(
            "      ok: storm of 2000 submissions ({done} done, {cancelled} cancelled) \
             settled exactly once; same seed reproduced the event log"
        );

        // Phase (b): training churn — a step quantum of 2 forces each
        // 4-step run to park mid-flight and resume. Resumed runs must
        // report full step counts bit-identical to the uninterrupted
        // reference (leg 5), and per-tenant bytes must sum exactly to
        // the global meter delta *including* the park/resume overhead.
        let before = rt.stats.snapshot();
        let cq = RunQueue::new_paused(requested);
        cq.set_step_quantum(2);
        let mut churn_handles = Vec::new();
        for (i, spec) in specs("churn").into_iter().enumerate() {
            let tenant = if i == 0 { "carol" } else { "dave" };
            churn_handles.push(cq.submit_run(&rt, &cache, spec, 0, tenant)?);
        }
        let victim_spec = {
            let mut s = specs("churn-victim");
            s.truncate(1);
            s.remove(0)
        };
        let v = cq.submit_run(&rt, &cache, victim_spec, 0, "carol")?;
        v.cancel(); // cancelled while queued: must never bill a byte
        cq.release();
        anyhow::ensure!(v.join()?.is_cancelled(), "churn victim must join as Cancelled");
        let mut resumed = Vec::new();
        for h in churn_handles {
            match h.join()? {
                RunResult::Done(o) => resumed.push(o),
                RunResult::Cancelled(_) => anyhow::bail!("churn run came back cancelled"),
            }
        }
        for (a, b) in seq.outputs.iter().zip(resumed.iter()) {
            anyhow::ensure!(
                a.bit_identical(b),
                "park/resume changed a run's losses: {} vs {}",
                a.label,
                b.label
            );
            anyhow::ensure!(
                b.summary.adam_steps == a.summary.adam_steps,
                "resumed run lost steps: {} vs {} ({})",
                b.summary.adam_steps,
                a.summary.adam_steps,
                b.label
            );
        }
        let parked: u64 = cq.tenants().values().map(|t| t.parked).sum();
        anyhow::ensure!(
            parked >= resumed.len() as u64,
            "quantum 2 over 4-step runs must park each run at least once (saw {parked})"
        );
        let delta = rt.stats.snapshot().since(&before);
        let mut summed = fastforward::runtime::TransferSnapshot::default();
        for t in cq.tenants().values() {
            summed = summed.plus(&t.transfers);
        }
        anyhow::ensure!(
            summed == delta,
            "tenant transfer totals with park/resume ({summed:?}) != global delta ({delta:?})"
        );
        println!(
            "      ok: {parked} parked slots; resumed runs bit-identical to the \
             uninterrupted reference with full step counts; tenant bytes (incl. \
             park/resume overhead) sum exactly to the global delta ({})",
            delta.report()
        );
    }

    if with_policies {
        // Printed before the shard leg, which always claims the last slot.
        let leg = total - usize::from(with_shard);
        println!(
            "[{leg}/{total}] FF policies: per-policy park/resume bit-identity, \
             IntervalPolicy vs controller path, LoFT backend, streaming accounting"
        );
        use fastforward::config::{FfPolicyKind, OptimBackend};
        const STEPS: usize = 8;
        // warmup 3 + T_interval 3 guarantee FF stages inside the 8-step
        // budget, so park/resume crosses *policy state*, not just weights.
        let ff_spec = |tag: &str, kind: FfPolicyKind, backend: OptimBackend| -> RunSpec {
            let mut c = presets::train_config("ff-tiny_lora_r8", "medical", 1).unwrap();
            c.train_examples = 256;
            c.test_examples = 32;
            c.backend = backend;
            c.ff =
                FfConfig { warmup_steps: 3, t_interval: 3, policy: kind, ..FfConfig::default() };
            RunSpec {
                label: format!("{tag}/{}-{}", kind.as_str(), backend.as_str()),
                cfg: c,
                stop: StopRule::MaxSteps(STEPS),
                base: Some(Arc::clone(&base)),
                drain_interval: None,
            }
        };

        // (a) Every policy (plus the LoFT backend) must survive quantum-2
        // park/resume bit-identically: the tagged FfPosition snapshot is
        // what round-trips here, per policy.
        let mut pairs: Vec<(FfPolicyKind, OptimBackend)> =
            FfPolicyKind::ALL.iter().map(|&k| (k, OptimBackend::Adam)).collect();
        pairs.push((FfPolicyKind::Interval, OptimBackend::Loft));
        let mut refs = Vec::new();
        for &(kind, backend) in &pairs {
            let rq = RunQueue::new(1);
            let h = rq.submit_run(&rt, &cache, ff_spec("ref", kind, backend), 0, "pol")?;
            let reference = match h.join()? {
                RunResult::Done(o) => o,
                RunResult::Cancelled(_) => anyhow::bail!("policy reference cancelled"),
            };
            let cq = RunQueue::new_paused(requested);
            cq.set_step_quantum(2);
            let h = cq.submit_run(&rt, &cache, ff_spec("churn", kind, backend), 0, "pol")?;
            cq.release();
            let churned = match h.join()? {
                RunResult::Done(o) => o,
                RunResult::Cancelled(_) => anyhow::bail!("policy churn run cancelled"),
            };
            anyhow::ensure!(
                reference.bit_identical(&churned)
                    && churned.summary.adam_steps == reference.summary.adam_steps,
                "park/resume changed a {}/{} run",
                kind.as_str(),
                backend.as_str()
            );
            let parked: u64 = cq.tenants().values().map(|t| t.parked).sum();
            anyhow::ensure!(
                parked >= 1,
                "quantum 2 over an {STEPS}-step {}/{} run never parked",
                kind.as_str(),
                backend.as_str()
            );
            refs.push(reference);
        }
        anyhow::ensure!(
            !refs[0].stages.is_empty(),
            "interval reference ran no FF stage — the leg proved nothing"
        );

        // (b) The IntervalPolicy trait path (queue) against the legacy
        // FfController entry (direct Trainer::run): same decisions, same
        // bits.
        let spec = ff_spec("direct", FfPolicyKind::Interval, OptimBackend::Adam);
        let mut dt = Trainer::new(&rt, &artifacts, spec.cfg, Some(base.as_ref()))?;
        let direct = dt.run(&StopRule::MaxSteps(STEPS))?;
        anyhow::ensure!(
            direct.final_test_loss.to_bits() == refs[0].summary.final_test_loss.to_bits()
                && direct.adam_steps == refs[0].summary.adam_steps
                && direct.sim_steps == refs[0].summary.sim_steps,
            "IntervalPolicy (queue path) diverged from the FfController trainer path"
        );
        drop(dt);

        // (c) LoFT with decay 1.0 realigns the moments by exactly 1 —
        // a bit-exact no-op, so the whole run must match plain Adam.
        let mut loft_spec = ff_spec("loft1", FfPolicyKind::Interval, OptimBackend::Loft);
        loft_spec.cfg.loft_decay = 1.0;
        let rq = RunQueue::new(1);
        let loft1 = match rq.submit_run(&rt, &cache, loft_spec, 0, "pol")?.join()? {
            RunResult::Done(o) => o,
            RunResult::Cancelled(_) => anyhow::bail!("loft decay-1 run cancelled"),
        };
        anyhow::ensure!(
            loft1.bit_identical(&refs[0]),
            "LoFT(decay=1) must match the Adam backend bit-for-bit"
        );

        // (d) Streaming run: the tenant feeds one step's worth of
        // examples at a time, then closes the stream. The run must be
        // bit-identical to its batch twin, and the streaming tenant's
        // byte totals must still sum exactly to the global meter delta
        // (holds and resumes included).
        let before = rt.stats.snapshot();
        let sq = RunQueue::new(requested);
        let spec = ff_spec("stream", FfPolicyKind::Interval, OptimBackend::Adam);
        let gb = spec.cfg.global_batch as u64;
        let (h, stream) = sq.submit_stream(&rt, &cache, spec, 0, "erin")?;
        for _ in 0..STEPS {
            stream.feed(gb);
        }
        stream.finish();
        let streamed = match h.join()? {
            RunResult::Done(o) => o,
            RunResult::Cancelled(_) => anyhow::bail!("streaming run cancelled"),
        };
        anyhow::ensure!(
            streamed.bit_identical(&refs[0])
                && streamed.summary.adam_steps == refs[0].summary.adam_steps,
            "streaming run diverged from its batch twin"
        );
        let delta = rt.stats.snapshot().since(&before);
        let mut summed = fastforward::runtime::TransferSnapshot::default();
        for t in sq.tenants().values() {
            summed = summed.plus(&t.transfers);
        }
        anyhow::ensure!(
            summed == delta,
            "streaming tenant bytes ({summed:?}) != global delta ({delta:?})"
        );
        println!(
            "      ok: {} policy/backend pairs park/resume bit-identical ({} FF \
             stages on the interval reference); trait path == controller path; \
             LoFT(decay=1) == Adam; streamed run bit-identical with exact tenant \
             bytes ({})",
            pairs.len(),
            refs[0].stages.len(),
            delta.report()
        );
    }

    if with_shard {
        println!(
            "[{total}/{total}] cross-host grid sharding: 2 shards + store vs \
             unsharded (byte-identical merge, warm shard served from the store)"
        );
        let scratch =
            std::env::temp_dir().join(format!("ff-selftest-shard-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&scratch);
        std::fs::create_dir_all(&scratch)?;
        // A tiny 4-cell grid (2 seeds × FF on/off) over the same artifact
        // and task as the legs above, 4 steps per cell. Only fields the
        // manifest serializes are set, so the save/load round trip below
        // is behavior-preserving.
        let mut cells = Vec::new();
        for (i, (seed, ff)) in
            [(0x5eedu64, false), (0x5eed, true), (0x5eee, false), (0x5eee, true)]
                .iter()
                .enumerate()
        {
            let mut c = presets::train_config("ff-tiny_lora_r8", "medical", 1)?;
            c.train_examples = 256;
            c.test_examples = 32;
            c.max_steps = 4;
            c.seed = *seed;
            c.ff.enabled = *ff;
            cells.push(grid::CellSpec {
                index: i,
                label: format!("seed{seed:x}/{}", if *ff { "ff" } else { "base" }),
                cfg: c,
            });
        }
        let manifest = GridManifest { name: "selftest".into(), cells };
        // Exercise the wire format: everything below runs off the
        // round-tripped manifest, exactly like a second host would.
        let mpath = scratch.join("grid-selftest.manifest.json");
        manifest.save(&mpath)?;
        let manifest = GridManifest::load(&mpath)?;
        let lock = GridLock::emit(&manifest, &artifacts)?;

        // Unsharded reference: local artifacts, no store.
        let r0 = grid::run_grid(
            &rt,
            &artifacts,
            None,
            &manifest,
            Some(&lock),
            None,
            &scratch.join("unsharded"),
            1,
        )?;
        // Host A: shard 1/2 from the local root, publishing into a fresh
        // store (cold: ingests artifacts, publishes W0).
        let store = Arc::new(ArtifactStore::open(scratch.join("store"))?);
        let shards_out = scratch.join("shards");
        let s1 = grid::run_grid(
            &rt,
            &artifacts,
            Some(Arc::clone(&store)),
            &manifest,
            Some(&lock),
            Some((1, 2)),
            &shards_out,
            1,
        )?;
        // Host B: shard 2/2 from an EMPTY artifacts root — programs and W0
        // must come out of the store: zero compiles, zero W0 rebuilds.
        let cold_root = scratch.join("host-b-artifacts");
        std::fs::create_dir_all(&cold_root)?;
        let s2 = grid::run_grid(
            &rt,
            &cold_root,
            Some(Arc::clone(&store)),
            &manifest,
            Some(&lock),
            Some((2, 2)),
            &shards_out,
            1,
        )?;
        let warm = s2.store.ok_or_else(|| anyhow::anyhow!("shard 2 ran without store stats"))?;
        anyhow::ensure!(
            warm.all_hits() && warm.artifact_hits > 0 && warm.w0_hits > 0,
            "warm shard on an empty root was not served entirely from the store: {}",
            warm.report()
        );
        anyhow::ensure!(
            r0.cells_run == s1.cells_run + s2.cells_run,
            "shards covered {} + {} cells, unsharded ran {}",
            s1.cells_run,
            s2.cells_run,
            r0.cells_run
        );
        let merged = grid::merge_shards(
            &[s1.report_path.clone(), s2.report_path.clone()],
            &scratch.join("merged"),
        )?;
        let reference = std::fs::read(&r0.report_path)?;
        let folded = std::fs::read(&merged)?;
        anyhow::ensure!(
            reference == folded,
            "merged shard report differs from the unsharded reference \
             ({} vs {})",
            merged.display(),
            r0.report_path.display()
        );
        println!(
            "      ok: {} + {} sharded cells merged byte-identical to the \
             {}-cell unsharded report; warm shard: {}",
            s1.cells_run,
            s2.cells_run,
            r0.cells_run,
            warm.report()
        );
        let _ = std::fs::remove_dir_all(&scratch);
    }
    println!("selftest passed");
    Ok(())
}
