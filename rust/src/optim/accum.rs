//! Gradient accumulation across micro-batches — the coordinator's
//! micro-batch scheduler sums `grad_step` outputs here and hands the mean
//! to one `adam_apply` per *global* batch (paper Appendix E batch shapes).

use crate::model::tensor::Tensor;

#[derive(Debug)]
pub struct GradAccumulator {
    sum: Vec<Tensor>,
    count: usize,
    /// Mean loss across accumulated micro-batches.
    loss_sum: f64,
}

impl GradAccumulator {
    pub fn new(shapes: &[Vec<usize>]) -> GradAccumulator {
        GradAccumulator {
            sum: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            count: 0,
            loss_sum: 0.0,
        }
    }

    pub fn zeros_like(params: &[Tensor]) -> GradAccumulator {
        Self::new(&params.iter().map(|t| t.shape.clone()).collect::<Vec<_>>())
    }

    /// Add one micro-batch's gradients (flat slices in param order).
    pub fn add_flat(&mut self, grads: &[&[f32]], loss: f32) {
        assert_eq!(grads.len(), self.sum.len());
        for (acc, g) in self.sum.iter_mut().zip(grads.iter()) {
            debug_assert_eq!(acc.data.len(), g.len());
            for (a, x) in acc.data.iter_mut().zip(g.iter()) {
                *a += x;
            }
        }
        self.loss_sum += loss as f64;
        self.count += 1;
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Mean gradients + mean loss; resets the accumulator.
    pub fn take_mean(&mut self) -> (Vec<Tensor>, f32) {
        assert!(self.count > 0, "take_mean on empty accumulator");
        let scale = 1.0 / self.count as f32;
        let mut out = Vec::with_capacity(self.sum.len());
        for t in self.sum.iter_mut() {
            let mut g = Tensor::zeros(&t.shape);
            for (o, s) in g.data.iter_mut().zip(t.data.iter()) {
                *o = s * scale;
            }
            t.fill(0.0);
            out.push(g);
        }
        let loss = (self.loss_sum / self.count as f64) as f32;
        self.loss_sum = 0.0;
        self.count = 0;
        (out, loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_of_two_micro_batches() {
        let mut acc = GradAccumulator::new(&[vec![2]]);
        acc.add_flat(&[&[1.0, 2.0]], 1.0);
        acc.add_flat(&[&[3.0, 4.0]], 3.0);
        assert_eq!(acc.count(), 2);
        let (g, loss) = acc.take_mean();
        assert_eq!(g[0].data, vec![2.0, 3.0]);
        assert_eq!(loss, 2.0);
        // reset: accumulating again starts fresh
        acc.add_flat(&[&[10.0, 10.0]], 5.0);
        let (g2, loss2) = acc.take_mean();
        assert_eq!(g2[0].data, vec![10.0, 10.0]);
        assert_eq!(loss2, 5.0);
    }

    #[test]
    fn single_micro_batch_is_identity() {
        let mut acc = GradAccumulator::new(&[vec![3]]);
        acc.add_flat(&[&[1.0, -1.0, 0.5]], 2.5);
        let (g, loss) = acc.take_mean();
        assert_eq!(g[0].data, vec![1.0, -1.0, 0.5]);
        assert_eq!(loss, 2.5);
    }

    #[test]
    #[should_panic(expected = "empty accumulator")]
    fn empty_take_mean_panics() {
        GradAccumulator::new(&[vec![1]]).take_mean();
    }
}
