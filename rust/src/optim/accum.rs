//! Gradient accumulation across micro-batches — two implementations of the
//! same contract (sum `grad_step` outputs, hand the mean to one
//! `adam_apply` per *global* batch, paper Appendix E batch shapes):
//!
//! * [`DeviceGradAccumulator`] — the trainer's default. Per-micro gradient
//!   buffers stay on the device: the first micro-batch's `grad_step`
//!   outputs *become* the accumulator (no zeros upload), later micros run
//!   the AOT `grad_accum` program (`acc + g`) donating the previous
//!   accumulator so the allocation is reused in place, and
//!   [`DeviceGradAccumulator::finalize`] scales by `1/n` through
//!   `grad_finalize` (also donated). Only the per-micro loss scalar (4
//!   bytes) ever crosses to the host — the last O(|trainable|) per-step
//!   upload (the mean-gradient upload into `adam_apply`) is gone.
//! * [`GradAccumulator`] — the host-side reference path. Kept for
//!   artifacts that predate the `grad_accum` program and for
//!   `Trainer::keep_micro_grads` runs (Fig 13 needs every micro gradient
//!   host-side anyway); also the numeric cross-check for the device path
//!   in `rust/tests/runtime_roundtrip.rs`.

use anyhow::{ensure, Result};

use crate::model::tensor::Tensor;
use crate::runtime::{InputBuf, Program, TransferMeter};

/// Device-resident micro-batch gradient accumulator (see module docs).
///
/// State machine per optimizer step: empty → (first `add_raw` adopts the
/// gradient buffers) → (later `add_raw`s fold them through `grad_accum`,
/// donating the old accumulator) → `finalize` returns the mean-gradient
/// buffers (ready to donate into `adam_apply`) and resets to empty.
#[derive(Default)]
pub struct DeviceGradAccumulator {
    acc: Vec<xla::PjRtBuffer>,
    count: usize,
    loss_sum: f64,
}

impl DeviceGradAccumulator {
    pub fn new() -> DeviceGradAccumulator {
        Self::default()
    }

    /// Micro-batches folded in since the last `finalize`.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Fold in one micro-batch's gradient buffers **without** a decoded
    /// loss — the pipelined step engine keeps the loss scalar deferred on
    /// the device (`runtime::stream::PendingLoss`) and never sees its
    /// value. The first call adopts the buffers as the accumulator
    /// outright; later calls dispatch `accum_prog` (`acc + g`), donating
    /// the previous accumulator so its allocation is reused for the new
    /// sum. `meter` is the owning run's exact [`TransferMeter`], if any:
    /// accumulator donations are that run's traffic.
    pub fn add_raw_bufs(
        &mut self,
        accum_prog: &Program,
        grads: Vec<xla::PjRtBuffer>,
        meter: Option<&TransferMeter>,
    ) -> Result<()> {
        if self.acc.is_empty() {
            self.acc = grads;
        } else {
            ensure!(
                grads.len() == self.acc.len(),
                "grad_accum arity: {} grads vs {} accumulated",
                grads.len(),
                self.acc.len()
            );
            let mut inputs: Vec<InputBuf> = Vec::with_capacity(2 * grads.len());
            inputs.extend(std::mem::take(&mut self.acc).into_iter().map(InputBuf::Donated));
            inputs.extend(grads.iter().map(InputBuf::Borrowed));
            self.acc = accum_prog.execute_raw_donated_metered(inputs, meter)?;
            // `grads` buffers die here: their allocations free immediately
        }
        self.count += 1;
        Ok(())
    }

    /// Fold in one micro-batch: `grads` are the raw `grad_step` output
    /// buffers (loss leaf already stripped), `loss` its decoded scalar.
    /// Synchronous-readback variant of [`Self::add_raw_bufs`], kept for
    /// callers that already hold the loss host-side.
    pub fn add_raw(
        &mut self,
        accum_prog: &Program,
        grads: Vec<xla::PjRtBuffer>,
        loss: f32,
    ) -> Result<()> {
        self.add_raw_bufs(accum_prog, grads, None)?;
        self.loss_sum += loss as f64;
        Ok(())
    }

    /// Scale the accumulated sum to the mean (`grad_finalize`, donated)
    /// and return the mean-gradient buffers, resetting the accumulator.
    /// `inv_n` must hold `1.0 / count()` as a device scalar; a
    /// single-micro step skips the dispatch entirely (the mean of one
    /// gradient is itself). `meter` as in [`Self::add_raw_bufs`].
    pub fn finalize_bufs(
        &mut self,
        finalize_prog: &Program,
        inv_n: &xla::PjRtBuffer,
        meter: Option<&TransferMeter>,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        assert!(self.count > 0, "finalize on empty accumulator");
        let acc = std::mem::take(&mut self.acc);
        let mean = if self.count == 1 {
            acc
        } else {
            let mut inputs: Vec<InputBuf> = Vec::with_capacity(acc.len() + 1);
            inputs.extend(acc.into_iter().map(InputBuf::Donated));
            inputs.push(InputBuf::Borrowed(inv_n));
            finalize_prog.execute_raw_donated_metered(inputs, meter)?
        };
        self.count = 0;
        self.loss_sum = 0.0;
        Ok(mean)
    }

    /// [`Self::finalize_bufs`] plus the mean of the losses fed through
    /// [`Self::add_raw`] (the synchronous-readback pairing).
    pub fn finalize(
        &mut self,
        finalize_prog: &Program,
        inv_n: &xla::PjRtBuffer,
    ) -> Result<(Vec<xla::PjRtBuffer>, f32)> {
        assert!(self.count > 0, "finalize on empty accumulator");
        let mean_loss = (self.loss_sum / self.count as f64) as f32;
        let mean = self.finalize_bufs(finalize_prog, inv_n, None)?;
        Ok((mean, mean_loss))
    }
}

#[derive(Debug)]
pub struct GradAccumulator {
    sum: Vec<Tensor>,
    count: usize,
    /// Mean loss across accumulated micro-batches.
    loss_sum: f64,
}

impl GradAccumulator {
    pub fn new(shapes: &[Vec<usize>]) -> GradAccumulator {
        GradAccumulator {
            sum: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            count: 0,
            loss_sum: 0.0,
        }
    }

    pub fn zeros_like(params: &[Tensor]) -> GradAccumulator {
        Self::new(&params.iter().map(|t| t.shape.clone()).collect::<Vec<_>>())
    }

    /// Add one micro-batch's gradients (flat slices in param order).
    pub fn add_flat(&mut self, grads: &[&[f32]], loss: f32) {
        assert_eq!(grads.len(), self.sum.len());
        for (acc, g) in self.sum.iter_mut().zip(grads.iter()) {
            debug_assert_eq!(acc.data.len(), g.len());
            for (a, x) in acc.data.iter_mut().zip(g.iter()) {
                *a += x;
            }
        }
        self.loss_sum += loss as f64;
        self.count += 1;
    }

    pub fn count(&self) -> usize {
        self.count
    }

    /// Mean gradients + mean loss; resets the accumulator.
    pub fn take_mean(&mut self) -> (Vec<Tensor>, f32) {
        assert!(self.count > 0, "take_mean on empty accumulator");
        let scale = 1.0 / self.count as f32;
        let mut out = Vec::with_capacity(self.sum.len());
        for t in self.sum.iter_mut() {
            let mut g = Tensor::zeros(&t.shape);
            for (o, s) in g.data.iter_mut().zip(t.data.iter()) {
                *o = s * scale;
            }
            t.fill(0.0);
            out.push(g);
        }
        let loss = (self.loss_sum / self.count as f64) as f32;
        self.loss_sum = 0.0;
        self.count = 0;
        (out, loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_of_two_micro_batches() {
        let mut acc = GradAccumulator::new(&[vec![2]]);
        acc.add_flat(&[&[1.0, 2.0]], 1.0);
        acc.add_flat(&[&[3.0, 4.0]], 3.0);
        assert_eq!(acc.count(), 2);
        let (g, loss) = acc.take_mean();
        assert_eq!(g[0].data, vec![2.0, 3.0]);
        assert_eq!(loss, 2.0);
        // reset: accumulating again starts fresh
        acc.add_flat(&[&[10.0, 10.0]], 5.0);
        let (g2, loss2) = acc.take_mean();
        assert_eq!(g2[0].data, vec![10.0, 10.0]);
        assert_eq!(loss2, 5.0);
    }

    #[test]
    fn single_micro_batch_is_identity() {
        let mut acc = GradAccumulator::new(&[vec![3]]);
        acc.add_flat(&[&[1.0, -1.0, 0.5]], 2.5);
        let (g, loss) = acc.take_mean();
        assert_eq!(g[0].data, vec![1.0, -1.0, 0.5]);
        assert_eq!(loss, 2.5);
    }

    #[test]
    #[should_panic(expected = "empty accumulator")]
    fn empty_take_mean_panics() {
        GradAccumulator::new(&[vec![1]]).take_mean();
    }
}
