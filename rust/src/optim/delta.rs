//! Δ_W tracking — the heart of Fast Forward (paper Eq. 2):
//!
//! > During a Fast Forward stage, for each trainable parameter, the
//! > difference between weights in the current and previous timesteps is
//! > calculated: Δ_W = W_t − W_{t−1}.
//!
//! The tracker snapshots W before each optimizer step and can emit the
//! resulting Δ after it — exactly "the most recent optimizer step".
//!
//! With device-resident training state the optimizer's outputs live on the
//! device until first host access; [`DeltaTracker::begin_step`] /
//! [`DeltaTracker::end_step`] wrap the raw slice API with a
//! `ParamSet::sync_host` so Δ_W is always computed from *synced* host
//! views, never stale ones.

use anyhow::Result;

use crate::model::tensor::Tensor;
use crate::runtime::ParamSet;

#[derive(Debug, Default)]
pub struct DeltaTracker {
    prev: Option<Vec<Tensor>>,
    delta: Option<Vec<Tensor>>,
}

impl DeltaTracker {
    pub fn new() -> DeltaTracker {
        DeltaTracker::default()
    }

    /// Record W_{t−1} (call immediately before an optimizer step).
    pub fn snapshot_before(&mut self, params: &[Tensor]) {
        self.prev = Some(params.to_vec());
    }

    /// Record W_{t−1} from a ParamSet, downloading any device-ahead
    /// tensors first (call immediately before an optimizer step).
    pub fn begin_step(&mut self, params: &mut ParamSet) -> Result<()> {
        params.sync_host()?;
        self.snapshot_before(params.tensors());
        Ok(())
    }

    /// Compute Δ_W = W_t − W_{t−1} from a ParamSet, downloading any
    /// device-ahead tensors first (call immediately after the step).
    pub fn end_step(&mut self, params: &mut ParamSet) -> Result<()> {
        params.sync_host()?;
        self.compute_after(params.tensors());
        Ok(())
    }

    /// Compute Δ_W = W_t − W_{t−1} (call immediately after the step).
    pub fn compute_after(&mut self, params: &[Tensor]) {
        let prev = self.prev.as_ref().expect("snapshot_before not called");
        let delta = params
            .iter()
            .zip(prev.iter())
            .map(|(now, before)| Tensor::sub_from(now, before))
            .collect();
        self.delta = Some(delta);
    }

    /// The most recent optimizer step direction, if any.
    pub fn delta(&self) -> Option<&[Tensor]> {
        self.delta.as_deref()
    }

    /// ‖Δ_W‖₂ over all trainables.
    pub fn delta_norm(&self) -> Option<f64> {
        self.delta.as_ref().map(|d| crate::model::tensor::list_norm(d))
    }

    pub fn clear(&mut self) {
        self.prev = None;
        self.delta = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_one_step() {
        let w0 = vec![Tensor::from_vec(&[2], vec![1.0, 2.0])];
        let w1 = vec![Tensor::from_vec(&[2], vec![1.5, 1.0])];
        let mut d = DeltaTracker::new();
        assert!(d.delta().is_none());
        d.snapshot_before(&w0);
        d.compute_after(&w1);
        assert_eq!(d.delta().unwrap()[0].data, vec![0.5, -1.0]);
        let norm = d.delta_norm().unwrap();
        assert!((norm - (0.25f64 + 1.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn repeated_steps_keep_latest() {
        let mut d = DeltaTracker::new();
        for i in 0..3 {
            let before = vec![Tensor::from_vec(&[1], vec![i as f32])];
            let after = vec![Tensor::from_vec(&[1], vec![i as f32 + (i + 1) as f32])];
            d.snapshot_before(&before);
            d.compute_after(&after);
        }
        assert_eq!(d.delta().unwrap()[0].data, vec![3.0]);
        d.clear();
        assert!(d.delta().is_none());
    }

    #[test]
    #[should_panic(expected = "snapshot_before")]
    fn compute_without_snapshot_panics() {
        DeltaTracker::new().compute_after(&[Tensor::zeros(&[1])]);
    }

    #[test]
    fn begin_end_step_sync_device_ahead_state() {
        use crate::runtime::Runtime;
        use std::collections::BTreeMap;
        let rt = Runtime::cpu().unwrap();
        let spec = vec![("w".to_string(), vec![2])];
        let mut vals = BTreeMap::new();
        vals.insert("w".into(), Tensor::from_vec(&[2], vec![1.0, 2.0]));
        let mut ps = ParamSet::from_spec(&rt, &spec, &vals).unwrap();

        let mut d = DeltaTracker::new();
        d.begin_step(&mut ps).unwrap();
        // simulate an optimizer step whose output stays on the device
        let buf = rt.upload_f32(&[1.5, 1.0], &[2]).unwrap();
        ps.adopt_device(0, buf);
        d.end_step(&mut ps).unwrap();
        // Δ_W computed from the synced host view, not the stale one
        assert_eq!(d.delta().unwrap()[0].data, vec![0.5, -1.0]);
        assert!(ps.host_in_sync());
        assert_eq!(ps.download_count(), 1);
    }
}
