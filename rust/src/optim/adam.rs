//! Reference host-side Adam — the same math as the HLO `adam_apply`
//! program (`python/compile/model.adam_update`). The training loop runs
//! Adam *on device* through the artifact; this host implementation exists
//! (a) as an independent oracle the integration tests compare against, and
//! (b) for host-only experiments (e.g. unit-testing the FF controller with
//! a synthetic quadratic objective, no XLA involved).

use crate::config::AdamConfig;
use crate::model::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct AdamState {
    pub cfg: AdamConfig,
    pub m: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// Steps already applied (f32 on the HLO side; u64 here).
    pub step: u64,
}

impl AdamState {
    pub fn new(cfg: AdamConfig, shapes: &[Vec<usize>]) -> AdamState {
        AdamState {
            cfg,
            m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            v: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            step: 0,
        }
    }

    pub fn zeros_like(cfg: AdamConfig, params: &[Tensor]) -> AdamState {
        AdamState::new(cfg, &params.iter().map(|t| t.shape.clone()).collect::<Vec<_>>())
    }

    /// One Adam update, in place on `params`.
    pub fn apply(&mut self, params: &mut [Tensor], grads: &[Tensor], lr: f32) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        let (b1, b2, eps) = (self.cfg.beta1, self.cfg.beta2, self.cfg.eps);
        let step1 = (self.step + 1) as f32;
        let bc1 = 1.0 - b1.powf(step1);
        let bc2 = 1.0 - b2.powf(step1);
        for ((w, g), (m, v)) in params
            .iter_mut()
            .zip(grads.iter())
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            for i in 0..w.data.len() {
                let gi = g.data[i];
                m.data[i] = b1 * m.data[i] + (1.0 - b1) * gi;
                v.data[i] = b2 * v.data[i] + (1.0 - b2) * gi * gi;
                let update = lr * (m.data[i] / bc1) / ((v.data[i] / bc2).sqrt() + eps);
                w.data[i] -= update;
            }
        }
        self.step += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(v: &[f32]) -> Vec<Tensor> {
        vec![Tensor::from_vec(&[v.len()], v.to_vec())]
    }

    #[test]
    fn first_step_is_lr_times_sign() {
        let mut p = one(&[0.0, 0.0, 0.0]);
        let g = one(&[0.5, -2.0, 3.0]);
        let mut st = AdamState::zeros_like(AdamConfig::default(), &p);
        st.apply(&mut p, &g, 0.1);
        for (w, gi) in p[0].data.iter().zip(g[0].data.iter()) {
            assert!((w + 0.1 * gi.signum()).abs() < 1e-3, "{w} vs {gi}");
        }
        assert_eq!(st.step, 1);
    }

    #[test]
    fn zero_grad_keeps_weights_with_zero_state() {
        let mut p = one(&[1.0, -1.0]);
        let g = one(&[0.0, 0.0]);
        let mut st = AdamState::zeros_like(AdamConfig::default(), &p);
        st.apply(&mut p, &g, 0.1);
        assert_eq!(p[0].data, vec![1.0, -1.0]);
    }

    #[test]
    fn converges_on_quadratic() {
        // minimize f(x) = Σ (x_i - c_i)^2 — Adam must land near c.
        let c = [3.0f32, -2.0, 0.5];
        let mut p = one(&[0.0, 0.0, 0.0]);
        let mut st = AdamState::zeros_like(AdamConfig::default(), &p);
        for _ in 0..800 {
            let g: Vec<f32> =
                p[0].data.iter().zip(c.iter()).map(|(x, ci)| 2.0 * (x - ci)).collect();
            let g = one(&g);
            st.apply(&mut p, &g, 0.05);
        }
        for (x, ci) in p[0].data.iter().zip(c.iter()) {
            assert!((x - ci).abs() < 0.05, "{x} vs {ci}");
        }
    }

    #[test]
    fn matches_double_precision_reference() {
        // Property: repeated updates track an f64 reference within f32 tol.
        crate::util::prop::check(20, |gen| {
            let n = gen.usize_in(1, 16);
            let mut w32 = Tensor::from_vec(&[n], gen.vec_f32(n, 1.0));
            let mut w64: Vec<f64> = w32.data.iter().map(|x| *x as f64).collect();
            let mut st = AdamState::zeros_like(AdamConfig::default(), std::slice::from_ref(&w32));
            let (mut m64, mut v64) = (vec![0.0f64; n], vec![0.0f64; n]);
            let lr = gen.f32_in(1e-4, 1e-2);
            for step in 0..10u64 {
                let g = Tensor::from_vec(&[n], gen.vec_f32(n, 1.0));
                let mut ws = [w32.clone()];
                st.apply(&mut ws, std::slice::from_ref(&g), lr);
                w32 = ws[0].clone();
                let (b1, b2, eps) = (0.9f64, 0.999f64, 1e-8f64);
                let bc1 = 1.0 - b1.powi(step as i32 + 1);
                let bc2 = 1.0 - b2.powi(step as i32 + 1);
                for i in 0..n {
                    let gi = g.data[i] as f64;
                    m64[i] = b1 * m64[i] + (1.0 - b1) * gi;
                    v64[i] = b2 * v64[i] + (1.0 - b2) * gi * gi;
                    w64[i] -= lr as f64 * (m64[i] / bc1) / ((v64[i] / bc2).sqrt() + eps);
                }
            }
            for i in 0..n {
                let d = (w32.data[i] as f64 - w64[i]).abs();
                if d > 1e-4 {
                    return Err(format!("drift {d} at {i}"));
                }
            }
            Ok(())
        });
    }
}
