//! Host-side optimizer substrate: reference Adam (cross-checked against
//! the HLO `adam_apply` by integration test), gradient accumulation, and
//! the Δ_W tracking FF extrapolates along.

pub mod accum;
pub mod adam;
pub mod delta;

pub use accum::GradAccumulator;
pub use adam::AdamState;
pub use delta::DeltaTracker;
