//! Optimizer substrate: reference Adam (cross-checked against the HLO
//! `adam_apply` by integration test), micro-batch gradient accumulation
//! (device-resident by default, host-side as fallback/reference), and the
//! Δ_W tracking FF extrapolates along.

pub mod accum;
pub mod adam;
pub mod delta;

pub use accum::{DeviceGradAccumulator, GradAccumulator};
pub use adam::AdamState;
pub use delta::DeltaTracker;
