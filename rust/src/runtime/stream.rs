//! Execution stream: deferred loss readback for pipelined step dispatch.
//!
//! PJRT executes asynchronously — `execute_b` enqueues the computation and
//! hands back device buffers immediately; only `to_literal_sync` blocks the
//! host until the value is ready. The old step loop squandered that: it
//! downloaded every micro-batch's 4-byte loss scalar the moment the
//! dispatch returned, turning each micro-batch into a full host↔device
//! round-trip ("Run LoRA Run" and "LoRA Is Slower Than You Think" both
//! find exactly this launch/transfer overhead — not FLOPs — dominating
//! low-rank training).
//!
//! [`ExecStream`] is the fix. Dispatch sites wrap each loss scalar in a
//! [`PendingLoss`] (the raw device buffer plus the program/slot needed to
//! decode it later) and push one [`PendingStep`] per optimizer step into a
//! bounded ring. Nothing crosses to the host until either
//!
//! * the ring reaches its **drain interval** K (`push` then drains the
//!   whole ring and returns the resolved steps), or
//! * a **forced sync** ([`ExecStream::sync`]) at a pipeline boundary — FF
//!   stage entry, eval, snapshot/checkpoint, a caller that needs this
//!   step's loss now, or shutdown — drains everything that is pending.
//!
//! Draining preserves FIFO order, downloads each deferred loss through the
//! same metered [`Program::download_output`] path the synchronous code
//! used (same bytes, later), and computes each step's mean micro-batch
//! loss with the same f64 accumulation — so **drain-every-1 is bit-for-bit
//! the old synchronous behaviour**, which
//! `rust/tests/trainer_e2e.rs::deferred_readback_matches_synchronous_losses`
//! asserts. The ordering rules (when a host sync is forced and why) are
//! documented in `docs/transfer-contract.md` §4 and `docs/step-pipeline.md`.

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use anyhow::Result;

use super::{Program, TransferMeter};

/// Why a host sync (ring drain) was forced — kept per-reason in
/// [`StreamStats`] so the pipeline's sync points are observable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncReason {
    /// A caller needs this step's loss value now (the synchronous
    /// `Trainer::sgd_step` wrapper).
    StepResult,
    /// Entering a Fast Forward stage: Δ_W and the stage stats must reflect
    /// a fully retired optimizer step.
    FfBoundary,
    /// A val/test evaluation is about to run; the run log must be current.
    Eval,
    /// A host-side parameter snapshot (checkpointing, analysis probes).
    Snapshot,
    /// End of the run loop: retire everything before the final eval.
    Shutdown,
}

impl SyncReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            SyncReason::StepResult => "step_result",
            SyncReason::FfBoundary => "ff_boundary",
            SyncReason::Eval => "eval",
            SyncReason::Snapshot => "snapshot",
            SyncReason::Shutdown => "shutdown",
        }
    }
}

/// One not-yet-downloaded scalar program output: the raw device buffer
/// plus the compiled program and output-slot index needed to decode it.
/// Holding the buffer keeps the value alive device-side; `wait` performs
/// the (metered) download.
pub struct PendingLoss {
    prog: Arc<Program>,
    buf: xla::PjRtBuffer,
    slot: usize,
    /// The owning run's exact per-run meter, if any: a deferred loss is
    /// still that run's download, whenever the ring drains it.
    meter: Option<Arc<TransferMeter>>,
}

impl PendingLoss {
    pub fn new(prog: &Arc<Program>, buf: xla::PjRtBuffer, slot: usize) -> PendingLoss {
        PendingLoss { prog: Arc::clone(prog), buf, slot, meter: None }
    }

    /// [`PendingLoss::new`] carrying the owning run's exact meter, so the
    /// eventual download tallies per-run as well as globally.
    pub fn metered(
        prog: &Arc<Program>,
        buf: xla::PjRtBuffer,
        slot: usize,
        meter: &Arc<TransferMeter>,
    ) -> PendingLoss {
        PendingLoss { prog: Arc::clone(prog), buf, slot, meter: Some(Arc::clone(meter)) }
    }

    /// Download the scalar now (blocks until the producing computation has
    /// finished). Metered exactly like the synchronous path.
    pub fn wait(&self) -> Result<f32> {
        Ok(self.prog.download_output_metered(&self.buf, self.slot, self.meter.as_deref())?[0])
    }
}

/// One dispatched optimizer step whose per-micro-batch losses are still on
/// the device. `ticket` is the caller's monotone step id; resolution is
/// strictly FIFO, so tickets come back in dispatch order.
pub struct PendingStep {
    ticket: u64,
    losses: Vec<PendingLoss>,
}

impl PendingStep {
    pub fn new(ticket: u64, losses: Vec<PendingLoss>) -> PendingStep {
        PendingStep { ticket, losses }
    }

    pub fn ticket(&self) -> u64 {
        self.ticket
    }

    /// Number of deferred micro-batch losses this step holds.
    pub fn len(&self) -> usize {
        self.losses.len()
    }

    pub fn is_empty(&self) -> bool {
        self.losses.is_empty()
    }

    /// Download every micro loss and reduce to the step mean, using the
    /// same f64 accumulation as the synchronous path (bit-for-bit
    /// equivalence). A step with no deferred losses (placeholder in unit
    /// tests; the host-accumulation path never enters the ring) resolves
    /// to a mean of 0.0.
    fn resolve(self) -> Result<ResolvedStep> {
        let mut micro_losses = Vec::with_capacity(self.losses.len());
        let mut sum = 0.0f64;
        for p in &self.losses {
            let l = p.wait()?;
            sum += l as f64;
            micro_losses.push(l);
        }
        let mean_loss = if micro_losses.is_empty() {
            0.0
        } else {
            (sum / micro_losses.len() as f64) as f32
        };
        Ok(ResolvedStep { ticket: self.ticket, mean_loss, micro_losses })
    }
}

/// A drained step: its ticket, mean micro-batch loss, and the individual
/// micro losses (in dispatch order).
#[derive(Debug, Clone)]
pub struct ResolvedStep {
    pub ticket: u64,
    pub mean_loss: f32,
    pub micro_losses: Vec<f32>,
}

/// Counters describing how the stream has been draining (surfaced by the
/// train CLI and `bench_step`'s JSON output).
#[derive(Debug, Default, Clone)]
pub struct StreamStats {
    /// Steps pushed into the ring.
    pub steps: u64,
    /// Steps resolved (losses downloaded).
    pub resolved: u64,
    /// Drains triggered by the ring reaching its drain interval.
    pub interval_drains: u64,
    /// Forced drains (`sync`) that found pending work, by reason.
    pub forced_drains: BTreeMap<&'static str, u64>,
    /// Deepest the ring has been.
    pub max_depth: usize,
}

impl StreamStats {
    pub fn forced_total(&self) -> u64 {
        self.forced_drains.values().sum()
    }

    pub fn report(&self) -> String {
        let forced: Vec<String> = self
            .forced_drains
            .iter()
            .map(|(k, v)| format!("{k}×{v}"))
            .collect();
        format!(
            "{} steps, {} interval drains, forced [{}], max depth {}",
            self.steps,
            self.interval_drains,
            forced.join(", "),
            self.max_depth
        )
    }
}

/// The deferred-readback ring (see module docs). Owned by exactly one run
/// (one `StepEngine`), on whichever scheduler worker thread drives it —
/// "async" here means *device* work stays in flight between host syncs;
/// host-thread parallelism across runs lives in `crate::sched`.
pub struct ExecStream {
    pending: VecDeque<PendingStep>,
    drain_interval: usize,
    stats: StreamStats,
}

impl ExecStream {
    /// `drain_interval` = K: the ring drains whenever K steps are pending.
    /// K = 1 reproduces the fully synchronous behaviour; 0 is clamped to 1.
    pub fn new(drain_interval: usize) -> ExecStream {
        ExecStream {
            pending: VecDeque::new(),
            drain_interval: drain_interval.max(1),
            stats: StreamStats::default(),
        }
    }

    pub fn drain_interval(&self) -> usize {
        self.drain_interval
    }

    /// Change K mid-run (bench sync-vs-pipelined comparisons). Does not
    /// drain; an oversized ring drains on the next push or sync.
    pub fn set_drain_interval(&mut self, k: usize) {
        self.drain_interval = k.max(1);
    }

    /// Steps currently pending readback.
    pub fn depth(&self) -> usize {
        self.pending.len()
    }

    pub fn stats(&self) -> &StreamStats {
        &self.stats
    }

    /// Enqueue one dispatched step. If the ring has reached the drain
    /// interval this downloads **all** pending losses (FIFO) and returns
    /// the resolved steps; otherwise returns empty and the device keeps
    /// working ahead of the host.
    pub fn push(&mut self, step: PendingStep) -> Result<Vec<ResolvedStep>> {
        self.pending.push_back(step);
        self.stats.steps += 1;
        self.stats.max_depth = self.stats.max_depth.max(self.pending.len());
        if self.pending.len() >= self.drain_interval {
            self.stats.interval_drains += 1;
            self.drain_all()
        } else {
            Ok(Vec::new())
        }
    }

    /// Count a step whose losses resolved synchronously and never entered
    /// the ring (the host-accumulation fallback path) — it is still a
    /// dispatched step the stats must reflect, or a `keep_micro_grads` /
    /// pre-`grad_accum` run would report an empty pipeline.
    pub fn record_passthrough(&mut self) {
        self.stats.steps += 1;
        self.stats.resolved += 1;
    }

    /// Force a full drain at a pipeline boundary. No-op (and not counted)
    /// when nothing is pending.
    pub fn sync(&mut self, reason: SyncReason) -> Result<Vec<ResolvedStep>> {
        if self.pending.is_empty() {
            return Ok(Vec::new());
        }
        *self.stats.forced_drains.entry(reason.as_str()).or_insert(0) += 1;
        self.drain_all()
    }

    fn drain_all(&mut self) -> Result<Vec<ResolvedStep>> {
        let mut out = Vec::with_capacity(self.pending.len());
        while let Some(step) = self.pending.pop_front() {
            out.push(step.resolve()?);
            self.stats.resolved += 1;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    //! Ring bookkeeping only — draining real deferred losses against AOT
    //! programs is covered by `rust/tests/runtime_roundtrip.rs`
    //! (`deferred_loss_readback_equals_sync_download`), which needs
    //! artifacts. Placeholder steps with no losses exercise the ring
    //! mechanics without a device.
    use super::*;

    fn step(ticket: u64) -> PendingStep {
        PendingStep::new(ticket, Vec::new())
    }

    #[test]
    fn interval_drains_whole_ring_in_fifo_order() {
        let mut s = ExecStream::new(3);
        assert!(s.push(step(0)).unwrap().is_empty());
        assert!(s.push(step(1)).unwrap().is_empty());
        assert_eq!(s.depth(), 2);
        let r = s.push(step(2)).unwrap();
        assert_eq!(r.iter().map(|x| x.ticket).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(s.depth(), 0);
        assert_eq!(s.stats().interval_drains, 1);
        assert_eq!(s.stats().max_depth, 3);
        assert_eq!(s.stats().resolved, 3);
    }

    #[test]
    fn drain_interval_one_is_fully_synchronous() {
        let mut s = ExecStream::new(1);
        for t in 0..4u64 {
            let r = s.push(step(t)).unwrap();
            assert_eq!(r.len(), 1);
            assert_eq!(r[0].ticket, t);
            assert_eq!(s.depth(), 0);
        }
        assert_eq!(s.stats().interval_drains, 4);
        assert_eq!(s.stats().forced_total(), 0);
    }

    #[test]
    fn zero_interval_clamps_to_one() {
        let mut s = ExecStream::new(0);
        assert_eq!(s.drain_interval(), 1);
        s.set_drain_interval(0);
        assert_eq!(s.drain_interval(), 1);
    }

    #[test]
    fn forced_sync_counts_by_reason_and_skips_empty() {
        let mut s = ExecStream::new(16);
        // empty sync is free and unrecorded
        assert!(s.sync(SyncReason::Eval).unwrap().is_empty());
        assert_eq!(s.stats().forced_total(), 0);
        s.push(step(0)).unwrap();
        s.push(step(1)).unwrap();
        let r = s.sync(SyncReason::FfBoundary).unwrap();
        assert_eq!(r.len(), 2);
        s.push(step(2)).unwrap();
        s.sync(SyncReason::FfBoundary).unwrap();
        s.push(step(3)).unwrap();
        s.sync(SyncReason::Shutdown).unwrap();
        assert_eq!(s.stats().forced_drains.get("ff_boundary"), Some(&2));
        assert_eq!(s.stats().forced_drains.get("shutdown"), Some(&1));
        assert_eq!(s.stats().forced_total(), 3);
        let rep = s.stats().report();
        assert!(rep.contains("ff_boundary×2"), "{rep}");
    }

    #[test]
    fn passthrough_steps_are_counted_without_touching_the_ring() {
        let mut s = ExecStream::new(4);
        s.record_passthrough();
        s.record_passthrough();
        assert_eq!(s.depth(), 0);
        assert_eq!(s.stats().steps, 2);
        assert_eq!(s.stats().resolved, 2);
        assert_eq!(s.stats().interval_drains, 0);
        assert_eq!(s.stats().forced_total(), 0);
    }

    #[test]
    fn shrinking_interval_drains_on_next_push() {
        let mut s = ExecStream::new(8);
        s.push(step(0)).unwrap();
        s.push(step(1)).unwrap();
        s.set_drain_interval(2);
        // already at the new bound: the next push drains everything
        let r = s.push(step(2)).unwrap();
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn empty_step_resolves_to_zero_mean() {
        let r = step(7).resolve().unwrap();
        assert_eq!(r.ticket, 7);
        assert_eq!(r.mean_loss, 0.0);
        assert!(r.micro_losses.is_empty());
    }
}
