//! Artifact manifest: the contract between `python/compile/aot.py` and this
//! runtime. Each artifact directory carries `manifest.json` describing the
//! exact flattened input/output ordering of every program; we parse it and
//! cross-check it against the spec derived in `model::spec` so any drift
//! between the python and rust parameter derivations aborts at load time.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{AdamConfig, ArtifactConfig, ModelConfig, TrainMode};
use crate::model::spec;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn from_str(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            other => bail!("unknown dtype '{other}'"),
        })
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct IoSlot {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSlot {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Device-buffer size of this slot (both supported dtypes are 4-byte).
    pub fn byte_len(&self) -> usize {
        self.numel() * 4
    }
}

/// Contraction order of the LoRA adapter chain `x·A·B` in one pass of a
/// program, as chosen by `python/compile/contraction.py` at emit time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LoraOrder {
    /// `(x·A)·B` — the legacy order; also what pre-order manifests imply.
    #[default]
    Factored,
    /// `x·(A·B)` forward / the `G = xᵀ·g` route backward.
    Merged,
}

impl LoraOrder {
    fn from_str(s: &str) -> Result<LoraOrder> {
        Ok(match s {
            "factored" => LoraOrder::Factored,
            "merged" => LoraOrder::Merged,
            other => bail!("unknown lora order '{other}'"),
        })
    }
}

/// Recorded contraction orders for a program's LoRA matmuls. `backward`
/// stays `Factored` (the default) for forward-only programs (`eval_loss`),
/// whose manifests record no backward order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct LoraOrders {
    pub forward: LoraOrder,
    pub backward: LoraOrder,
}

#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub file: String,
    pub inputs: Vec<IoSlot>,
    pub outputs: Vec<IoSlot>,
    /// Input-slot indices the executable donates (its HLO
    /// `input_output_alias` map reuses these allocations for outputs).
    /// Non-empty ⇒ the program must be run through
    /// `Program::execute_raw_donated` with exactly these slots passed by
    /// value; empty for manifests that predate donation.
    pub donated_inputs: Vec<usize>,
    /// Contraction orders the emitted HLO uses for its LoRA matmuls
    /// (`flops::FlopsModel::for_manifest` charges exactly these). `None`
    /// for programs without LoRA matmuls, non-LoRA artifacts, and
    /// manifests that predate order selection (legacy factored).
    pub lora_orders: Option<LoraOrders>,
    /// `Some(R)` for `*_batched{R}` variants: the leading run axis stacks
    /// R independent runs' state over one shared frozen base.
    pub batch_runs: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub key: String,
    pub dir: PathBuf,
    pub config: ArtifactConfig,
    pub adam: AdamConfig,
    /// (name, shape) of every trainable / frozen param, in program order.
    pub trainable: Vec<(String, Vec<usize>)>,
    pub frozen: Vec<(String, Vec<usize>)>,
    pub programs: BTreeMap<String, ProgramSpec>,
    /// Canonical content hash stamped by the python emitter (manifest +
    /// HLO bytes; see `crate::store` for the recipe). `None` for artifacts
    /// emitted before content addressing existed — the store hashes those
    /// from directory contents instead.
    pub content_hash: Option<String>,
}

fn parse_slots(v: &Json) -> Result<Vec<IoSlot>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of io slots"))?
        .iter()
        .map(|s| {
            Ok(IoSlot {
                name: s.get("name").as_str().ok_or_else(|| anyhow!("slot missing name"))?.into(),
                shape: s
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow!("slot missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad shape dim")))
                    .collect::<Result<_>>()?,
                dtype: Dtype::from_str(s.get("dtype").as_str().unwrap_or("f32"))?,
            })
        })
        .collect()
}

fn parse_program(p: &Json) -> Result<ProgramSpec> {
    let lora_orders = match p.get("lora_orders") {
        j if j.is_null() => None,
        j => {
            let forward = LoraOrder::from_str(
                j.get("forward").as_str().ok_or_else(|| anyhow!("lora_orders missing forward"))?,
            )?;
            // Absent for forward-only programs → legacy default (Factored).
            let backward = match j.get("backward").as_str() {
                Some(s) => LoraOrder::from_str(s)?,
                None => LoraOrder::default(),
            };
            Some(LoraOrders { forward, backward })
        }
    };
    Ok(ProgramSpec {
        file: p.get("file").as_str().ok_or_else(|| anyhow!("program missing file"))?.into(),
        inputs: parse_slots(p.get("inputs"))?,
        outputs: parse_slots(p.get("outputs"))?,
        donated_inputs: p
            .get("donated_inputs")
            .as_arr()
            .map(|a| {
                a.iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad donated slot")))
                    .collect::<Result<Vec<usize>>>()
            })
            .transpose()?
            .unwrap_or_default(),
        lora_orders,
        batch_runs: p.get("batch_runs").as_usize(),
    })
}

fn parse_named_shapes(v: &Json) -> Result<Vec<(String, Vec<usize>)>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of params"))?
        .iter()
        .map(|p| {
            Ok((
                p.get("name").as_str().ok_or_else(|| anyhow!("param missing name"))?.to_string(),
                p.get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow!("param missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<usize>>>()?,
            ))
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;

        let cfg = j.get("config");
        let model = ModelConfig::from_manifest(cfg)?;
        let config = ArtifactConfig {
            model,
            train_mode: TrainMode::from_str(
                cfg.get("train_mode").as_str().ok_or_else(|| anyhow!("missing train_mode"))?,
            )?,
            lora_rank: cfg.get("lora_rank").as_usize().unwrap_or(8),
            lora_alpha: cfg.get("lora_alpha").as_f64().unwrap_or(16.0) as f32,
            use_pallas: cfg.get("use_pallas").as_bool().unwrap_or(false),
        };
        let adam = AdamConfig {
            beta1: j.get("adam").get("beta1").as_f64().unwrap_or(0.9) as f32,
            beta2: j.get("adam").get("beta2").as_f64().unwrap_or(0.999) as f32,
            eps: j.get("adam").get("eps").as_f64().unwrap_or(1e-8) as f32,
        };

        let mut programs = BTreeMap::new();
        let progs = j.get("programs").as_obj().ok_or_else(|| anyhow!("missing programs"))?;
        for (name, p) in progs {
            let spec = parse_program(p).with_context(|| format!("program '{name}'"))?;
            programs.insert(name.clone(), spec);
        }

        let man = Manifest {
            key: j.get("key").as_str().unwrap_or_default().to_string(),
            dir: dir.to_path_buf(),
            config,
            adam,
            trainable: parse_named_shapes(j.get("trainable"))?,
            frozen: parse_named_shapes(j.get("frozen"))?,
            programs,
            content_hash: j.get("content_hash").as_str().map(str::to_string),
        };
        man.cross_check()?;
        Ok(man)
    }

    /// Verify the manifest agrees with the rust-side spec derivation.
    fn cross_check(&self) -> Result<()> {
        if self.key != self.config.key() {
            bail!("manifest key '{}' != derived key '{}'", self.key, self.config.key());
        }
        let want_t: Vec<(String, Vec<usize>)> = spec::trainable_spec(&self.config)
            .into_iter()
            .map(|p| (p.name, p.shape))
            .collect();
        let want_f: Vec<(String, Vec<usize>)> = spec::frozen_spec(&self.config)
            .into_iter()
            .map(|p| (p.name, p.shape))
            .collect();
        if self.trainable != want_t {
            bail!(
                "trainable spec drift for '{}': manifest has {} params, rust derives {}",
                self.key,
                self.trainable.len(),
                want_t.len()
            );
        }
        if self.frozen != want_f {
            bail!("frozen spec drift for '{}'", self.key);
        }
        // The original four programs are mandatory; `grad_accum` and
        // `grad_finalize` (device-side accumulation, donated) are optional
        // so artifacts emitted before they existed keep loading — the
        // trainer falls back to host-side accumulation when they're absent.
        for name in ["train_step", "grad_step", "adam_apply", "eval_loss"] {
            let p = self
                .programs
                .get(name)
                .ok_or_else(|| anyhow!("manifest missing program '{name}'"))?;
            if p.inputs.is_empty() || p.outputs.is_empty() {
                bail!("program '{name}' has empty io spec");
            }
        }
        Ok(())
    }

    /// Whether this artifact carries an (optional) program, e.g. the
    /// device-side accumulation pair `grad_accum`/`grad_finalize`.
    pub fn has_program(&self, name: &str) -> bool {
        self.programs.contains_key(name)
    }

    pub fn program(&self, name: &str) -> Result<&ProgramSpec> {
        self.programs.get(name).ok_or_else(|| anyhow!("no program '{name}' in '{}'", self.key))
    }

    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.program(name)?.file))
    }

    /// Group sizes R for which this artifact carries the full chained
    /// batched program set (`grad_step_batched{R}`, `adam_apply_batched{R}`,
    /// `eval_loss_batched{R}`), ascending. Empty for artifacts emitted
    /// before batched variants existed and for non-LoRA/Pallas artifacts —
    /// the queue then simply never packs runs on them.
    pub fn batched_group_sizes(&self) -> Vec<usize> {
        let mut sizes: Vec<usize> = self
            .programs
            .iter()
            .filter_map(|(name, p)| {
                let r = p.batch_runs?;
                if name == &format!("grad_step_batched{r}")
                    && self.has_program(&format!("adam_apply_batched{r}"))
                    && self.has_program(&format!("eval_loss_batched{r}"))
                {
                    Some(r)
                } else {
                    None
                }
            })
            .collect();
        sizes.sort_unstable();
        sizes
    }
}

/// Artifact index (artifacts/index.json): what exists, without globbing.
#[derive(Debug, Clone)]
pub struct ArtifactIndex {
    pub root: PathBuf,
    pub entries: Vec<IndexEntry>,
}

#[derive(Debug, Clone)]
pub struct IndexEntry {
    pub key: String,
    pub model: String,
    pub train_mode: String,
    pub lora_rank: usize,
    pub n_params: usize,
    pub n_trainable: usize,
}

impl ArtifactIndex {
    pub fn load(root: &Path) -> Result<ArtifactIndex> {
        let path = root.join("index.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {} — run `make artifacts` first", path.display())
        })?;
        let j = Json::parse(&text)?;
        let entries = j
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("index.json missing 'artifacts'"))?
            .iter()
            .map(|e| {
                Ok(IndexEntry {
                    key: e.get("key").as_str().unwrap_or_default().into(),
                    model: e.get("model").as_str().unwrap_or_default().into(),
                    train_mode: e.get("train_mode").as_str().unwrap_or_default().into(),
                    lora_rank: e.get("lora_rank").as_usize().unwrap_or(0),
                    n_params: e.get("n_params").as_usize().unwrap_or(0),
                    n_trainable: e.get("n_trainable").as_usize().unwrap_or(0),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactIndex { root: root.to_path_buf(), entries })
    }

    pub fn manifest(&self, key: &str) -> Result<Manifest> {
        if !self.entries.iter().any(|e| e.key == key) {
            bail!(
                "artifact '{key}' not in index (have: {})",
                self.entries.iter().map(|e| e.key.as_str()).collect::<Vec<_>>().join(", ")
            );
        }
        Manifest::load(&self.root.join(key))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tests against real artifacts live in rust/tests/ (they need
    /// `make artifacts`); here we exercise the parsing layer only.
    #[test]
    fn parse_slots_happy_and_sad() {
        let ok = Json::parse(r#"[{"name":"x","shape":[2,3],"dtype":"i32"}]"#).unwrap();
        let slots = parse_slots(&ok).unwrap();
        assert_eq!(slots[0].numel(), 6);
        assert_eq!(slots[0].dtype, Dtype::I32);
        let bad = Json::parse(r#"[{"shape":[2]}]"#).unwrap();
        assert!(parse_slots(&bad).is_err());
        let bad_dtype = Json::parse(r#"[{"name":"x","shape":[],"dtype":"f64"}]"#).unwrap();
        assert!(parse_slots(&bad_dtype).is_err());
    }

    #[test]
    fn missing_file_is_contextual_error() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(format!("{err:#}").contains("manifest.json"));
    }

    #[test]
    fn program_round_trips_orders_and_batch_runs() {
        let j = Json::parse(
            r#"{"file":"grad_step_batched2.hlo.txt",
                "inputs":[{"name":"t:x","shape":[2,4,3],"dtype":"f32"}],
                "outputs":[{"name":"loss","shape":[2],"dtype":"f32"}],
                "donated_inputs":[],
                "lora_orders":{"forward":"merged","backward":"factored"},
                "batch_runs":2}"#,
        )
        .unwrap();
        let p = parse_program(&j).unwrap();
        assert_eq!(
            p.lora_orders,
            Some(LoraOrders { forward: LoraOrder::Merged, backward: LoraOrder::Factored })
        );
        assert_eq!(p.batch_runs, Some(2));
    }

    #[test]
    fn legacy_program_defaults_to_factored_solo() {
        // Manifests emitted before order selection / batching carry neither
        // key; they must load with `None` orders (callers treat that as
        // Factored/Factored) and no batch axis.
        let j = Json::parse(
            r#"{"file":"grad_step.hlo.txt",
                "inputs":[{"name":"t:x","shape":[4,3],"dtype":"f32"}],
                "outputs":[{"name":"loss","shape":[],"dtype":"f32"}]}"#,
        )
        .unwrap();
        let p = parse_program(&j).unwrap();
        assert_eq!(p.lora_orders, None);
        assert_eq!(p.batch_runs, None);
        assert!(p.donated_inputs.is_empty());
        assert_eq!(LoraOrders::default().forward, LoraOrder::Factored);
        assert_eq!(LoraOrders::default().backward, LoraOrder::Factored);
    }

    #[test]
    fn forward_only_orders_default_backward_factored() {
        let j = Json::parse(
            r#"{"file":"eval_loss.hlo.txt",
                "inputs":[{"name":"t:x","shape":[4,3],"dtype":"f32"}],
                "outputs":[{"name":"loss","shape":[],"dtype":"f32"}],
                "lora_orders":{"forward":"merged"}}"#,
        )
        .unwrap();
        let p = parse_program(&j).unwrap();
        let o = p.lora_orders.unwrap();
        assert_eq!(o.forward, LoraOrder::Merged);
        assert_eq!(o.backward, LoraOrder::Factored);
        let bad = Json::parse(
            r#"{"file":"x","inputs":[],"outputs":[],
                "lora_orders":{"forward":"sideways"}}"#,
        )
        .unwrap();
        assert!(parse_program(&bad).is_err());
    }
}
