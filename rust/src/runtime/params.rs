//! `ParamSet`: an ordered collection of named parameter tensors whose
//! authoritative copy moves between host and device under a per-tensor
//! **sync-state machine**.
//!
//! The coordinator needs parameters host-side for FF's `W_t + τΔ_W`
//! arithmetic, checkpointing, and analysis probes, and device-side for
//! every program call. Rather than round-tripping the full state through
//! host memory on every optimizer step, each tensor carries one of four
//! states:
//!
//! | state         | authoritative copy | how it is entered                         |
//! |---------------|--------------------|-------------------------------------------|
//! | `HostAhead`   | host               | construction, `set_flat`, `axpy`, `restore` |
//! | `DeviceAhead` | device             | `adopt_device` (a program output retained as a buffer) |
//! | `InSync`      | both (identical)   | upload (`device_buffers`) or download (`sync_host`) |
//! | `Donated`     | *none* (transient) | `take_device_buffers` (buffer donated into a program) |
//!
//! Transitions:
//!
//! * [`ParamSet::device_buffers`] uploads only `HostAhead` (or never-
//!   uploaded) tensors → `InSync`; `DeviceAhead`/`InSync` buffers are
//!   reused as-is. The frozen base weights therefore upload exactly once,
//!   and device-resident optimizer state is **never** re-uploaded.
//! * [`ParamSet::adopt_device`] installs a program output buffer as the new
//!   authoritative value → `DeviceAhead`, with **no** host copy. This is
//!   how `adam_apply` outputs stay on the device between steps.
//! * [`ParamSet::take_device_buffers`] removes the device buffers so the
//!   caller can donate them into a program call
//!   ([`Program::execute_raw_donated`](crate::runtime::Program::execute_raw_donated))
//!   → `Donated`. The state is transient and one-way: the set has **no**
//!   authoritative copy until the program's outputs are adopted back
//!   (`adopt_all`/`adopt_device` → `DeviceAhead`) or the tensor is wholly
//!   overwritten from the host (`set_flat`/`restore` → `HostAhead`). Every
//!   read — host *or* device — panics in between, so a donation that is
//!   not immediately repaid by adoption is a loud bug.
//! * [`ParamSet::sync_host`] lazily downloads every `DeviceAhead` tensor →
//!   `InSync`. Host reads (`tensors`, `snapshot`, …) assert that no tensor
//!   is `DeviceAhead`/`Donated`, so a missing `sync_host()` is a loud bug,
//!   not a silent stale read. Host read-modify-writes (`axpy`) carry the
//!   same assertion; whole-tensor overwrites (`set_flat`, `restore`) are
//!   safe from any state.
//!
//! Uploads and downloads are counted per set (`upload_count` /
//! `download_count`) and metered in bytes on the shared
//! [`Runtime::stats`](crate::runtime::TransferStats); a set owned by a
//! scheduled run additionally carries that run's
//! [`TransferMeter`](crate::runtime::TransferMeter)
//! ([`ParamSet::attach_meter`]) so per-run transfer totals stay exact
//! under concurrency — see the runtime module docs, §Perf counters, and
//! `docs/transfer-contract.md` for the full movement rules.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::model::tensor::Tensor;
use crate::runtime::{Runtime, TransferMeter};

/// Which copy of a tensor is authoritative (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncState {
    /// Host and device hold the same value.
    InSync,
    /// Host was written; the device buffer (if any) is stale.
    HostAhead,
    /// A program output buffer is authoritative; the host tensor is stale.
    DeviceAhead,
    /// The device buffer was donated into a program call and no
    /// authoritative copy exists; only `adopt_device`/`adopt_all` (program
    /// outputs) or a whole-tensor host overwrite may follow. Transient
    /// within one optimizer step.
    Donated,
}

/// A `ParamSet` is **per-run state**: it is created, used, and dropped on
/// whichever scheduler worker thread owns the run, never shared between
/// runs (see `docs/transfer-contract.md` §5). Only the `Arc<Runtime>`
/// handle inside it is shared.
pub struct ParamSet {
    rt: Arc<Runtime>,
    /// The owning run's exact per-run meter, if any: every upload this
    /// set performs (`device_buffers`) and every download (`sync_host`)
    /// is tallied here in addition to the global `Runtime::stats`.
    meter: Option<Arc<TransferMeter>>,
    names: Vec<String>,
    index: BTreeMap<String, usize>,
    host: Vec<Tensor>,
    device: Vec<Option<xla::PjRtBuffer>>,
    state: Vec<SyncState>,
    uploads: u64,
    downloads: u64,
}

impl ParamSet {
    /// Build from (name, shape) spec order, pulling tensors from `values`.
    pub fn from_spec(
        rt: &Arc<Runtime>,
        spec: &[(String, Vec<usize>)],
        values: &BTreeMap<String, Tensor>,
    ) -> Result<ParamSet> {
        let mut names = Vec::new();
        let mut host = Vec::new();
        for (name, shape) in spec {
            let t = values
                .get(name)
                .ok_or_else(|| anyhow!("missing init value for param '{name}'"))?;
            if &t.shape != shape {
                bail!("param '{name}': init shape {:?} != spec {:?}", t.shape, shape);
            }
            names.push(name.clone());
            host.push(t.clone());
        }
        Ok(Self::from_tensors(rt, names, host))
    }

    /// Build an all-zeros set with the same names/shapes as `like`
    /// (Adam m/v state, gradient accumulators).
    pub fn zeros_like(rt: &Arc<Runtime>, like: &ParamSet) -> ParamSet {
        let host = like.host.iter().map(|t| Tensor::zeros(&t.shape)).collect();
        Self::from_tensors(rt, like.names.clone(), host)
    }

    fn from_tensors(rt: &Arc<Runtime>, names: Vec<String>, host: Vec<Tensor>) -> ParamSet {
        let n = names.len();
        let index = names.iter().cloned().enumerate().map(|(i, n)| (n, i)).collect();
        ParamSet {
            rt: Arc::clone(rt),
            meter: None,
            names,
            index,
            host,
            device: (0..n).map(|_| None).collect(),
            state: vec![SyncState::HostAhead; n],
            uploads: 0,
            downloads: 0,
        }
    }

    /// Attach the owning run's exact transfer meter (see struct field
    /// docs). Call before any upload/download so the run's accounting
    /// starts complete; sets without a meter tally globally only.
    pub fn attach_meter(&mut self, meter: &Arc<TransferMeter>) {
        self.meter = Some(Arc::clone(meter));
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn numel(&self) -> usize {
        self.host.iter().map(|t| t.len()).sum()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Shape of tensor `i`. Valid in any sync state — shapes are fixed at
    /// construction, so no host sync is required (unlike value reads).
    pub fn shape(&self, i: usize) -> &[usize] {
        &self.host[i].shape
    }

    /// All tensor shapes in spec order. Like [`ParamSet::shape`], valid in
    /// any sync state: callers that only need the *geometry* of the set
    /// (Δ_W-sized probe directions, log lines, size accounting) must not
    /// pay a device→host sync for it.
    pub fn shapes(&self) -> Vec<Vec<usize>> {
        self.host.iter().map(|t| t.shape.clone()).collect()
    }

    /// True when no tensor is `DeviceAhead` or `Donated` — host reads are
    /// valid.
    pub fn host_in_sync(&self) -> bool {
        !self
            .state
            .iter()
            .any(|s| matches!(s, SyncState::DeviceAhead | SyncState::Donated))
    }

    fn assert_host_fresh(&self, op: &str) {
        assert!(
            !self.state.contains(&SyncState::Donated),
            "{op} on a donated ParamSet — adopt the program outputs first"
        );
        assert!(
            self.host_in_sync(),
            "{op} on a device-ahead ParamSet — call sync_host() first"
        );
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        self.assert_host_fresh("tensor()");
        let i = *self.index.get(name).ok_or_else(|| anyhow!("no param '{name}'"))?;
        Ok(&self.host[i])
    }

    pub fn tensors(&self) -> &[Tensor] {
        self.assert_host_fresh("tensors()");
        &self.host
    }

    /// Snapshot all host tensors (W_{t-1} for Δ_W).
    pub fn snapshot(&self) -> Vec<Tensor> {
        self.assert_host_fresh("snapshot()");
        self.host.clone()
    }

    /// Overwrite every tensor from a snapshot; host becomes authoritative.
    pub fn restore(&mut self, snap: &[Tensor]) {
        assert_eq!(snap.len(), self.host.len());
        for (i, t) in snap.iter().enumerate() {
            self.host[i] = t.clone();
            self.state[i] = SyncState::HostAhead;
            self.device[i] = None;
        }
    }

    /// Overwrite tensor `i` from a flat f32 slice; host becomes
    /// authoritative (safe from any state — the whole tensor is replaced).
    pub fn set_flat(&mut self, i: usize, data: &[f32]) {
        debug_assert_eq!(self.host[i].len(), data.len());
        self.host[i].data.copy_from_slice(data);
        self.state[i] = SyncState::HostAhead;
        self.device[i] = None;
    }

    /// In-place axpy on every tensor: `self += alpha * delta` — the FF
    /// simulated step `W_t + τΔ_W` applies this with alpha=1 per τ.
    /// Read-modify-write: requires the host view to be fresh.
    pub fn axpy(&mut self, alpha: f32, delta: &[Tensor]) {
        self.assert_host_fresh("axpy()");
        assert_eq!(delta.len(), self.host.len());
        for (i, d) in delta.iter().enumerate() {
            self.host[i].axpy(alpha, d);
            self.state[i] = SyncState::HostAhead;
            self.device[i] = None;
        }
    }

    /// Ensure device buffers exist for all tensors; uploads only host-ahead
    /// (or never-uploaded) ones. `DeviceAhead` buffers are reused as-is —
    /// steady-state optimizer steps perform zero uploads here.
    pub fn device_buffers(&mut self) -> Result<Vec<&xla::PjRtBuffer>> {
        for i in 0..self.host.len() {
            assert_ne!(
                self.state[i],
                SyncState::Donated,
                "device_buffers() on donated param '{}' — adopt the program \
                 outputs first",
                self.names[i]
            );
            let stale = self.state[i] == SyncState::HostAhead || self.device[i].is_none();
            if stale {
                // Hard assert: a device-ahead tensor with no buffer means
                // the only up-to-date copy of the weights is gone; the
                // re-upload below would silently train on stale host data.
                assert_ne!(
                    self.state[i],
                    SyncState::DeviceAhead,
                    "device-ahead tensor lost its buffer"
                );
                let buf = match &self.meter {
                    Some(m) => m.upload_tensor(&self.rt, &self.host[i])?,
                    None => self.rt.upload_tensor(&self.host[i])?,
                };
                self.device[i] = Some(buf);
                self.state[i] = SyncState::InSync;
                self.uploads += 1;
            }
        }
        Ok(self.device.iter().map(|b| b.as_ref().unwrap()).collect())
    }

    /// Remove every device buffer for donation into a program call
    /// ([`Program::execute_raw_donated`](crate::runtime::Program::execute_raw_donated)),
    /// uploading any host-ahead tensors first so a buffer exists to donate
    /// (first step) and reusing resident buffers otherwise (steady state —
    /// zero uploads). Every tensor transitions to [`SyncState::Donated`]:
    /// the set holds **no** authoritative value until the program's outputs
    /// are adopted back with [`ParamSet::adopt_all`]; any read in between
    /// panics.
    pub fn take_device_buffers(&mut self) -> Result<Vec<xla::PjRtBuffer>> {
        self.device_buffers()?; // materialize + meter uploads for host-ahead
        let mut out = Vec::with_capacity(self.device.len());
        for i in 0..self.device.len() {
            out.push(self.device[i].take().expect("buffer materialized above"));
            self.state[i] = SyncState::Donated;
        }
        Ok(out)
    }

    /// Install a program output buffer as tensor `i`'s authoritative value
    /// (`DeviceAhead`). No host copy is made; the host view goes stale
    /// until [`ParamSet::sync_host`].
    pub fn adopt_device(&mut self, i: usize, buf: xla::PjRtBuffer) {
        assert!(i < self.host.len(), "adopt_device: no param #{i}");
        self.device[i] = Some(buf);
        self.state[i] = SyncState::DeviceAhead;
    }

    /// Adopt the next `len()` buffers of a raw program-output stream as
    /// this set's device state, in spec order — the single place that
    /// encodes the `[.., tr.., m.., v..]` output-layout walk: callers
    /// chain `tr.adopt_all(&mut outs)?; m.adopt_all(&mut outs)?; …`.
    pub fn adopt_all(
        &mut self,
        outs: &mut impl Iterator<Item = xla::PjRtBuffer>,
    ) -> Result<()> {
        for i in 0..self.host.len() {
            let buf = outs.next().ok_or_else(|| {
                anyhow!("adopt_all: raw output stream exhausted at param '{}'", self.names[i])
            })?;
            self.adopt_device(i, buf);
        }
        Ok(())
    }

    /// Download every `DeviceAhead` tensor into its host view (→ `InSync`).
    /// No-op for sets that are already host-fresh; each device-side step is
    /// paid for by at most one download per tensor on first host access.
    pub fn sync_host(&mut self) -> Result<()> {
        for i in 0..self.host.len() {
            if self.state[i] == SyncState::Donated {
                bail!(
                    "sync_host: param '{}' was donated and has no \
                     authoritative copy — adopt the program outputs first",
                    self.names[i]
                );
            }
            if self.state[i] != SyncState::DeviceAhead {
                continue;
            }
            let buf = self.device[i]
                .as_ref()
                .expect("device-ahead tensor without a buffer");
            let v = match &self.meter {
                Some(m) => m.download_f32(&self.rt, buf)?,
                None => self.rt.download_f32(buf)?,
            };
            if v.len() != self.host[i].len() {
                bail!(
                    "param '{}': device buffer has {} elems, host expects {}",
                    self.names[i],
                    v.len(),
                    self.host[i].len()
                );
            }
            self.host[i].data.copy_from_slice(&v);
            self.state[i] = SyncState::InSync;
            self.downloads += 1;
        }
        Ok(())
    }

    /// Total device uploads performed (perf counter; see runtime §Perf).
    pub fn upload_count(&self) -> u64 {
        self.uploads
    }

    /// Total device→host downloads performed by `sync_host`.
    pub fn download_count(&self) -> u64 {
        self.downloads
    }

    /// L2 norm over the whole set (‖W_FF − W_0‖ probes, Fig 5 axes).
    pub fn norm(&self) -> f64 {
        self.assert_host_fresh("norm()");
        crate::model::tensor::list_norm(&self.host)
    }
}

#[cfg(test)]
mod tests {
    //! Device-dependent behaviour is covered by rust/tests/runtime_roundtrip
    //! (requires artifacts); here we test the sync-state bookkeeping via a
    //! real CPU client, which is cheap to create.
    use super::*;
    use std::collections::BTreeMap;

    fn mk() -> (Arc<Runtime>, ParamSet) {
        let rt = Runtime::cpu().unwrap();
        let spec = vec![
            ("a".to_string(), vec![2, 2]),
            ("b".to_string(), vec![3]),
        ];
        let mut vals = BTreeMap::new();
        vals.insert("a".into(), Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]));
        vals.insert("b".into(), Tensor::from_vec(&[3], vec![5., 6., 7.]));
        let ps = ParamSet::from_spec(&rt, &spec, &vals).unwrap();
        (rt, ps)
    }

    #[test]
    fn spec_order_and_lookup() {
        let (_rt, ps) = mk();
        assert_eq!(ps.names(), &["a".to_string(), "b".to_string()]);
        assert_eq!(ps.numel(), 7);
        assert_eq!(ps.tensor("b").unwrap().data, vec![5., 6., 7.]);
        assert!(ps.tensor("c").is_err());
    }

    #[test]
    fn missing_or_misshapen_init_fails() {
        let rt = Runtime::cpu().unwrap();
        let spec = vec![("a".to_string(), vec![2])];
        assert!(ParamSet::from_spec(&rt, &spec, &BTreeMap::new()).is_err());
        let mut wrong = BTreeMap::new();
        wrong.insert("a".into(), Tensor::zeros(&[3]));
        assert!(ParamSet::from_spec(&rt, &spec, &wrong).is_err());
    }

    #[test]
    fn dirty_tracking_uploads_once() {
        let (_rt, mut ps) = mk();
        ps.device_buffers().unwrap();
        assert_eq!(ps.upload_count(), 2);
        ps.device_buffers().unwrap(); // clean: no re-upload
        assert_eq!(ps.upload_count(), 2);
        ps.set_flat(0, &[9., 9., 9., 9.]);
        ps.device_buffers().unwrap(); // only tensor 0 re-uploads
        assert_eq!(ps.upload_count(), 3);
    }

    #[test]
    fn attached_meter_sees_every_upload_and_download() {
        let (rt, mut ps) = mk();
        let meter = TransferMeter::new();
        ps.attach_meter(&meter);
        ps.device_buffers().unwrap(); // uploads a (4 elems) + b (3 elems)
        let snap = meter.snapshot();
        assert_eq!(snap.uploads, 2);
        assert_eq!(snap.uploaded_bytes, (4 + 3) * 4);
        // adopt a device value, then sync: one metered download
        let buf = rt.upload_f32(&[9., 8., 7., 6.], &[2, 2]).unwrap();
        ps.adopt_device(0, buf);
        ps.sync_host().unwrap();
        let snap = meter.snapshot();
        assert_eq!(snap.downloads, 1);
        assert_eq!(snap.downloaded_bytes, 4 * 4);
        // no re-upload, nothing further metered
        ps.device_buffers().unwrap();
        assert_eq!(meter.snapshot().uploads, 2);
    }

    #[test]
    fn axpy_and_snapshot_restore() {
        let (_rt, mut ps) = mk();
        let snap = ps.snapshot();
        let delta = vec![Tensor::ones(&[2, 2]), Tensor::ones(&[3])];
        ps.axpy(2.0, &delta);
        assert_eq!(ps.tensor("a").unwrap().data, vec![3., 4., 5., 6.]);
        ps.restore(&snap);
        assert_eq!(ps.tensor("a").unwrap().data, vec![1., 2., 3., 4.]);
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let (rt, ps) = mk();
        let z = ParamSet::zeros_like(&rt, &ps);
        assert_eq!(z.numel(), ps.numel());
        assert!(z.tensor("a").unwrap().data.iter().all(|v| *v == 0.0));
    }

    // -- sync-state machine ---------------------------------------------------

    #[test]
    fn device_ahead_host_read_downloads_exactly_once() {
        let (rt, mut ps) = mk();
        ps.device_buffers().unwrap(); // both InSync
        let buf = rt.upload_f32(&[9., 8., 7., 6.], &[2, 2]).unwrap();
        ps.adopt_device(0, buf);
        assert!(!ps.host_in_sync());
        assert_eq!(ps.download_count(), 0);
        ps.sync_host().unwrap(); // first host access: one download
        assert_eq!(ps.download_count(), 1);
        assert!(ps.host_in_sync());
        assert_eq!(ps.tensor("a").unwrap().data, vec![9., 8., 7., 6.]);
        ps.sync_host().unwrap(); // already in sync: no second download
        assert_eq!(ps.download_count(), 1);
    }

    #[test]
    fn adopted_buffer_is_reused_without_reupload() {
        let (rt, mut ps) = mk();
        ps.device_buffers().unwrap();
        let before = ps.upload_count();
        let buf = rt.upload_f32(&[0.5; 4], &[2, 2]).unwrap();
        ps.adopt_device(0, buf);
        // device read straight after adoption: the adopted buffer serves it
        ps.device_buffers().unwrap();
        assert_eq!(ps.upload_count(), before);
        // and host sync afterwards still leaves the buffer reusable
        ps.sync_host().unwrap();
        ps.device_buffers().unwrap();
        assert_eq!(ps.upload_count(), before);
    }

    #[test]
    fn host_axpy_then_device_read_uploads_exactly_once_per_tensor() {
        let (_rt, mut ps) = mk();
        ps.device_buffers().unwrap();
        let before = ps.upload_count();
        let delta = vec![Tensor::ones(&[2, 2]), Tensor::ones(&[3])];
        ps.axpy(1.0, &delta); // host write: both tensors go HostAhead
        ps.device_buffers().unwrap();
        assert_eq!(ps.upload_count(), before + 2);
        ps.device_buffers().unwrap(); // clean again
        assert_eq!(ps.upload_count(), before + 2);
    }

    #[test]
    fn adopt_all_walks_spec_order_and_detects_exhaustion() {
        let (rt, mut ps) = mk();
        let bufs = vec![
            rt.upload_f32(&[9.; 4], &[2, 2]).unwrap(),
            rt.upload_f32(&[8.; 3], &[3]).unwrap(),
        ];
        let mut it = bufs.into_iter();
        ps.adopt_all(&mut it).unwrap();
        ps.sync_host().unwrap();
        assert_eq!(ps.tensor("a").unwrap().data, vec![9.; 4]);
        assert_eq!(ps.tensor("b").unwrap().data, vec![8.; 3]);
        // an exhausted stream is a loud error naming the missing param
        let err = ps.adopt_all(&mut std::iter::empty()).unwrap_err();
        assert!(format!("{err}").contains("exhausted"));
    }

    #[test]
    fn set_flat_overwrite_is_legal_from_device_ahead() {
        let (rt, mut ps) = mk();
        let buf = rt.upload_f32(&[0.; 4], &[2, 2]).unwrap();
        ps.adopt_device(0, buf);
        ps.set_flat(0, &[1., 1., 1., 1.]); // full overwrite: no stale read
        assert!(ps.host_in_sync());
        assert_eq!(ps.tensor("a").unwrap().data, vec![1., 1., 1., 1.]);
    }

    // -- donation -------------------------------------------------------------

    #[test]
    fn take_device_buffers_then_adopt_keeps_uploads_flat() {
        let (rt, mut ps) = mk();
        ps.device_buffers().unwrap(); // first (and only) upload
        let before = ps.upload_count();
        for _ in 0..3 {
            // steady-state donated step: take → (program) → adopt outputs
            let taken = ps.take_device_buffers().unwrap();
            assert_eq!(taken.len(), 2);
            assert!(!ps.host_in_sync());
            // stand-in for the program's aliased outputs
            let outs = vec![
                rt.upload_f32(&[2.; 4], &[2, 2]).unwrap(),
                rt.upload_f32(&[3.; 3], &[3]).unwrap(),
            ];
            let mut it = outs.into_iter();
            ps.adopt_all(&mut it).unwrap();
            assert_eq!(ps.state[0], SyncState::DeviceAhead);
        }
        assert_eq!(
            ps.upload_count(),
            before,
            "donated steps must not re-upload through the ParamSet"
        );
        ps.sync_host().unwrap();
        assert_eq!(ps.tensor("a").unwrap().data, vec![2.; 4]);
    }

    #[test]
    fn take_device_buffers_uploads_host_ahead_first() {
        let (_rt, mut ps) = mk();
        // never uploaded: taking must materialize buffers from the host
        let taken = ps.take_device_buffers().unwrap();
        assert_eq!(taken.len(), 2);
        assert_eq!(ps.upload_count(), 2);
    }

    #[test]
    #[should_panic(expected = "donated")]
    fn device_read_of_donated_panics() {
        let (_rt, mut ps) = mk();
        ps.take_device_buffers().unwrap();
        let _ = ps.device_buffers();
    }

    #[test]
    #[should_panic(expected = "donated")]
    fn host_read_of_donated_panics() {
        let (_rt, mut ps) = mk();
        ps.take_device_buffers().unwrap();
        let _ = ps.tensors();
    }

    #[test]
    fn sync_host_of_donated_is_loud_error() {
        let (_rt, mut ps) = mk();
        ps.take_device_buffers().unwrap();
        let err = ps.sync_host().unwrap_err();
        assert!(format!("{err}").contains("donated"));
    }

    #[test]
    fn whole_tensor_overwrite_recovers_from_donated() {
        let (_rt, mut ps) = mk();
        ps.take_device_buffers().unwrap();
        ps.set_flat(0, &[1., 2., 3., 4.]);
        let snap = vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[3])];
        ps.restore(&snap);
        assert!(ps.host_in_sync());
        ps.device_buffers().unwrap(); // re-upload from the restored host view
    }

    #[test]
    #[should_panic(expected = "device-ahead")]
    fn host_read_of_device_ahead_panics() {
        let (rt, mut ps) = mk();
        let buf = rt.upload_f32(&[0.; 4], &[2, 2]).unwrap();
        ps.adopt_device(0, buf);
        let _ = ps.tensors();
    }

    #[test]
    #[should_panic(expected = "device-ahead")]
    fn host_axpy_of_device_ahead_panics() {
        let (rt, mut ps) = mk();
        let buf = rt.upload_f32(&[0.; 4], &[2, 2]).unwrap();
        ps.adopt_device(0, buf);
        let delta = vec![Tensor::ones(&[2, 2]), Tensor::ones(&[3])];
        ps.axpy(1.0, &delta);
    }
}
