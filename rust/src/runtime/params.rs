//! `ParamSet`: an ordered collection of named host tensors with cached
//! device buffers.
//!
//! The coordinator owns parameters host-side (FF's `W_t + τΔ_W` arithmetic,
//! gradient accumulation, checkpointing all happen on the host), and the
//! runtime needs them device-side for every program call. A `ParamSet`
//! tracks a dirty bit per tensor so *unchanged* parameters upload exactly
//! once — in particular the frozen base weights, which dominate bytes but
//! never change during low-rank finetuning.

use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{anyhow, bail, Result};

use crate::model::tensor::Tensor;
use crate::runtime::Runtime;

pub struct ParamSet {
    rt: Rc<Runtime>,
    names: Vec<String>,
    index: BTreeMap<String, usize>,
    host: Vec<Tensor>,
    device: Vec<Option<xla::PjRtBuffer>>,
    dirty: Vec<bool>,
    uploads: std::cell::Cell<u64>,
}

impl ParamSet {
    /// Build from (name, shape) spec order, pulling tensors from `values`.
    pub fn from_spec(
        rt: &Rc<Runtime>,
        spec: &[(String, Vec<usize>)],
        values: &BTreeMap<String, Tensor>,
    ) -> Result<ParamSet> {
        let mut names = Vec::new();
        let mut host = Vec::new();
        for (name, shape) in spec {
            let t = values
                .get(name)
                .ok_or_else(|| anyhow!("missing init value for param '{name}'"))?;
            if &t.shape != shape {
                bail!("param '{name}': init shape {:?} != spec {:?}", t.shape, shape);
            }
            names.push(name.clone());
            host.push(t.clone());
        }
        Ok(Self::from_tensors(rt, names, host))
    }

    /// Build an all-zeros set with the same names/shapes as `like`
    /// (Adam m/v state, gradient accumulators).
    pub fn zeros_like(rt: &Rc<Runtime>, like: &ParamSet) -> ParamSet {
        let host = like.host.iter().map(|t| Tensor::zeros(&t.shape)).collect();
        Self::from_tensors(rt, like.names.clone(), host)
    }

    fn from_tensors(rt: &Rc<Runtime>, names: Vec<String>, host: Vec<Tensor>) -> ParamSet {
        let n = names.len();
        let index = names.iter().cloned().enumerate().map(|(i, n)| (n, i)).collect();
        ParamSet {
            rt: Rc::clone(rt),
            names,
            index,
            host,
            device: (0..n).map(|_| None).collect(),
            dirty: vec![true; n],
            uploads: std::cell::Cell::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn numel(&self) -> usize {
        self.host.iter().map(|t| t.len()).sum()
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn tensor(&self, name: &str) -> Result<&Tensor> {
        let i = *self.index.get(name).ok_or_else(|| anyhow!("no param '{name}'"))?;
        Ok(&self.host[i])
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.host
    }

    /// Snapshot all host tensors (W_{t-1} for Δ_W).
    pub fn snapshot(&self) -> Vec<Tensor> {
        self.host.clone()
    }

    /// Overwrite every tensor from a snapshot; marks all dirty.
    pub fn restore(&mut self, snap: &[Tensor]) {
        assert_eq!(snap.len(), self.host.len());
        for (i, t) in snap.iter().enumerate() {
            self.host[i] = t.clone();
            self.dirty[i] = true;
            self.device[i] = None;
        }
    }

    /// Overwrite tensor `i` from a flat f32 slice (program outputs).
    pub fn set_flat(&mut self, i: usize, data: &[f32]) {
        debug_assert_eq!(self.host[i].len(), data.len());
        self.host[i].data.copy_from_slice(data);
        self.dirty[i] = true;
        self.device[i] = None;
    }

    /// In-place axpy on every tensor: `self += alpha * delta` — the FF
    /// simulated step `W_t + τΔ_W` applies this with alpha=1 per τ.
    pub fn axpy(&mut self, alpha: f32, delta: &[Tensor]) {
        assert_eq!(delta.len(), self.host.len());
        for (i, d) in delta.iter().enumerate() {
            self.host[i].axpy(alpha, d);
            self.dirty[i] = true;
            self.device[i] = None;
        }
    }

    /// Ensure device buffers exist for all tensors; uploads only dirty ones.
    pub fn device_buffers(&mut self) -> Result<Vec<&xla::PjRtBuffer>> {
        for i in 0..self.host.len() {
            if self.dirty[i] || self.device[i].is_none() {
                self.device[i] = Some(self.rt.upload_tensor(&self.host[i])?);
                self.dirty[i] = false;
                self.uploads.set(self.uploads.get() + 1);
            }
        }
        Ok(self.device.iter().map(|b| b.as_ref().unwrap()).collect())
    }

    /// Total device uploads performed (perf counter; see EXPERIMENTS §Perf).
    pub fn upload_count(&self) -> u64 {
        self.uploads.get()
    }

    /// L2 norm over the whole set (‖W_FF − W_0‖ probes, Fig 5 axes).
    pub fn norm(&self) -> f64 {
        crate::model::tensor::list_norm(&self.host)
    }
}

#[cfg(test)]
mod tests {
    //! Device-dependent behaviour is covered by rust/tests/runtime_roundtrip
    //! (requires artifacts); here we test the host-side bookkeeping via a
    //! real CPU client, which is cheap to create.
    use super::*;
    use std::collections::BTreeMap;

    fn mk() -> (Rc<Runtime>, ParamSet) {
        let rt = Runtime::cpu().unwrap();
        let spec = vec![
            ("a".to_string(), vec![2, 2]),
            ("b".to_string(), vec![3]),
        ];
        let mut vals = BTreeMap::new();
        vals.insert("a".into(), Tensor::from_vec(&[2, 2], vec![1., 2., 3., 4.]));
        vals.insert("b".into(), Tensor::from_vec(&[3], vec![5., 6., 7.]));
        let ps = ParamSet::from_spec(&rt, &spec, &vals).unwrap();
        (rt, ps)
    }

    #[test]
    fn spec_order_and_lookup() {
        let (_rt, ps) = mk();
        assert_eq!(ps.names(), &["a".to_string(), "b".to_string()]);
        assert_eq!(ps.numel(), 7);
        assert_eq!(ps.tensor("b").unwrap().data, vec![5., 6., 7.]);
        assert!(ps.tensor("c").is_err());
    }

    #[test]
    fn missing_or_misshapen_init_fails() {
        let rt = Runtime::cpu().unwrap();
        let spec = vec![("a".to_string(), vec![2])];
        assert!(ParamSet::from_spec(&rt, &spec, &BTreeMap::new()).is_err());
        let mut wrong = BTreeMap::new();
        wrong.insert("a".into(), Tensor::zeros(&[3]));
        assert!(ParamSet::from_spec(&rt, &spec, &wrong).is_err());
    }

    #[test]
    fn dirty_tracking_uploads_once() {
        let (_rt, mut ps) = mk();
        ps.device_buffers().unwrap();
        assert_eq!(ps.upload_count(), 2);
        ps.device_buffers().unwrap(); // clean: no re-upload
        assert_eq!(ps.upload_count(), 2);
        ps.set_flat(0, &[9., 9., 9., 9.]);
        ps.device_buffers().unwrap(); // only tensor 0 re-uploads
        assert_eq!(ps.upload_count(), 3);
    }

    #[test]
    fn axpy_and_snapshot_restore() {
        let (_rt, mut ps) = mk();
        let snap = ps.snapshot();
        let delta = vec![Tensor::ones(&[2, 2]), Tensor::ones(&[3])];
        ps.axpy(2.0, &delta);
        assert_eq!(ps.tensor("a").unwrap().data, vec![3., 4., 5., 6.]);
        ps.restore(&snap);
        assert_eq!(ps.tensor("a").unwrap().data, vec![1., 2., 3., 4.]);
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let (rt, ps) = mk();
        let z = ParamSet::zeros_like(&rt, &ps);
        assert_eq!(z.numel(), ps.numel());
        assert!(z.tensor("a").unwrap().data.iter().all(|v| *v == 0.0));
    }
}
