//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! This is the only module that touches the `xla` crate. The flow per
//! program (see /opt/xla-example/load_hlo for the reference wiring):
//!
//! ```text
//! HloModuleProto::from_text_file → XlaComputation::from_proto
//!     → PjRtClient::compile → PjRtLoadedExecutable
//! ```
//!
//! Programs were lowered with `return_tuple=True`; PJRT untuples the root
//! tuple at execution time, so `execute_b` hands back one device buffer per
//! output leaf. That gives two output modes:
//!
//! * **decoded** ([`Program::execute_buffers`]) — download every leaf into
//!   host `Vec<f32>`s (the original path, still used where the coordinator
//!   needs all outputs host-side, e.g. per-micro-batch gradients);
//! * **raw** ([`Program::execute_raw`]) — keep every leaf as a device
//!   buffer. The trainer's Adam step retains its updated trainable/m/v
//!   outputs this way and feeds them straight back in on the next step,
//!   eliminating the per-step host↔device round-trip of the full parameter
//!   + optimizer state. Individual leaves (the loss scalar) can still be
//!   pulled selectively with [`Program::download_output`];
//! * **raw + donated** ([`Program::execute_raw_donated`]) — like raw, but
//!   some inputs are passed by value ([`InputBuf::Donated`]) and consumed.
//!   Programs lowered with `donate_argnums` (see
//!   `python/compile/model.py`, `PROGRAM_DONATE`) carry an
//!   `input_output_alias` map in their HLO, so PJRT reuses the donated
//!   input allocations for the aliased outputs *in place* — one generation
//!   of accumulator/optimizer state lives per step instead of two. A
//!   donated buffer is invalid after the call; the ownership transfer into
//!   this API is what makes reuse-after-donation a compile error rather
//!   than a runtime one.
//!
//! Inputs are passed as device buffers (`execute_b`) so large frozen
//! parameter sets upload once and are reused across steps (see
//! `params::ParamSet` and its sync-state machine). The full host↔device
//! movement rules — which programs donate, which buffers are long-lived,
//! and the steady-state traffic expectations — are documented in
//! `docs/transfer-contract.md`.
//!
//! # Perf counters
//!
//! Every host→device upload and device→host download that flows through
//! this module is metered in [`Runtime::stats`] ([`TransferStats`]): call
//! counts and **bytes** in each direction, plus the bytes of device memory
//! handed back to the allocator through donation. `bench_runtime`/
//! `bench_step` report these per Adam step and per FF probe, and
//! `RunSummary` carries a per-run [`TransferSnapshot`] — the
//! device-residency win is measured, not asserted.

pub mod manifest;
pub mod params;
pub mod stream;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{ArtifactIndex, Dtype, IoSlot, Manifest, ProgramSpec};
pub use params::{ParamSet, SyncState};
pub use stream::{ExecStream, PendingLoss, PendingStep, ResolvedStep, StreamStats, SyncReason};

use crate::model::tensor::Tensor;

/// Host↔device traffic meters, shared by every upload/download helper on a
/// [`Runtime`]. Atomic because one runtime is shared (`Arc`) across the
/// scheduler's worker threads (`crate::sched`): concurrent runs meter into
/// the same counters, and `fetch_add` keeps the totals **exact** — never
/// lost-update approximate. `Relaxed` ordering is sufficient: these are
/// pure tallies with no cross-thread happens-before obligations; snapshots
/// taken while runs are in flight are a consistent-enough point-in-time
/// view, and snapshots taken at quiescent points (before/after a
/// `WorkerPool` batch) are exact aggregates.
#[derive(Debug, Default)]
pub struct TransferStats {
    uploads: AtomicU64,
    uploaded_bytes: AtomicU64,
    downloads: AtomicU64,
    downloaded_bytes: AtomicU64,
    donations: AtomicU64,
    donated_bytes: AtomicU64,
}

impl TransferStats {
    pub fn record_upload(&self, bytes: usize) {
        self.uploads.fetch_add(1, Ordering::Relaxed);
        self.uploaded_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    pub fn record_download(&self, bytes: usize) {
        self.downloads.fetch_add(1, Ordering::Relaxed);
        self.downloaded_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// One input buffer donated into a program call: its allocation is
    /// either reused in place for an aliased output or freed immediately —
    /// bytes the allocator does *not* have to hold a second generation of.
    pub fn record_donation(&self, bytes: usize) {
        self.donations.fetch_add(1, Ordering::Relaxed);
        self.donated_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Point-in-time copy of the counters; diff two with
    /// [`TransferSnapshot::since`] to attribute traffic to a code region.
    /// Exact at quiescent points; see the struct docs for what a snapshot
    /// means while other worker threads are mid-run.
    pub fn snapshot(&self) -> TransferSnapshot {
        TransferSnapshot {
            uploads: self.uploads.load(Ordering::Relaxed),
            uploaded_bytes: self.uploaded_bytes.load(Ordering::Relaxed),
            downloads: self.downloads.load(Ordering::Relaxed),
            downloaded_bytes: self.downloaded_bytes.load(Ordering::Relaxed),
            donations: self.donations.load(Ordering::Relaxed),
            donated_bytes: self.donated_bytes.load(Ordering::Relaxed),
        }
    }
}

/// Per-run (per-engine) **exact** transfer meter.
///
/// The shared [`Runtime::stats`] counters are a process-global total:
/// under the scheduler (`crate::sched`) every concurrent run tallies into
/// them, so a *window* over them attributes sibling traffic to whichever
/// run happens to be measuring. A `TransferMeter` is the per-run half of
/// the contract (`docs/transfer-contract.md` §5): one meter is owned by
/// each `StepEngine` and threaded through every upload/download helper
/// that moves that run's bytes (`ParamSet`, `BatchStager`, `EvalCache`,
/// `PendingLoss`, donated program calls). Each crossing records into
/// **both** this meter and the global stats, so per-run totals are exact
/// at any `--jobs` level and the per-run meters of a quiescent batch sum
/// exactly to the global delta (`rust/tests/sched_pool.rs`,
/// `rust/tests/sched_queue.rs`).
#[derive(Debug, Default)]
pub struct TransferMeter {
    local: TransferStats,
}

impl TransferMeter {
    /// Fresh meter with zeroed counters, ready to share (`Arc`) across
    /// the per-run components that move bytes on this run's behalf.
    pub fn new() -> Arc<TransferMeter> {
        Arc::new(TransferMeter::default())
    }

    pub fn record_upload(&self, bytes: usize) {
        self.local.record_upload(bytes);
    }

    pub fn record_download(&self, bytes: usize) {
        self.local.record_download(bytes);
    }

    pub fn record_donation(&self, bytes: usize) {
        self.local.record_donation(bytes);
    }

    /// This run's exact traffic so far.
    pub fn snapshot(&self) -> TransferSnapshot {
        self.local.snapshot()
    }

    // -- metered wrappers over the runtime's upload/download helpers ------
    // (the runtime call meters the *global* stats; the extra record here
    // is the run-local tally — two counters, one crossing, no double
    // count on either.)

    pub fn upload_f32(
        &self,
        rt: &Runtime,
        data: &[f32],
        shape: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        let buf = rt.upload_f32(data, shape)?;
        self.record_upload(std::mem::size_of_val(data));
        Ok(buf)
    }

    pub fn upload_i32(
        &self,
        rt: &Runtime,
        data: &[i32],
        shape: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        let buf = rt.upload_i32(data, shape)?;
        self.record_upload(std::mem::size_of_val(data));
        Ok(buf)
    }

    pub fn upload_scalar(&self, rt: &Runtime, v: f32) -> Result<xla::PjRtBuffer> {
        self.upload_f32(rt, &[v], &[])
    }

    pub fn upload_tensor(&self, rt: &Runtime, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.upload_f32(rt, &t.data, &t.shape)
    }

    pub fn download_f32(&self, rt: &Runtime, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let v = rt.download_f32(buf)?;
        self.record_download(v.len() * 4);
        Ok(v)
    }
}

/// Upload through an *optional* per-run meter: the metered wrapper when
/// the caller owns one, the plain (global-only) runtime helper
/// otherwise. One code path for components that work in both modes
/// (`BatchStager`, `EvalCache`), so the run-local byte accounting can
/// never drift from the global metering.
pub fn upload_f32_opt(
    rt: &Runtime,
    meter: Option<&TransferMeter>,
    data: &[f32],
    shape: &[usize],
) -> Result<xla::PjRtBuffer> {
    match meter {
        Some(m) => m.upload_f32(rt, data, shape),
        None => rt.upload_f32(data, shape),
    }
}

/// [`upload_f32_opt`]'s i32 twin.
pub fn upload_i32_opt(
    rt: &Runtime,
    meter: Option<&TransferMeter>,
    data: &[i32],
    shape: &[usize],
) -> Result<xla::PjRtBuffer> {
    match meter {
        Some(m) => m.upload_i32(rt, data, shape),
        None => rt.upload_i32(data, shape),
    }
}

/// Immutable copy of [`TransferStats`] at one instant.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransferSnapshot {
    pub uploads: u64,
    pub uploaded_bytes: u64,
    pub downloads: u64,
    pub downloaded_bytes: u64,
    pub donations: u64,
    pub donated_bytes: u64,
}

impl TransferSnapshot {
    /// Traffic since an earlier snapshot.
    pub fn since(&self, earlier: &TransferSnapshot) -> TransferSnapshot {
        TransferSnapshot {
            uploads: self.uploads.saturating_sub(earlier.uploads),
            uploaded_bytes: self.uploaded_bytes.saturating_sub(earlier.uploaded_bytes),
            downloads: self.downloads.saturating_sub(earlier.downloads),
            downloaded_bytes: self.downloaded_bytes.saturating_sub(earlier.downloaded_bytes),
            donations: self.donations.saturating_sub(earlier.donations),
            donated_bytes: self.donated_bytes.saturating_sub(earlier.donated_bytes),
        }
    }

    /// Element-wise sum with another snapshot (summing per-run meters
    /// into per-tenant or whole-batch totals).
    pub fn plus(&self, other: &TransferSnapshot) -> TransferSnapshot {
        TransferSnapshot {
            uploads: self.uploads + other.uploads,
            uploaded_bytes: self.uploaded_bytes + other.uploaded_bytes,
            downloads: self.downloads + other.downloads,
            downloaded_bytes: self.downloaded_bytes + other.downloaded_bytes,
            donations: self.donations + other.donations,
            donated_bytes: self.donated_bytes + other.donated_bytes,
        }
    }

    /// Mean traffic per iteration (bench reporting).
    pub fn per_iter(&self, iters: u64) -> TransferSnapshot {
        let n = iters.max(1);
        TransferSnapshot {
            uploads: self.uploads / n,
            uploaded_bytes: self.uploaded_bytes / n,
            downloads: self.downloads / n,
            downloaded_bytes: self.downloaded_bytes / n,
            donations: self.donations / n,
            donated_bytes: self.donated_bytes / n,
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "up {} ({} calls), down {} ({} calls)",
            human_bytes(self.uploaded_bytes),
            self.uploads,
            human_bytes(self.downloaded_bytes),
            self.downloads
        );
        if self.donations > 0 {
            s.push_str(&format!(
                ", donated {} ({} bufs)",
                human_bytes(self.donated_bytes),
                self.donations
            ));
        }
        s
    }

    /// JSON form for the machine-readable bench outputs
    /// (`BENCH_step.json` / `BENCH_runtime.json`).
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("uploads", self.uploads as i64)
            .set("uploaded_bytes", self.uploaded_bytes as i64)
            .set("downloads", self.downloads as i64)
            .set("downloaded_bytes", self.downloaded_bytes as i64)
            .set("donations", self.donations as i64)
            .set("donated_bytes", self.donated_bytes as i64)
    }
}

/// `1234567` → `"1.18 MiB"` (bench/report formatting).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 4] = ["B", "KiB", "MiB", "GiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Shared PJRT CPU client. `Arc` because one client is shared by every
/// concurrent run: buffers and programs hold a handle, and the scheduler
/// (`crate::sched`) executes whole training runs on worker threads against
/// the same runtime (XLA:CPU additionally parallelizes internally).
pub struct Runtime {
    pub client: xla::PjRtClient,
    /// Host↔device traffic meters (see module docs, §Perf counters).
    pub stats: TransferStats,
}

// Thread-safety (compiled only under `--features xla-shared-client`): the
// PJRT C API requires implementations to be thread-safe — clients, loaded
// executables, and buffers may be used concurrently from multiple host
// threads (compile/execute/transfer all take internal locks; XLA:CPU's
// client is explicitly multi-threaded). `TransferStats` is atomic.
// Everything else on `Runtime` is immutable after construction. Each
// *run* owns its own buffers (ParamSets, staged batches, pending losses)
// on the worker thread that created them; only the client, compiled
// programs, and these counters are shared.
//
// The load-bearing assumption is about the *wrapper* crate, not PJRT:
// the `xla` wrapper types must hold their C++ handles as plain pointers
// with no non-atomic shared bookkeeping. Upstream xla-rs wrappers keep
// the client behind a non-atomic `Rc` cloned into every
// `PjRtBuffer`/`PjRtLoadedExecutable` — cloning/dropping those across
// worker threads races the refcount (UB: corruption, double-free)
// regardless of PJRT's own thread-safety. Since Cargo.toml resolves
// `xla` from a floating branch, these impls are therefore feature-gated
// OFF by default; without them, cross-thread use of `Runtime`/`Program`
// is a compile error and the scheduler (`crate::sched`) runs jobs
// sequentially. Enabling the feature requires pinning `xla` to a `rev`
// whose handle semantics have been audited as refcount-free (or
// `Arc`-based) and recording it in rust/XLA_AUDIT —
// ci/check_xla_audit.sh enforces that precondition in CI.
// SAFETY: PJRT clients are thread-safe per the C API contract, and the feature gate requires an audited refcount-free xla wrapper rev (full argument above).
#[cfg(feature = "xla-shared-client")]
unsafe impl Send for Runtime {}
// SAFETY: shared state on `Runtime` is the thread-safe client plus the atomic `TransferStats`; everything else is immutable after construction.
#[cfg(feature = "xla-shared-client")]
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Arc<Runtime>> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Arc::new(Runtime { client, stats: TransferStats::default() }))
    }

    /// Compile one program of an artifact. Compilation is cached per
    /// (artifact, program) by [`Artifact::program`].
    pub fn load_program(self: &Arc<Self>, man: &Manifest, name: &str) -> Result<Program> {
        let path = man.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        crate::debug!(
            "compiled {}/{} in {:.2?}",
            man.key,
            name,
            t0.elapsed()
        );
        Ok(Program {
            rt: Arc::clone(self),
            name: name.to_string(),
            spec: man.program(name)?.clone(),
            exe,
        })
    }

    // -- host<->device helpers ------------------------------------------------

    pub fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        let buf = self
            .client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow!("upload f32{shape:?}: {e}"))?;
        self.stats.record_upload(std::mem::size_of_val(data));
        Ok(buf)
    }

    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        let buf = self
            .client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow!("upload i32{shape:?}: {e}"))?;
        self.stats.record_upload(std::mem::size_of_val(data));
        Ok(buf)
    }

    pub fn upload_scalar(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.upload_f32(&[v], &[])
    }

    pub fn upload_tensor(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.upload_f32(&t.data, &t.shape)
    }

    /// Download one f32 device buffer into a host vector (metered).
    pub fn download_f32(&self, buf: &xla::PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("download f32 buffer: {e}"))?;
        let v = lit.to_vec::<f32>().map_err(|e| anyhow!("decode f32 buffer: {e}"))?;
        self.stats.record_download(v.len() * 4);
        Ok(v)
    }
}

/// One input to a donated program execution ([`Program::execute_raw_donated`]).
///
/// `Donated` passes ownership: the buffer is handed to the executable,
/// which (per its `input_output_alias` map) may reuse the allocation in
/// place for an output, and is dropped after the call — it cannot be
/// touched again. `Borrowed` inputs stay valid across the call (frozen
/// params, cached batch buffers, scalars).
pub enum InputBuf<'a> {
    Borrowed(&'a xla::PjRtBuffer),
    Donated(xla::PjRtBuffer),
}

impl InputBuf<'_> {
    fn buffer(&self) -> &xla::PjRtBuffer {
        match self {
            InputBuf::Borrowed(b) => b,
            InputBuf::Donated(b) => b,
        }
    }
}

/// One compiled executable plus its manifest I/O spec.
pub struct Program {
    rt: Arc<Runtime>,
    pub name: String,
    pub spec: ProgramSpec,
    exe: xla::PjRtLoadedExecutable,
}

// Thread-safety (compiled only under `--features xla-shared-client`): see
// the `Runtime` impls — PJRT loaded executables are thread-safe to execute
// concurrently per the PJRT API contract; `name` and `spec` are immutable
// after construction. Compiled programs are the read-only artifacts the
// scheduler shares across worker threads. Gated for the same reason as
// `Runtime`: the wrapper may clone a non-atomic client handle into each
// executable/buffer, so the impls only exist once the resolved xla
// revision is pinned and audited (rust/XLA_AUDIT).
// SAFETY: PJRT loaded executables execute concurrently per the API contract; gated on the audited wrapper rev like `Runtime` (see the block above).
#[cfg(feature = "xla-shared-client")]
unsafe impl Send for Program {}
// SAFETY: `name` and `spec` are immutable after construction; the executable is shared read-only across workers under the same audited-rev gate.
#[cfg(feature = "xla-shared-client")]
unsafe impl Sync for Program {}

/// Decoded program outputs, aligned with `spec.outputs`.
pub struct Outputs {
    pub slots: Vec<IoSlot>,
    pub values: Vec<Vec<f32>>,
}

impl Outputs {
    pub fn by_name(&self, name: &str) -> Result<&[f32]> {
        self.slots
            .iter()
            .position(|s| s.name == name)
            .map(|i| self.values[i].as_slice())
            .ok_or_else(|| anyhow!("no output '{name}'"))
    }

    pub fn scalar(&self, name: &str) -> Result<f32> {
        let v = self.by_name(name)?;
        if v.len() != 1 {
            bail!("output '{name}' is not a scalar ({} elems)", v.len());
        }
        Ok(v[0])
    }
}

impl Program {
    fn check_arity(&self, got: usize) -> Result<()> {
        if got != self.spec.inputs.len() {
            bail!(
                "program '{}' expects {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                got
            );
        }
        Ok(())
    }

    /// Donation is a property of the *executable* (its `input_output_alias`
    /// map), not of the call API — every execution mode funnels into the
    /// same PJRT execute, which invalidates donatable inputs regardless of
    /// how the rust side borrowed them. The borrowed-input modes therefore
    /// refuse donating programs outright: silently invalidating buffers the
    /// caller still holds (and that a `ParamSet` may still track as
    /// `InSync`) is exactly the bug class `execute_raw_donated`'s ownership
    /// transfer exists to prevent.
    fn check_not_donating(&self) -> Result<()> {
        if !self.spec.donated_inputs.is_empty() {
            bail!(
                "program '{}' donates {} input slots (input_output_alias): \
                 borrowed-input execution would leave the caller holding \
                 invalidated buffers — use execute_raw_donated",
                self.name,
                self.spec.donated_inputs.len()
            );
        }
        Ok(())
    }

    /// Execute with pre-uploaded device buffers, downloading every output
    /// (hot path for programs whose outputs the coordinator consumes
    /// host-side, e.g. per-micro-batch gradients). Downloads are metered
    /// on the global [`Runtime::stats`] only; callers that own a per-run
    /// [`TransferMeter`] use [`Program::execute_buffers_metered`].
    pub fn execute_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Outputs> {
        self.execute_buffers_metered(inputs, None)
    }

    /// [`Program::execute_buffers`] that additionally records every
    /// downloaded byte into a per-run [`TransferMeter`] (exact per-run
    /// accounting under the scheduler — `docs/transfer-contract.md` §5).
    pub fn execute_buffers_metered(
        &self,
        inputs: &[&xla::PjRtBuffer],
        meter: Option<&TransferMeter>,
    ) -> Result<Outputs> {
        self.check_arity(inputs.len())?;
        self.check_not_donating()?;
        let mut out = self
            .exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("executing '{}': {e}", self.name))?;
        let mut bufs = out.swap_remove(0);
        if bufs.len() == self.spec.outputs.len() {
            // untupled root: one buffer per output leaf. For single-output
            // programs the count can't distinguish a leaf from a whole root
            // tuple, so a failed leaf decode there falls through to the
            // tuple path instead of erroring.
            let mut values = Vec::with_capacity(bufs.len());
            let mut leaf_decode_ok = true;
            for (i, buf) in bufs.iter().enumerate() {
                match self.download_output_metered(buf, i, meter) {
                    Ok(v) => values.push(v),
                    Err(e) if bufs.len() == 1 => {
                        crate::debug!(
                            "program '{}': leaf decode failed ({e:#}), \
                             retrying as whole root tuple",
                            self.name
                        );
                        leaf_decode_ok = false;
                        break;
                    }
                    Err(e) => return Err(e),
                }
            }
            if leaf_decode_ok {
                return Ok(Outputs { slots: self.spec.outputs.clone(), values });
            }
        }
        if bufs.len() == 1 {
            // legacy path: root tuple kept whole — download + decompose
            let tuple = bufs
                .pop()
                .unwrap()
                .to_literal_sync()
                .map_err(|e| anyhow!("downloading '{}' result: {e}", self.name))?;
            return self.decode_tuple(tuple, meter);
        }
        bail!(
            "program '{}' returned {} output buffers, manifest says {}",
            self.name,
            bufs.len(),
            self.spec.outputs.len()
        )
    }

    /// Execute with pre-uploaded device buffers, keeping every output as a
    /// raw device buffer — nothing is downloaded. Buffers align with
    /// `spec.outputs`; use [`Program::download_output`] to pull individual
    /// leaves (the loss scalar) and `ParamSet::adopt_device` to retain
    /// updated state device-side.
    ///
    /// Requires the runtime to untuple the root (every multi-output
    /// program on this backend does); for single-output programs the
    /// buffer count cannot distinguish leaf from root tuple — raw-mode
    /// callers are all multi-output, and `execute_buffers` handles the
    /// single-output fallback.
    pub fn execute_raw(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        self.check_arity(inputs.len())?;
        self.check_not_donating()?;
        let mut out = self
            .exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("executing '{}': {e}", self.name))?;
        let bufs = out.swap_remove(0);
        if bufs.len() != self.spec.outputs.len() {
            bail!(
                "program '{}' returned {} output buffers, manifest says {} — \
                 raw output mode requires untupled results",
                self.name,
                bufs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(bufs)
    }

    /// Execute keeping every output as a raw device buffer, *consuming* the
    /// [`InputBuf::Donated`] inputs. Use for programs lowered with
    /// `donate_argnums` (`grad_accum`, `grad_finalize`, `adam_apply`): the
    /// executable's `input_output_alias` map lets PJRT reuse the donated
    /// allocations for the aliased outputs in place, so steady-state
    /// optimizer steps keep one generation of state live instead of two.
    ///
    /// Donated buffers are invalid after this call whether or not the
    /// backend chose to alias them (PJRT invalidates every donatable
    /// input); taking them by value makes reuse impossible by
    /// construction. Each donation is metered in [`Runtime::stats`] with
    /// the byte size the manifest records for that input slot.
    pub fn execute_raw_donated(&self, inputs: Vec<InputBuf>) -> Result<Vec<xla::PjRtBuffer>> {
        self.execute_raw_donated_metered(inputs, None)
    }

    /// [`Program::execute_raw_donated`] that additionally records each
    /// donation into a per-run [`TransferMeter`] (exact per-run
    /// accounting under the scheduler).
    pub fn execute_raw_donated_metered(
        &self,
        inputs: Vec<InputBuf>,
        meter: Option<&TransferMeter>,
    ) -> Result<Vec<xla::PjRtBuffer>> {
        self.check_arity(inputs.len())?;
        // Every slot the executable donates must be passed by value: a
        // borrowed buffer there would be invalidated while its owner still
        // holds (and might reuse) it. The converse is allowed — passing a
        // buffer as Donated on a slot the manifest doesn't declare (e.g. a
        // pre-donation artifact) just drops it after the call.
        for &i in &self.spec.donated_inputs {
            match inputs.get(i) {
                Some(InputBuf::Donated(_)) => {}
                Some(InputBuf::Borrowed(_)) => bail!(
                    "program '{}' donates input #{i} ('{}') — pass it by \
                     value (InputBuf::Donated), not borrowed",
                    self.name,
                    self.spec.inputs[i].name
                ),
                None => bail!(
                    "program '{}': manifest donates input #{i} but the \
                     program only has {} inputs",
                    self.name,
                    self.spec.inputs.len()
                ),
            }
        }
        let refs: Vec<&xla::PjRtBuffer> = inputs.iter().map(InputBuf::buffer).collect();
        let mut out = self
            .exe
            .execute_b(&refs)
            .map_err(|e| anyhow!("executing '{}' (donated): {e}", self.name))?;
        drop(refs);
        // Meter only the slots the executable actually donates: on
        // pre-donation artifacts a Donated input is merely dropped, not
        // reused in place, and must not count as saved bytes.
        for &i in &self.spec.donated_inputs {
            let bytes = self.spec.inputs[i].byte_len();
            self.rt.stats.record_donation(bytes);
            if let Some(m) = meter {
                m.record_donation(bytes);
            }
        }
        drop(inputs); // donated inputs are dead from here on
        let bufs = out.swap_remove(0);
        if bufs.len() != self.spec.outputs.len() {
            bail!(
                "program '{}' returned {} output buffers, manifest says {} — \
                 raw output mode requires untupled results",
                self.name,
                bufs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(bufs)
    }

    /// Position of a named output in `spec.outputs` (and thus in the buffer
    /// list returned by [`Program::execute_raw`]).
    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.spec
            .outputs
            .iter()
            .position(|s| s.name == name)
            .ok_or_else(|| anyhow!("program '{}' has no output '{name}'", self.name))
    }

    /// Selectively download one raw output buffer (index into
    /// `spec.outputs`) as f32s, validating dtype and element count.
    pub fn download_output(&self, buf: &xla::PjRtBuffer, index: usize) -> Result<Vec<f32>> {
        self.download_output_metered(buf, index, None)
    }

    /// [`Program::download_output`] that additionally records the
    /// downloaded bytes into a per-run [`TransferMeter`].
    pub fn download_output_metered(
        &self,
        buf: &xla::PjRtBuffer,
        index: usize,
        meter: Option<&TransferMeter>,
    ) -> Result<Vec<f32>> {
        let slot = self
            .spec
            .outputs
            .get(index)
            .ok_or_else(|| anyhow!("program '{}' has no output #{index}", self.name))?;
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("downloading output '{}': {e}", slot.name))?;
        let v = Self::literal_to_f32(lit, slot)?;
        self.rt.stats.record_download(v.len() * 4);
        if let Some(m) = meter {
            m.record_download(v.len() * 4);
        }
        Ok(v)
    }

    fn literal_to_f32(lit: xla::Literal, slot: &IoSlot) -> Result<Vec<f32>> {
        let v: Vec<f32> = match slot.dtype {
            Dtype::F32 => lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output '{}': {e}", slot.name))?,
            Dtype::I32 => lit
                .to_vec::<i32>()
                .map_err(|e| anyhow!("output '{}': {e}", slot.name))?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
        };
        if v.len() != slot.numel() {
            bail!(
                "output '{}' has {} elems, expected {}",
                slot.name,
                v.len(),
                slot.numel()
            );
        }
        Ok(v)
    }

    fn decode_tuple(&self, tuple: xla::Literal, meter: Option<&TransferMeter>) -> Result<Outputs> {
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("decomposing '{}' tuple: {e}", self.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "program '{}' returned {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut values = Vec::with_capacity(parts.len());
        for (lit, slot) in parts.into_iter().zip(self.spec.outputs.iter()) {
            let v = Self::literal_to_f32(lit, slot)?;
            self.rt.stats.record_download(v.len() * 4);
            if let Some(m) = meter {
                m.record_download(v.len() * 4);
            }
            values.push(v);
        }
        Ok(Outputs { slots: self.spec.outputs.clone(), values })
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }
}

/// Lazy per-artifact program cache: an `Artifact` owns its manifest plus the
/// compiled executables, compiling each program on first use (fig-grid
/// experiments touch many artifacts but rarely all four programs of each).
///
/// The cache is lock-guarded so one `Arc<Artifact>` can be shared by every
/// worker of a [`crate::sched::WorkerPool`]: concurrent runs over the same
/// artifact share each read-only executable. Compilation happens *outside*
/// the lock with a double-checked insert — a worker asking for a
/// different, also-uncached program never blocks behind another program's
/// XLA compile; two workers racing on the *same* program may rarely both
/// compile it, and the first insert wins.
pub struct Artifact {
    pub manifest: Manifest,
    rt: Arc<Runtime>,
    programs: Mutex<BTreeMap<String, Arc<Program>>>,
}

impl Artifact {
    pub fn load(rt: &Arc<Runtime>, dir: &Path) -> Result<Artifact> {
        let manifest =
            Manifest::load(dir).with_context(|| format!("loading artifact {}", dir.display()))?;
        Ok(Artifact { manifest, rt: Arc::clone(rt), programs: Default::default() })
    }

    pub fn program(&self, name: &str) -> Result<Arc<Program>> {
        if let Some(p) = self
            .programs
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .get(name)
        {
            return Ok(Arc::clone(p));
        }
        // Compile with the lock released so concurrent requests for
        // *other* programs of this artifact proceed; re-check on insert
        // (first finisher wins, a racing duplicate compile is dropped).
        let p = Arc::new(self.rt.load_program(&self.manifest, name)?);
        let mut cache = self.programs.lock().unwrap_or_else(PoisonError::into_inner);
        Ok(Arc::clone(cache.entry(name.to_string()).or_insert(p)))
    }

    pub fn runtime(&self) -> &Arc<Runtime> {
        &self.rt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_stats_meter_both_directions() {
        let s = TransferStats::default();
        s.record_upload(1024);
        s.record_upload(512);
        s.record_download(4);
        let snap = s.snapshot();
        assert_eq!(snap.uploads, 2);
        assert_eq!(snap.uploaded_bytes, 1536);
        assert_eq!(snap.downloads, 1);
        assert_eq!(snap.downloaded_bytes, 4);
    }

    #[test]
    fn snapshot_since_and_per_iter() {
        let a = TransferSnapshot {
            uploads: 10,
            uploaded_bytes: 4000,
            downloads: 2,
            downloaded_bytes: 80,
            ..Default::default()
        };
        let b = TransferSnapshot {
            uploads: 4,
            uploaded_bytes: 1000,
            downloads: 2,
            downloaded_bytes: 80,
            ..Default::default()
        };
        let d = a.since(&b);
        assert_eq!(d.uploads, 6);
        assert_eq!(d.uploaded_bytes, 3000);
        assert_eq!(d.downloads, 0);
        let p = d.per_iter(3);
        assert_eq!(p.uploads, 2);
        assert_eq!(p.uploaded_bytes, 1000);
        // per_iter never divides by zero
        assert_eq!(d.per_iter(0).uploads, 6);
    }

    #[test]
    fn donation_meters_and_reports() {
        let s = TransferStats::default();
        s.record_upload(64);
        let before = s.snapshot();
        assert!(!before.report().contains("donated"), "no donations yet");
        s.record_donation(4096);
        s.record_donation(4096);
        let d = s.snapshot().since(&before);
        assert_eq!(d.donations, 2);
        assert_eq!(d.donated_bytes, 8192);
        assert_eq!(d.uploads, 0, "donation is not an upload");
        assert!(d.report().contains("donated 8.00 KiB (2 bufs)"));
    }

    #[test]
    fn concurrent_meter_updates_are_exact() {
        // The scheduler shares one TransferStats across worker threads;
        // totals must be exact under contention, not lost-update
        // approximate. 8 threads × 10k records each, all tallied.
        let s = std::sync::Arc::new(TransferStats::default());
        let threads = 8u64;
        let per = 10_000u64;
        std::thread::scope(|scope| {
            for t in 0..threads {
                let s = std::sync::Arc::clone(&s);
                scope.spawn(move || {
                    for i in 0..per {
                        s.record_upload(4);
                        if (i + t) % 2 == 0 {
                            s.record_download(8);
                        }
                        if i % 4 == 0 {
                            s.record_donation(16);
                        }
                    }
                });
            }
        });
        let snap = s.snapshot();
        assert_eq!(snap.uploads, threads * per);
        assert_eq!(snap.uploaded_bytes, threads * per * 4);
        assert_eq!(snap.downloads, threads * per / 2);
        assert_eq!(snap.downloaded_bytes, threads * per / 2 * 8);
        assert_eq!(snap.donations, threads * (per / 4), "10k/4 per thread");
        assert_eq!(snap.donated_bytes, threads * (per / 4) * 16);
    }

    #[test]
    fn snapshot_plus_sums_elementwise() {
        let a = TransferSnapshot {
            uploads: 1,
            uploaded_bytes: 100,
            downloads: 2,
            downloaded_bytes: 8,
            donations: 3,
            donated_bytes: 48,
        };
        let b = TransferSnapshot {
            uploads: 10,
            uploaded_bytes: 1000,
            downloads: 20,
            downloaded_bytes: 80,
            donations: 30,
            donated_bytes: 480,
        };
        let s = a.plus(&b);
        assert_eq!(s.uploads, 11);
        assert_eq!(s.uploaded_bytes, 1100);
        assert_eq!(s.downloads, 22);
        assert_eq!(s.downloaded_bytes, 88);
        assert_eq!(s.donations, 33);
        assert_eq!(s.donated_bytes, 528);
        assert_eq!(s.since(&b), a, "plus is since's inverse");
    }

    #[test]
    fn transfer_meter_tallies_local_and_global() {
        // A metered upload/download crosses once but is recorded twice:
        // in the run-local meter and in the shared global stats, with
        // identical byte counts.
        let rt = Runtime::cpu().unwrap();
        let meter = TransferMeter::new();
        let global0 = rt.stats.snapshot();
        let buf = meter.upload_f32(&rt, &[1.0; 8], &[8]).unwrap();
        let _i = meter.upload_i32(&rt, &[1; 4], &[4]).unwrap();
        let _s = meter.upload_scalar(&rt, 0.5).unwrap();
        let v = meter.download_f32(&rt, &buf).unwrap();
        assert_eq!(v.len(), 8);
        let local = meter.snapshot();
        let global = rt.stats.snapshot().since(&global0);
        assert_eq!(local.uploads, 3);
        assert_eq!(local.uploaded_bytes, 8 * 4 + 4 * 4 + 4);
        assert_eq!(local.downloads, 1);
        assert_eq!(local.downloaded_bytes, 32);
        assert_eq!(local, global, "one crossing, two exact tallies");
    }

    #[test]
    fn unmetered_traffic_stays_out_of_the_meter() {
        let rt = Runtime::cpu().unwrap();
        let meter = TransferMeter::new();
        let _b = rt.upload_f32(&[0.0; 4], &[4]).unwrap();
        assert_eq!(meter.snapshot(), TransferSnapshot::default());
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn uploads_are_metered_through_the_client() {
        let rt = Runtime::cpu().unwrap();
        let base = rt.stats.snapshot();
        let _b = rt.upload_f32(&[1.0; 16], &[4, 4]).unwrap();
        let _c = rt.upload_i32(&[1; 8], &[8]).unwrap();
        let d = rt.stats.snapshot().since(&base);
        assert_eq!(d.uploads, 2);
        assert_eq!(d.uploaded_bytes, 16 * 4 + 8 * 4);
    }

    #[test]
    fn download_roundtrips_and_meters() {
        let rt = Runtime::cpu().unwrap();
        let buf = rt.upload_f32(&[1.0, 2.0, 3.0], &[3]).unwrap();
        let base = rt.stats.snapshot();
        let v = rt.download_f32(&buf).unwrap();
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
        let d = rt.stats.snapshot().since(&base);
        assert_eq!(d.downloads, 1);
        assert_eq!(d.downloaded_bytes, 12);
    }
}
