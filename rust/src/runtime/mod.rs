//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! This is the only module that touches the `xla` crate. The flow per
//! program (see /opt/xla-example/load_hlo for the reference wiring):
//!
//! ```text
//! HloModuleProto::from_text_file → XlaComputation::from_proto
//!     → PjRtClient::compile → PjRtLoadedExecutable
//! ```
//!
//! Programs were lowered with `return_tuple=True`, so execution returns a
//! single tuple buffer; we download it synchronously and decompose into
//! per-output literals. Inputs are passed as device buffers (`execute_b`)
//! so large frozen parameter sets upload once and are reused across steps
//! (see `params::ParamSet` buffer caching).

pub mod manifest;
pub mod params;

use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{ArtifactIndex, Dtype, IoSlot, Manifest, ProgramSpec};
pub use params::ParamSet;

use crate::model::tensor::Tensor;

/// Shared PJRT CPU client. `Rc` because buffers hold a client handle and the
/// coordinator is single-threaded around the device (XLA:CPU parallelizes
/// internally).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Rc<Runtime>> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Rc::new(Runtime { client }))
    }

    /// Compile one program of an artifact. Compilation is cached per
    /// (artifact, program) by `ProgramCache`.
    pub fn load_program(self: &Rc<Self>, man: &Manifest, name: &str) -> Result<Program> {
        let path = man.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        crate::debug!(
            "compiled {}/{} in {:.2?}",
            man.key,
            name,
            t0.elapsed()
        );
        Ok(Program {
            rt: Rc::clone(self),
            name: name.to_string(),
            spec: man.program(name)?.clone(),
            exe,
        })
    }

    // -- host<->device helpers ------------------------------------------------

    pub fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow!("upload f32{shape:?}: {e}"))
    }

    pub fn upload_i32(&self, data: &[i32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, shape, None)
            .map_err(|e| anyhow!("upload i32{shape:?}: {e}"))
    }

    pub fn upload_scalar(&self, v: f32) -> Result<xla::PjRtBuffer> {
        self.upload_f32(&[v], &[])
    }

    pub fn upload_tensor(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.upload_f32(&t.data, &t.shape)
    }
}

/// One compiled executable plus its manifest I/O spec.
pub struct Program {
    rt: Rc<Runtime>,
    pub name: String,
    pub spec: ProgramSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Decoded program outputs, aligned with `spec.outputs`.
pub struct Outputs {
    pub slots: Vec<IoSlot>,
    pub values: Vec<Vec<f32>>,
}

impl Outputs {
    pub fn by_name(&self, name: &str) -> Result<&[f32]> {
        self.slots
            .iter()
            .position(|s| s.name == name)
            .map(|i| self.values[i].as_slice())
            .ok_or_else(|| anyhow!("no output '{name}'"))
    }

    pub fn scalar(&self, name: &str) -> Result<f32> {
        let v = self.by_name(name)?;
        if v.len() != 1 {
            bail!("output '{name}' is not a scalar ({} elems)", v.len());
        }
        Ok(v[0])
    }
}

impl Program {
    /// Execute with pre-uploaded device buffers (hot path).
    pub fn execute_buffers(&self, inputs: &[&xla::PjRtBuffer]) -> Result<Outputs> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "program '{}' expects {} inputs, got {}",
                self.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let out = self
            .exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("executing '{}': {e}", self.name))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("downloading '{}' result: {e}", self.name))?;
        self.decode(tuple)
    }

    fn decode(&self, tuple: xla::Literal) -> Result<Outputs> {
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("decomposing '{}' tuple: {e}", self.name))?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "program '{}' returned {} outputs, manifest says {}",
                self.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut values = Vec::with_capacity(parts.len());
        for (lit, slot) in parts.into_iter().zip(self.spec.outputs.iter()) {
            let v: Vec<f32> = match slot.dtype {
                Dtype::F32 => lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output '{}': {e}", slot.name))?,
                Dtype::I32 => lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("output '{}': {e}", slot.name))?
                    .into_iter()
                    .map(|x| x as f32)
                    .collect(),
            };
            if v.len() != slot.numel() {
                bail!(
                    "output '{}' has {} elems, expected {}",
                    slot.name,
                    v.len(),
                    slot.numel()
                );
            }
            values.push(v);
        }
        Ok(Outputs { slots: self.spec.outputs.clone(), values })
    }

    pub fn runtime(&self) -> &Rc<Runtime> {
        &self.rt
    }
}

/// Lazy per-artifact program cache: an `Artifact` owns its manifest plus the
/// compiled executables, compiling each program on first use (fig-grid
/// experiments touch many artifacts but rarely all four programs of each).
pub struct Artifact {
    pub manifest: Manifest,
    rt: Rc<Runtime>,
    programs: std::cell::RefCell<BTreeMap<String, Rc<Program>>>,
}

impl Artifact {
    pub fn load(rt: &Rc<Runtime>, dir: &Path) -> Result<Artifact> {
        let manifest =
            Manifest::load(dir).with_context(|| format!("loading artifact {}", dir.display()))?;
        Ok(Artifact { manifest, rt: Rc::clone(rt), programs: Default::default() })
    }

    pub fn program(&self, name: &str) -> Result<Rc<Program>> {
        if let Some(p) = self.programs.borrow().get(name) {
            return Ok(Rc::clone(p));
        }
        let p = Rc::new(self.rt.load_program(&self.manifest, name)?);
        self.programs.borrow_mut().insert(name.to_string(), Rc::clone(&p));
        Ok(p)
    }

    pub fn runtime(&self) -> &Rc<Runtime> {
        &self.rt
    }
}
