//! Evaluation: the few-shot QA benchmark of paper §5.2.
//!
//! The paper evaluates medical-finetuned Llama-3 models on PubMedQA with a
//! 3-shot prompt (one yes / one no / one maybe example in arbitrary order)
//! and reports that FF training does not harm accuracy. Our substitute: a
//! synthetic 3-way cloze task over the medical token domain where the
//! answer is a deterministic function of the "symptom" tokens — scored the
//! same way (argmin candidate loss on the answer position).

pub mod qa;

pub use qa::{qa_accuracy, QaBenchmark, QaItem};
