//! Synthetic few-shot QA benchmark (§5.2 substitute for PubMedQA).
//!
//! Item structure (token ids): each *shot* is `BOS? s₁ … s_k SEP answer`,
//! shots concatenated; the query repeats the pattern and the candidate
//! answer occupies the final position. The ground-truth answer is
//! `(Σ symptom ids) mod 3 → {yes, no, maybe}` — deterministic, so a model
//! that reads the context can in principle learn it, and two equally good
//! models (regular vs FF) score equivalently, which is the claim under
//! test.

use crate::data::corpus::Example;
use crate::data::vocab::{self, Vocab};
use crate::util::rng::Rng;

pub const ANSWERS: [i32; 3] = [vocab::ANS_YES, vocab::ANS_NO, vocab::ANS_MAYBE];

#[derive(Debug, Clone)]
pub struct QaItem {
    /// Prefix tokens: 3 shots + query symptoms + SEP.
    pub prefix: Vec<i32>,
    /// Index into ANSWERS of the true answer.
    pub truth: usize,
}

#[derive(Debug, Clone)]
pub struct QaBenchmark {
    pub items: Vec<QaItem>,
    pub seq_len: usize,
}

fn answer_of(symptoms: &[i32]) -> usize {
    (symptoms.iter().map(|&t| t as u64).sum::<u64>() % 3) as usize
}

fn gen_symptoms(v: &Vocab, rng: &mut Rng, len: usize) -> Vec<i32> {
    let dom = v.medical_domain();
    (0..len).map(|_| v.content(dom.start + rng.below(dom.len()))).collect()
}

impl QaBenchmark {
    /// Build `n` items. Every prompt carries one shot per answer class in
    /// shuffled order (the paper's protocol).
    pub fn generate(vocab_size: usize, seq_len: usize, n: usize, seed: u64) -> QaBenchmark {
        let v = Vocab::new(vocab_size);
        let mut rng = Rng::new(seed ^ 0x9a);
        let sym_len = 4;
        let mut items = Vec::with_capacity(n);
        while items.len() < n {
            // one exemplar per class, then shuffle
            let mut shots: Vec<(Vec<i32>, usize)> = Vec::new();
            for class in 0..3 {
                // rejection-sample symptoms whose answer == class
                loop {
                    let s = gen_symptoms(&v, &mut rng, sym_len);
                    if answer_of(&s) == class {
                        shots.push((s, class));
                        break;
                    }
                }
            }
            rng.shuffle(&mut shots);
            let query = gen_symptoms(&v, &mut rng, sym_len);
            let truth = answer_of(&query);
            let mut prefix = vec![vocab::BOS];
            for (s, class) in &shots {
                prefix.extend_from_slice(s);
                prefix.push(vocab::SEP);
                prefix.push(ANSWERS[*class]);
            }
            prefix.extend_from_slice(&query);
            prefix.push(vocab::SEP);
            if prefix.len() + 1 > seq_len + 1 {
                continue; // doesn't fit; regenerate (shouldn't happen at T≥64)
            }
            items.push(QaItem { prefix, truth });
        }
        QaBenchmark { items, seq_len }
    }

    /// Render (item, candidate) as a padded `Example` whose mask covers
    /// exactly the answer position.
    pub fn render(&self, item: &QaItem, candidate: usize) -> Example {
        let t = self.seq_len;
        let mut seq = item.prefix.clone();
        seq.push(ANSWERS[candidate]);
        let answer_target_pos = seq.len() - 2; // mask[i] governs seq[i+1]
        while seq.len() < t + 1 {
            seq.push(vocab::PAD);
        }
        seq.truncate(t + 1);
        let mut mask = vec![0.0f32; t];
        mask[answer_target_pos] = 1.0;
        Example { seq, mask }
    }
}

/// Score the benchmark with an arbitrary loss oracle (the experiment wires
/// this to the trainer's eval program): accuracy of argmin-loss candidates.
pub fn qa_accuracy(
    bench: &QaBenchmark,
    mut loss_of: impl FnMut(&Example) -> anyhow::Result<f32>,
) -> anyhow::Result<f64> {
    let mut correct = 0usize;
    for item in &bench.items {
        let mut best = (f32::INFINITY, 0usize);
        for cand in 0..ANSWERS.len() {
            let ex = bench.render(item, cand);
            let loss = loss_of(&ex)?;
            if loss < best.0 {
                best = (loss, cand);
            }
        }
        if best.1 == item.truth {
            correct += 1;
        }
    }
    Ok(correct as f64 / bench.items.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn items_fit_and_are_deterministic() {
        let a = QaBenchmark::generate(512, 64, 50, 1);
        let b = QaBenchmark::generate(512, 64, 50, 1);
        assert_eq!(a.items.len(), 50);
        for (x, y) in a.items.iter().zip(b.items.iter()) {
            assert_eq!(x.prefix, y.prefix);
            assert_eq!(x.truth, y.truth);
        }
        // roughly balanced classes
        let mut counts = [0usize; 3];
        for it in &a.items {
            counts[it.truth] += 1;
        }
        assert!(counts.iter().all(|&c| c > 5), "{counts:?}");
    }

    #[test]
    fn every_prompt_shows_all_three_answers() {
        let b = QaBenchmark::generate(512, 64, 20, 2);
        for it in &b.items {
            for ans in ANSWERS {
                assert!(it.prefix.contains(&ans), "missing answer {ans} in shot prompt");
            }
        }
    }

    #[test]
    fn render_masks_exactly_the_answer() {
        let b = QaBenchmark::generate(512, 64, 5, 3);
        let ex = b.render(&b.items[0], 1);
        assert_eq!(ex.seq.len(), 65);
        assert_eq!(ex.mask.iter().filter(|&&m| m > 0.0).count(), 1);
        let pos = ex.mask.iter().position(|&m| m > 0.0).unwrap();
        assert_eq!(ex.seq[pos + 1], ANSWERS[1]); // target at mask is the candidate
        assert_eq!(ex.seq[pos], vocab::SEP); // preceded by the query SEP
    }

    #[test]
    fn oracle_scoring_yields_perfect_accuracy() {
        // a loss oracle that knows the rule must score 100%
        let b = QaBenchmark::generate(512, 64, 30, 4);
        let acc = qa_accuracy(&b, |ex| {
            let pos = ex.mask.iter().position(|&m| m > 0.0).unwrap();
            let cand = ex.seq[pos + 1];
            // recover query symptoms: the sym_len tokens before final SEP
            let sym = &ex.seq[pos - 4..pos];
            let truth = ANSWERS[(sym.iter().map(|&t| t as u64).sum::<u64>() % 3) as usize];
            Ok(if cand == truth { 0.0 } else { 1.0 })
        })
        .unwrap();
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn random_guessing_scores_near_third() {
        let b = QaBenchmark::generate(512, 64, 300, 5);
        let mut rng = Rng::new(9);
        let acc = qa_accuracy(&b, |_| Ok(rng.next_f32())).unwrap();
        assert!((acc - 1.0 / 3.0).abs() < 0.12, "acc {acc}");
    }
}
