//! The per-submission lifecycle state machine of the run queue,
//! extracted **pure**: no locks, no condvars, no I/O, no xla — just the
//! transitions and the invariants they carry. `crate::sched::queue`
//! consumes this module for every handle-state change (the queue's
//! mutexes/condvars stay where they are; what moved here is the state
//! *logic* those locks protect), and `rust/tests/lifecycle_model.rs`
//! model-checks the same type exhaustively over bounded interleavings.
//!
//! # The state machine
//!
//! ```text
//!            try_claim            finish(outcome)
//!   Queued ────────────► Running ───────────────► Finished(Some)
//!     ▲                  ▲  │                          │
//!     │        try_claim │  │ park                     │ take_outcome
//!     └─ (submit)        │  ▼                          ▼
//!                        Parked                   Finished(None)
//! ```
//!
//! Three invariants are load-bearing for the queue's serving contracts
//! (`docs/queue-serving.md`):
//!
//! * **Claim exclusivity.** [`Lifecycle::try_claim`] is the *only* way
//!   into `Running`, and it fails on anything already `Running` or
//!   `Finished`. Workers popping the queue, pack leaders claiming
//!   siblings, `cancel()`'s transient claim, and queue-drop cleanup all
//!   race through this one transition, so each submission is owned by
//!   exactly one of them no matter the interleaving.
//! * **Terminal gate.** [`Lifecycle::finish`] asserts (in release —
//!   these are contract-bearing checks, see `docs/static-analysis.md`)
//!   that the submission was `Running`: every terminal path first wins
//!   the claim, so a submission finishes exactly once.
//! * **Exactly-once delivery.** The outcome sits in an `Option` slot;
//!   [`Lifecycle::take_outcome`] moves it out. Whichever of `join` /
//!   the completions stream asks first gets it, the other provably
//!   cannot.
//!
//! The [`model`] submodule is a pure replica of the queue's *scheduling*
//! protocol (ready list, worker condvar, terminal gate ordering,
//! cancel/park/pack races) built on this same `Lifecycle` type, small
//! enough for exhaustive interleaving exploration.

use std::fmt;

/// How a finished submission ended. `Cancelled(None)` = cancelled before
/// it ever started (nothing was constructed); `Cancelled(Some)` = a
/// running job honored the cooperative flag and returned partial output.
pub enum Outcome<R> {
    Done(R),
    Cancelled(Option<R>),
    Failed(anyhow::Error),
}

/// Which non-terminal state a successful [`Lifecycle::try_claim`] left.
/// Queue-drop cleanup branches on this: a claimed `Queued` submission is
/// cancelled (it never ran), a claimed `Parked` one is *failed* loudly
/// (its checkpointed progress is discarded — never silently).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClaimedFrom {
    Queued,
    Parked,
}

/// Observable phase of a submission ([`Lifecycle::phase`]) — the pure
/// core of `RunPoll`, with delivery made explicit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Queued,
    Running,
    Parked,
    Done,
    Cancelled,
    Failed,
    /// Terminal and already delivered (`join` or the completions stream
    /// took the outcome).
    Delivered,
}

enum State<R> {
    Queued,
    Running,
    Parked,
    Finished(Option<Outcome<R>>),
}

/// One submission's lifecycle. Opaque on purpose: the queue cannot write
/// a state directly — every change goes through a transition method that
/// carries its invariant.
pub struct Lifecycle<R> {
    state: State<R>,
}

impl<R> Default for Lifecycle<R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<R> Lifecycle<R> {
    /// A fresh submission: `Queued`.
    pub fn new() -> Self {
        Lifecycle { state: State::Queued }
    }

    /// The exclusivity transition: `Queued | Parked → Running`. Returns
    /// where the claim came from, or `None` if someone else already owns
    /// the submission (`Running`) or it is already terminal
    /// (`Finished`). Every executor — worker pop, pack leader, cancel's
    /// transient claim, queue-drop cleanup — must win this transition
    /// before touching the submission.
    pub fn try_claim(&mut self) -> Option<ClaimedFrom> {
        match self.state {
            State::Queued => {
                self.state = State::Running;
                Some(ClaimedFrom::Queued)
            }
            State::Parked => {
                self.state = State::Running;
                Some(ClaimedFrom::Parked)
            }
            State::Running | State::Finished(_) => None,
        }
    }

    /// Pack-leader variant of [`Lifecycle::try_claim`]: claims only a
    /// still-`Queued` submission (a parked submission is an interrupted
    /// run mid-resume — a group leader must never swallow one).
    pub fn try_claim_queued(&mut self) -> bool {
        match self.state {
            State::Queued => {
                self.state = State::Running;
                true
            }
            _ => false,
        }
    }

    /// The terminal gate: `Running → Finished(Some(outcome))`. Hard
    /// assert (not `debug_assert!` — this is the exactly-once-completion
    /// contract, it must hold in release): the caller must have won the
    /// claim first, so two paths can never both finish one submission.
    pub fn finish(&mut self, outcome: Outcome<R>) {
        assert!(
            matches!(self.state, State::Running),
            "lifecycle: finish() from {:?} — every terminal path must claim Running first \
             (exactly-once completion gate, docs/queue-serving.md)",
            self.phase()
        );
        self.state = State::Finished(Some(outcome));
    }

    /// `Running → Parked`: the job checkpointed at a step boundary and
    /// re-enters the queue to resume later. Hard assert for the same
    /// reason as [`Lifecycle::finish`]: only the current owner may park.
    pub fn park(&mut self) {
        assert!(
            matches!(self.state, State::Running),
            "lifecycle: park() from {:?} — only the claiming owner may park",
            self.phase()
        );
        self.state = State::Parked;
    }

    /// Move the outcome out — the exactly-once delivery token. `None`
    /// when not yet finished *or* when the other delivery surface
    /// (`join` vs the completions stream) already took it.
    pub fn take_outcome(&mut self) -> Option<Outcome<R>> {
        match &mut self.state {
            State::Finished(slot) => slot.take(),
            _ => None,
        }
    }

    /// Terminal (whether or not the outcome was already delivered).
    pub fn is_finished(&self) -> bool {
        matches!(self.state, State::Finished(_))
    }

    /// Observable phase (pure core of the queue's `RunPoll`).
    pub fn phase(&self) -> Phase {
        match &self.state {
            State::Queued => Phase::Queued,
            State::Running => Phase::Running,
            State::Parked => Phase::Parked,
            State::Finished(Some(Outcome::Done(_))) => Phase::Done,
            State::Finished(Some(Outcome::Cancelled(_))) => Phase::Cancelled,
            State::Finished(Some(Outcome::Failed(_))) => Phase::Failed,
            State::Finished(None) => Phase::Delivered,
        }
    }
}

impl<R> fmt::Debug for Lifecycle<R> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Lifecycle({:?})", self.phase())
    }
}

impl<R: Clone> Lifecycle<R> {
    /// Model-checker support: duplicate this lifecycle *including* its
    /// undelivered outcome slot. Deliberately not a `Clone` impl — a
    /// forked lifecycle duplicates the exactly-once delivery token,
    /// which is only sound when exploring hypothetical futures of a
    /// model state (each branch is its own world). `Failed` errors are
    /// re-wrapped by message.
    pub fn fork(&self) -> Lifecycle<R> {
        let state = match &self.state {
            State::Queued => State::Queued,
            State::Running => State::Running,
            State::Parked => State::Parked,
            State::Finished(slot) => State::Finished(slot.as_ref().map(|o| match o {
                Outcome::Done(r) => Outcome::Done(r.clone()),
                Outcome::Cancelled(r) => Outcome::Cancelled(r.clone()),
                Outcome::Failed(e) => Outcome::Failed(anyhow::anyhow!("{e:#}")),
            })),
        };
        Lifecycle { state }
    }
}

pub mod model {
    //! A pure, deterministic replica of the run queue's scheduling
    //! protocol, built on the real [`Lifecycle`] type, for exhaustive
    //! interleaving exploration (`rust/tests/lifecycle_model.rs`).
    //!
    //! Each [`Action`] is one lock-atomic region of
    //! `crate::sched::queue`: `Submit` is `try_submit_inner`'s
    //! enqueue+notify, `Pop` is `worker_loop`+`take_next`+`run_entry`'s
    //! claim (including husk reaping and the fall-asleep-when-empty
    //! decision, which the real code makes while *holding* the state
    //! lock — that atomicity is exactly what makes the condvar protocol
    //! lose no wakeups, and the model mirrors it), `Step` is one
    //! trainer step boundary with its cancel-then-park check order,
    //! `Cancel` is `RunHandle::cancel`'s flag+transient-claim,
    //! `ClaimMate` is a pack leader's `Queued → Running` sibling claim,
    //! `Feed` is `StreamHandle::finish`'s publish-remaining-data +
    //! held-continuation re-enqueue (a streaming submission's
    //! data-starved slot parks itself *off* the ready list —
    //! `JobYield::Held` — and only a feed brings it back), and the
    //! terminal gate (`finish_handle`) — publish outcome, decrement
    //! `live`, feed the completions stream — runs as one unit because
    //! the real code funnels every terminal path through that single
    //! function.
    //!
    //! Scope: the worker condvar (`Shared::cv`) and its wakeup tokens
    //! are modeled; the delivery-side condvars (`done_cv`, `space_cv`)
    //! are not — model consumers poll. The queue's admission layer
    //! (capacity/quota/rate windows) and shutdown path (including the
    //! drop-drain of held streaming continuations) are out of scope;
    //! they sit in front of / behind the state machine modeled here and
    //! are covered by the unit tests in `queue.rs`.

    use std::collections::VecDeque;

    use super::{ClaimedFrom, Lifecycle, Outcome, Phase};

    /// One bounded scenario to explore. `steps[i]` is submission `i`'s
    /// job length in step-boundaries; the one-shot lists name which
    /// environment actions exist at all (each may fire at any point of
    /// the interleaving, once).
    #[derive(Clone, Default)]
    pub struct Config {
        pub workers: usize,
        /// Steps per submission (each ≥ 1).
        pub steps: Vec<u8>,
        /// Submissions the environment may `cancel()` (one-shot each).
        pub cancels: Vec<usize>,
        /// Submissions the environment may park-request (one-shot each).
        pub parks: Vec<usize>,
        /// Submissions a joiner may take directly (one-shot each); all
        /// other deliveries go through the completions stream.
        pub joins: Vec<usize>,
        /// Submissions eligible for pack-claiming: a worker already
        /// running one of these may claim another still-`Queued` one as
        /// a group mate (publishing its outcome at the group end).
        pub packables: Vec<usize>,
        /// Streaming submissions (`RunQueue::submit_stream`): they start
        /// data-starved — the first slot to claim one parks it *off* the
        /// ready list (`JobYield::Held`) — and stay held until the
        /// environment `Feed`s them (one-shot each, modeling
        /// `StreamHandle::finish` closing the stream).
        pub streams: Vec<usize>,
        /// Property-test mode: start with every worker already claiming
        /// its same-indexed submission and expose **only** `Step`
        /// actions (workers retire after their run, no deliveries).
        /// Schedule counts are then a pure multinomial — the exact
        /// expected-count oracle for the explorer.
        pub pure_steps: bool,
        /// Seeded bug for the checker's self-test: check the park flag
        /// *before* the cancel flag at step boundaries (the real code
        /// checks cancel first — `Trainer::park_due` docs and
        /// `repark_entry`). The explorer must catch this.
        pub buggy_park_before_cancel: bool,
    }

    /// One interleaving step. The explorer enumerates these in a fixed
    /// deterministic order, so traces are reproducible by construction.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub enum Action {
        /// Admit submission `i`: enqueue + `live += 1` + notify a worker.
        Submit(usize),
        /// Sleeping worker `w` consumes a pending notify.
        Wake(usize),
        /// Idle worker `w` pops the ready list: reap husks, claim the
        /// first claimable entry, or fall asleep if nothing is left.
        Pop(usize),
        /// Worker `w` reaches its running job's next step boundary.
        Step(usize),
        /// Worker `w` (running a packable leader) pack-claims queued
        /// submission `mate`.
        ClaimMate { worker: usize, mate: usize },
        /// Environment cancels submission `i` (flag + transient claim).
        Cancel(usize),
        /// Environment asks submission `i` to park at its next boundary.
        ParkRequest(usize),
        /// Tenant closes streaming submission `i`'s stream
        /// (`StreamHandle::finish`): all remaining data arrives and, if
        /// the continuation is held data-starved off the ready list, it
        /// re-enters the ready list + notify — even as a terminal husk
        /// (a cancel raced the hold; `Pop` reaps it).
        Feed(usize),
        /// Consumer pops the completions stream once.
        DeliverStream,
        /// Joiner takes submission `i`'s outcome directly.
        Join(usize),
    }

    /// An invariant the interleaving broke, with the witness state.
    #[derive(Debug, Clone, PartialEq, Eq)]
    pub enum Violation {
        /// `live` stopped equaling the number of admitted-and-unfinished
        /// submissions.
        LiveCountMismatch { live: usize, unfinished: usize },
        /// An outcome was delivered twice.
        DoubleDelivery { sub: usize },
        /// A submission sits `Parked` with its cancel flag raised — the
        /// park beat the cancel (the real ordering checks cancel first,
        /// so a cancelled run never re-enters the queue).
        ParkBeatCancel { sub: usize },
        /// Two executors own the same submission.
        ClaimOverlap { sub: usize },
        /// A worker owns a submission that is not `Running`.
        OwnerStateMismatch { sub: usize, phase: Phase },
        /// A submission sits in the held (data-starved) set without
        /// being `Parked` or a terminal husk — the hold published the
        /// continuation before parking the handle, so a racing feed
        /// could re-enqueue a still-`Running` entry whose claim then
        /// fails and strands the joiner.
        HeldNotParked { sub: usize, phase: Phase },
    }

    #[derive(Clone, Debug, PartialEq, Eq)]
    enum Worker {
        Idle,
        Asleep,
        Run { sub: usize, mates: Vec<usize> },
    }

    struct Sub {
        life: Lifecycle<u32>,
        submitted: bool,
        cancel: bool,
        park_req: bool,
        steps_left: u8,
        /// Streaming submission (config-constant).
        streaming: bool,
        /// Its stream was closed (`Feed` fired) — data is no longer
        /// starved.
        fed: bool,
        /// Continuation parked off the ready list in `Shared::streams`.
        held: bool,
    }

    impl Sub {
        fn fork(&self) -> Sub {
            Sub {
                life: self.life.fork(),
                submitted: self.submitted,
                cancel: self.cancel,
                park_req: self.park_req,
                steps_left: self.steps_left,
                streaming: self.streaming,
                fed: self.fed,
                held: self.held,
            }
        }
    }

    /// The explorable queue state. Build one per [`Config`], enumerate
    /// [`QueueModel::enabled`] actions, [`QueueModel::apply`] them on
    /// [`QueueModel::fork`]s of the state, and recurse.
    pub struct QueueModel {
        subs: Vec<Sub>,
        ready: VecDeque<usize>,
        live: usize,
        done: VecDeque<usize>,
        workers: Vec<Worker>,
        /// Pending worker-condvar notify tokens (`Shared::cv`).
        notifies: usize,
        delivered: Vec<u8>,
        cancels_left: Vec<bool>,
        parks_left: Vec<bool>,
        joins_left: Vec<bool>,
        feeds_left: Vec<bool>,
    }

    impl QueueModel {
        pub fn new(cfg: &Config) -> QueueModel {
            let n = cfg.steps.len();
            assert!(
                cfg.streams.iter().all(|s| !cfg.packables.contains(s)),
                "streaming submissions are never packable (submit_stream has no pack variant)"
            );
            let mut m = QueueModel {
                subs: cfg
                    .steps
                    .iter()
                    .enumerate()
                    .map(|(i, &s)| Sub {
                        life: Lifecycle::new(),
                        submitted: false,
                        cancel: false,
                        park_req: false,
                        steps_left: s.max(1),
                        streaming: cfg.streams.contains(&i),
                        fed: false,
                        held: false,
                    })
                    .collect(),
                ready: VecDeque::new(),
                live: 0,
                done: VecDeque::new(),
                workers: vec![Worker::Idle; cfg.workers],
                notifies: 0,
                delivered: vec![0; n],
                cancels_left: (0..n).map(|i| cfg.cancels.contains(&i)).collect(),
                parks_left: (0..n).map(|i| cfg.parks.contains(&i)).collect(),
                joins_left: (0..n).map(|i| cfg.joins.contains(&i)).collect(),
                feeds_left: (0..n).map(|i| cfg.streams.contains(&i)).collect(),
            };
            if cfg.pure_steps {
                assert_eq!(cfg.workers, n, "pure_steps pre-claims sub w on worker w");
                assert!(cfg.streams.is_empty(), "pure_steps exposes Step actions only");
                for w in 0..n {
                    // Reach the pre-claimed state through the real
                    // transitions, not by writing states directly.
                    m.subs[w].submitted = true;
                    m.live += 1;
                    assert_eq!(m.subs[w].life.try_claim(), Some(ClaimedFrom::Queued));
                    m.workers[w] = Worker::Run { sub: w, mates: Vec::new() };
                }
            }
            m
        }

        pub fn fork(&self) -> QueueModel {
            QueueModel {
                subs: self.subs.iter().map(Sub::fork).collect(),
                ready: self.ready.clone(),
                live: self.live,
                done: self.done.clone(),
                workers: self.workers.clone(),
                notifies: self.notifies,
                delivered: self.delivered.clone(),
                cancels_left: self.cancels_left.clone(),
                parks_left: self.parks_left.clone(),
                joins_left: self.joins_left.clone(),
                feeds_left: self.feeds_left.clone(),
            }
        }

        /// Every action currently enabled, in a fixed deterministic
        /// order (the explorer's branch order).
        pub fn enabled(&self, cfg: &Config) -> Vec<Action> {
            let mut out = Vec::new();
            if cfg.pure_steps {
                for (w, worker) in self.workers.iter().enumerate() {
                    if matches!(worker, Worker::Run { .. }) {
                        out.push(Action::Step(w));
                    }
                }
                return out;
            }
            for (i, s) in self.subs.iter().enumerate() {
                if !s.submitted {
                    out.push(Action::Submit(i));
                }
            }
            for (w, worker) in self.workers.iter().enumerate() {
                match worker {
                    Worker::Asleep if self.notifies > 0 => out.push(Action::Wake(w)),
                    Worker::Idle => out.push(Action::Pop(w)),
                    Worker::Run { sub, .. } => {
                        out.push(Action::Step(w));
                        if cfg.packables.contains(sub) {
                            for &j in &cfg.packables {
                                if j != *sub
                                    && self.subs[j].submitted
                                    && self.subs[j].life.phase() == Phase::Queued
                                {
                                    out.push(Action::ClaimMate { worker: w, mate: j });
                                }
                            }
                        }
                    }
                    _ => {}
                }
            }
            for (i, s) in self.subs.iter().enumerate() {
                if self.cancels_left[i] && s.submitted {
                    out.push(Action::Cancel(i));
                }
                if self.parks_left[i] && s.submitted && !s.life.is_finished() {
                    out.push(Action::ParkRequest(i));
                }
                if self.joins_left[i] && s.life.is_finished() {
                    out.push(Action::Join(i));
                }
                if self.feeds_left[i] && s.submitted {
                    out.push(Action::Feed(i));
                }
            }
            if !self.done.is_empty() {
                out.push(Action::DeliverStream);
            }
            out
        }

        /// The terminal gate, mirroring `finish_handle`: publish the
        /// outcome (asserting the claim was won — the real release
        /// assert in [`Lifecycle::finish`] fires right here if a model
        /// path forgets to claim), decrement `live`, feed the stream.
        fn gate(&mut self, i: usize, outcome: Outcome<u32>) {
            self.subs[i].life.finish(outcome);
            self.live -= 1;
            self.done.push_back(i);
        }

        /// Apply one action; `Err` when an invariant broke.
        pub fn apply(&mut self, cfg: &Config, action: Action) -> Result<(), Violation> {
            match action {
                Action::Submit(i) => {
                    self.subs[i].submitted = true;
                    self.ready.push_back(i);
                    self.live += 1;
                    self.notifies += 1; // cv.notify_one
                }
                Action::Wake(w) => {
                    self.workers[w] = Worker::Idle;
                    self.notifies -= 1;
                }
                Action::Pop(w) => loop {
                    match self.ready.pop_front() {
                        None => {
                            // take_next returned None while holding the
                            // state lock: the wait is atomic with the
                            // emptiness check (no sleep/notify race).
                            self.workers[w] = Worker::Asleep;
                            break;
                        }
                        Some(j) => {
                            if self.subs[j].life.try_claim().is_some() {
                                self.workers[w] = Worker::Run { sub: j, mates: Vec::new() };
                                break;
                            }
                            // husk (cancelled while queued, or claimed
                            // by a pack leader): reap, keep looking —
                            // same loop as take_next/run_entry.
                        }
                    }
                },
                Action::Step(w) => {
                    let (sub, mates) = match &self.workers[w] {
                        Worker::Run { sub, mates } => (*sub, mates.clone()),
                        other => unreachable!("Step on non-running worker {other:?}"),
                    };
                    if !mates.is_empty() {
                        // In-flight batched group: no per-step cancel or
                        // park point — members run to the group end and
                        // finish Done (cancel lands at the batch
                        // boundary, docs/queue-serving.md).
                        self.subs[sub].steps_left -= 1;
                        if self.subs[sub].steps_left == 0 {
                            self.gate(sub, Outcome::Done(sub as u32));
                            for m in mates {
                                self.gate(m, Outcome::Done(m as u32));
                            }
                            self.workers[w] = Worker::Idle;
                        }
                    } else if self.subs[sub].streaming && !self.subs[sub].fed {
                        // run_stream_slot's data-starved hold: park the
                        // handle *first* (the order whose inversion is
                        // the HeldNotParked bug), move the continuation
                        // off the ready list into the held set, and let
                        // run_entry's Held arm reap a cancel that raced
                        // the hold (the claim comes from Parked; no
                        // output exists yet, so it ends Cancelled(None)).
                        self.subs[sub].life.park();
                        self.subs[sub].held = true;
                        if self.subs[sub].cancel {
                            self.subs[sub].held = false;
                            assert_eq!(
                                self.subs[sub].life.try_claim(),
                                Some(ClaimedFrom::Parked)
                            );
                            self.gate(sub, Outcome::Cancelled(None));
                        }
                        self.workers[w] = Worker::Idle;
                    } else {
                        let s = &self.subs[sub];
                        let (cancel_now, park_now) = if cfg.buggy_park_before_cancel {
                            (s.cancel && !s.park_req, s.park_req)
                        } else {
                            // The real order: cancellation wins over
                            // parking (Trainer::park_due + repark_entry).
                            (s.cancel, s.park_req && !s.cancel)
                        };
                        if cancel_now {
                            self.gate(sub, Outcome::Cancelled(Some(sub as u32)));
                            self.workers[w] = Worker::Idle;
                        } else if park_now {
                            // repark_entry: publish Parked, re-queue the
                            // continuation, notify a worker. The park
                            // flag is consumed (Trainer::park_due swaps
                            // it off) so the next slot starts clean.
                            self.subs[sub].park_req = false;
                            self.subs[sub].life.park();
                            self.ready.push_back(sub);
                            self.notifies += 1;
                            self.workers[w] = Worker::Idle;
                        } else {
                            self.subs[sub].steps_left -= 1;
                            if self.subs[sub].steps_left == 0 {
                                self.gate(sub, Outcome::Done(sub as u32));
                                self.workers[w] = if cfg.pure_steps {
                                    Worker::Asleep // retire: property mode
                                } else {
                                    Worker::Idle
                                };
                            }
                        }
                    }
                }
                Action::ClaimMate { worker, mate } => {
                    // The pack leader's claim is the same Queued→Running
                    // transition the workers make, so each submission is
                    // owned exactly once no matter which side wins.
                    if self.subs[mate].life.try_claim_queued() {
                        match &mut self.workers[worker] {
                            Worker::Run { mates, .. } => mates.push(mate),
                            other => unreachable!("ClaimMate on {other:?}"),
                        }
                        // The mate's ready entry stays behind as a husk
                        // (Pop reaps it), exactly like the real pool.
                    }
                }
                Action::Cancel(i) => {
                    self.cancels_left[i] = false;
                    self.subs[i].cancel = true;
                    // RunHandle::cancel: transient claim — a queued or
                    // parked submission finishes Cancelled immediately;
                    // a running one keeps only the cooperative flag.
                    if self.subs[i].life.try_claim().is_some() {
                        self.gate(i, Outcome::Cancelled(None));
                    }
                }
                Action::ParkRequest(i) => {
                    self.parks_left[i] = false;
                    self.subs[i].park_req = true;
                }
                Action::Feed(i) => {
                    self.feeds_left[i] = false;
                    self.subs[i].fed = true;
                    // StreamHandle::finish: under the feed lock, a held
                    // continuation is removed from Shared::streams and
                    // re-enqueued + notify. This includes a terminal
                    // husk (cancel's transient claim beat the feed; the
                    // entry stayed behind in the map) — Pop's claim
                    // fails on it and reaps, exactly like the real path.
                    if self.subs[i].held {
                        self.subs[i].held = false;
                        self.ready.push_back(i);
                        self.notifies += 1;
                    }
                }
                Action::DeliverStream => {
                    let h = self.done.pop_front().expect("enabled() checked");
                    // claim_completion: None = a join got there first —
                    // the stream skips the husk.
                    if self.subs[h].life.take_outcome().is_some() {
                        self.delivered[h] += 1;
                    }
                }
                Action::Join(i) => {
                    self.joins_left[i] = false;
                    // None = the stream already delivered it: join's
                    // loud-error path, not a second delivery.
                    if self.subs[i].life.take_outcome().is_some() {
                        self.delivered[i] += 1;
                    }
                }
            }
            self.check()
        }

        /// Invariants that must hold after **every** action.
        fn check(&self) -> Result<(), Violation> {
            let unfinished = self
                .subs
                .iter()
                .filter(|s| s.submitted && !s.life.is_finished())
                .count();
            if self.live != unfinished {
                return Err(Violation::LiveCountMismatch { live: self.live, unfinished });
            }
            for (i, &d) in self.delivered.iter().enumerate() {
                if d > 1 {
                    return Err(Violation::DoubleDelivery { sub: i });
                }
            }
            for (i, s) in self.subs.iter().enumerate() {
                if s.life.phase() == Phase::Parked && s.cancel {
                    return Err(Violation::ParkBeatCancel { sub: i });
                }
                if s.held {
                    let phase = s.life.phase();
                    if phase != Phase::Parked && !s.life.is_finished() {
                        return Err(Violation::HeldNotParked { sub: i, phase });
                    }
                }
            }
            let mut owned = vec![false; self.subs.len()];
            for worker in &self.workers {
                if let Worker::Run { sub, mates } = worker {
                    for &j in std::iter::once(sub).chain(mates) {
                        if owned[j] {
                            return Err(Violation::ClaimOverlap { sub: j });
                        }
                        owned[j] = true;
                        let phase = self.subs[j].life.phase();
                        if phase != Phase::Running {
                            return Err(Violation::OwnerStateMismatch { sub: j, phase });
                        }
                    }
                }
            }
            Ok(())
        }

        /// A schedule is complete when every submission reached a
        /// terminal state, the stream is drained, and (full mode) every
        /// outcome was delivered exactly once.
        pub fn is_complete(&self, cfg: &Config) -> bool {
            let all_finished =
                self.subs.iter().all(|s| s.submitted && s.life.is_finished());
            if cfg.pure_steps {
                return all_finished;
            }
            all_finished
                && self.done.is_empty()
                && self.delivered.iter().all(|&d| d == 1)
        }

        /// Deterministic, collision-free byte encoding of the state —
        /// the explorer's memoization key.
        pub fn encode(&self) -> Vec<u8> {
            let mut out = Vec::with_capacity(16 + 4 * self.subs.len());
            for s in &self.subs {
                out.push(s.life.phase() as u8);
                out.push(
                    (s.submitted as u8)
                        | (s.cancel as u8) << 1
                        | (s.park_req as u8) << 2
                        | (s.fed as u8) << 3
                        | (s.held as u8) << 4,
                );
                out.push(s.steps_left);
            }
            out.push(0xFE);
            out.extend(self.ready.iter().map(|&i| i as u8));
            out.push(0xFE);
            out.extend(self.done.iter().map(|&i| i as u8));
            out.push(0xFE);
            for w in &self.workers {
                match w {
                    Worker::Idle => out.push(0xF0),
                    Worker::Asleep => out.push(0xF1),
                    Worker::Run { sub, mates } => {
                        out.push(0xF2);
                        out.push(*sub as u8);
                        out.push(mates.len() as u8);
                        out.extend(mates.iter().map(|&m| m as u8));
                    }
                }
            }
            out.push(self.notifies as u8);
            out.extend(self.delivered.iter().copied());
            let pack_bools = |v: &[bool]| -> u8 {
                v.iter().enumerate().fold(0u8, |acc, (i, &b)| acc | ((b as u8) << i))
            };
            out.push(pack_bools(&self.cancels_left));
            out.push(pack_bools(&self.parks_left));
            out.push(pack_bools(&self.joins_left));
            out.push(pack_bools(&self.feeds_left));
            out
        }

        /// How many deliveries each submission received (test support).
        pub fn delivered(&self) -> &[u8] {
            &self.delivered
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn done() -> Outcome<u32> {
        Outcome::Done(7)
    }

    #[test]
    fn claim_is_exclusive_and_single_winner() {
        let mut l: Lifecycle<u32> = Lifecycle::new();
        assert_eq!(l.phase(), Phase::Queued);
        assert_eq!(l.try_claim(), Some(ClaimedFrom::Queued));
        // the racing second claimant must lose
        assert_eq!(l.try_claim(), None);
        assert!(!l.try_claim_queued());
        l.finish(done());
        assert_eq!(l.try_claim(), None, "terminal states are never claimable");
    }

    #[test]
    fn park_resume_claims_report_parked_origin() {
        let mut l: Lifecycle<u32> = Lifecycle::new();
        assert_eq!(l.try_claim(), Some(ClaimedFrom::Queued));
        l.park();
        assert_eq!(l.phase(), Phase::Parked);
        assert!(!l.try_claim_queued(), "pack leaders must not claim parked runs");
        assert_eq!(l.try_claim(), Some(ClaimedFrom::Parked));
    }

    #[test]
    fn outcome_is_delivered_exactly_once() {
        let mut l: Lifecycle<u32> = Lifecycle::new();
        assert!(l.take_outcome().is_none(), "nothing to deliver while queued");
        l.try_claim().unwrap();
        l.finish(Outcome::Cancelled(None));
        assert_eq!(l.phase(), Phase::Cancelled);
        assert!(l.take_outcome().is_some());
        assert!(l.take_outcome().is_none(), "second delivery must be impossible");
        assert_eq!(l.phase(), Phase::Delivered);
        assert!(l.is_finished());
    }

    #[test]
    #[should_panic(expected = "finish() from Queued")]
    fn finishing_without_a_claim_panics_in_release_too() {
        let mut l: Lifecycle<u32> = Lifecycle::new();
        l.finish(done()); // no claim: the terminal gate must refuse
    }

    #[test]
    #[should_panic(expected = "park() from Parked")]
    fn double_park_panics() {
        let mut l: Lifecycle<u32> = Lifecycle::new();
        l.try_claim().unwrap();
        l.park();
        l.park();
    }

    #[test]
    fn fork_duplicates_the_delivery_token_for_model_branches() {
        let mut l: Lifecycle<u32> = Lifecycle::new();
        l.try_claim().unwrap();
        l.finish(done());
        let mut a = l.fork();
        let mut b = l.fork();
        assert!(a.take_outcome().is_some());
        assert!(b.take_outcome().is_some(), "each branch is its own world");
    }
}
