//! Concurrent run scheduler: execute many independent training runs on a
//! bounded pool of host threads, against one shared [`Runtime`].
//!
//! The paper's protocol (§4, Figs 2/7/12) is an embarrassingly parallel
//! grid of (model, task, FF-on/off, rank, seed) cells, and low-rank
//! training is dispatch/overhead-bound at small ranks ("Run LoRA Run",
//! "LoRA Is Slower Than You Think") — wall-clock wins come from keeping
//! more independent runs in flight, not from bigger kernels. This module
//! is the fan-out layer the figure harnesses and the `--jobs N` CLI use.
//!
//! # Ownership rules (see `docs/transfer-contract.md` §5)
//!
//! Shared **read-only** across workers:
//! * the `Arc<Runtime>` (PJRT client + atomic
//!   [`TransferStats`](crate::runtime::TransferStats) meters),
//! * compiled `Arc<Program>`s via each artifact's lock-guarded cache
//!   ([`ArtifactCache`] shares one `Arc<Artifact>` per key),
//! * the pretrained `W0` value map (`Arc<BTreeMap<String, Tensor>>`).
//!
//! Owned **per run**, created and dropped on the worker thread that drives
//! the run: the `Trainer` and its `StepEngine`, every `ParamSet`, the
//! `ExecStream` readback ring, the `BatchStager` double buffer, eval
//! caches, and all device buffers. Nothing device-resident ever crosses
//! between runs, which is why same-seed runs are bit-identical at any
//! `--jobs` level: each run's dispatch sequence is independent of how many
//! sibling runs happen to be in flight.
//!
//! # Determinism
//!
//! [`WorkerPool::scatter`] pops work from a shared queue (completion order
//! is whatever the OS scheduler does) but stores every result in its
//! **submission slot** — callers always get results back in submission
//! order, and `--jobs 1` vs `--jobs N` produce identical result vectors
//! for deterministic jobs. `rust/tests/sched_pool.rs` asserts the losses
//! are bit-identical and the shared transfer meters tally exactly.
//!
//! # Thread-safety gate (`xla-shared-client` feature)
//!
//! Sharing one PJRT client and its executables across host threads needs
//! `unsafe impl Send/Sync` on `Runtime`/`Program` (see the SAFETY
//! comments in `crate::runtime`), and those impls are only sound against
//! an xla-rs revision whose wrappers hold refcount-free handles — which
//! the floating dependency cannot guarantee. Both the impls and the
//! thread spawn below are therefore compiled out unless the crate is
//! built with `--features xla-shared-client` (requires a pinned, audited
//! rev — see `rust/XLA_AUDIT` and `ci/check_xla_audit.sh`). Without the
//! feature, [`threads_enabled`] is `false`, [`WorkerPool::new`] clamps to
//! one effective worker, and every batch runs inline in submission order:
//! the results, reports, and determinism contract are identical — only
//! the wall-clock overlap is lost.

pub mod lifecycle;
pub mod queue;
pub mod shard;

use std::collections::BTreeMap;
#[cfg(feature = "xla-shared-client")]
use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

#[cfg(any(test, feature = "xla-shared-client"))]
use anyhow::bail;
use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::ff::controller::FfStageStats;
use crate::metrics::StepKind;
use crate::model::tensor::Tensor;
use crate::runtime::{Artifact, Runtime, StreamStats, TransferSnapshot};
use crate::store::ArtifactStore;
use crate::train::checkpoint::ParkState;
use crate::train::trainer::{RunSummary, StopRule, Trainer};

pub use queue::{
    join_all, CancelToken, Completion, RunHandle, RunPoll, RunQueue, RunResult, StreamHandle,
    SubmitError, TenantQuota, TenantStats,
};

/// Whether this build may actually fan runs out over host threads. False
/// in the default build (see module docs, §Thread-safety gate): the
/// runtime wrappers carry no `Send`/`Sync` until the resolved xla
/// revision is pinned and audited, so the pool executes inline.
pub const fn threads_enabled() -> bool {
    cfg!(feature = "xla-shared-client")
}

/// Worker-thread count to use when the caller has no opinion: one per
/// available core (the PJRT CPU backend also parallelizes within a
/// dispatch, so benches typically cap this lower). Always 1 when
/// [`threads_enabled`] is false.
pub fn default_jobs() -> usize {
    if !threads_enabled() {
        return 1;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

pub(crate) fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One whole training run, as a schedulable unit: everything the
/// scheduler needs to construct a `Trainer` on a worker thread and drive
/// it to completion — whether that is a *finite batch*
/// ([`WorkerPool::run_all`]) or a submission to the long-lived
/// multi-tenant [`RunQueue`] (`RunQueue::submit_run`, which adds a
/// priority and a tenant on top of the spec).
pub struct RunSpec {
    /// Caller-facing tag carried into [`RunOutput`] (e.g. `"r8/seed3"`).
    pub label: String,
    pub cfg: TrainConfig,
    pub stop: StopRule,
    /// Pretrained W0, shared read-only across every run that uses it.
    pub base: Option<Arc<BTreeMap<String, Tensor>>>,
    /// Override the engine's deferred-readback drain interval (None keeps
    /// `train::engine::DEFAULT_DRAIN_INTERVAL`).
    pub drain_interval: Option<usize>,
}

/// What one scheduled run produced — plain host data only; every device
/// buffer the run owned died with its trainer on the worker thread.
/// Produced by both execution surfaces: finite batches
/// ([`WorkerPool::run_all`]) and long-lived queue submissions
/// ([`RunQueue`] handles, where `summary.cancelled` marks a run the
/// cooperative cancel flag stopped at a step boundary).
pub struct RunOutput {
    pub label: String,
    /// Per-run summary; `summary.transfers` is this run's **exact**
    /// traffic (its engine's own `TransferMeter`), valid at any `--jobs`
    /// level — not a window over the shared global meters.
    pub summary: RunSummary,
    /// The run's deferred-readback ring counters (per-run exact — the
    /// ring is owned by the run).
    pub stream: StreamStats,
    /// SGD losses in dispatch order (the determinism surface: bit-equal
    /// across `--jobs` levels for equal seeds).
    pub sgd_losses: Vec<f32>,
    /// FF stage stats, if the run fast-forwarded.
    pub stages: Vec<FfStageStats>,
    /// Wall-clock of this run on its worker, construction through summary.
    pub seconds: f64,
}

impl RunOutput {
    /// The scheduler's determinism contract, in one place: two runs of the
    /// same spec are bit-identical when every SGD loss and the final test
    /// loss match bit-for-bit. Used by the CLI selftest, the scaling
    /// bench, and `tests/sched_pool.rs` to compare `--jobs` levels.
    pub fn bit_identical(&self, other: &RunOutput) -> bool {
        self.sgd_losses.len() == other.sgd_losses.len()
            && self
                .sgd_losses
                .iter()
                .zip(other.sgd_losses.iter())
                .all(|(a, b)| a.to_bits() == b.to_bits())
            && self.summary.final_test_loss.to_bits()
                == other.summary.final_test_loss.to_bits()
    }
}

/// A completed [`WorkerPool::run_all`] batch: submission-ordered outputs
/// plus batch-level aggregates.
pub struct PoolRun {
    pub outputs: Vec<RunOutput>,
    /// Aggregate host↔device traffic of the whole batch, measured across
    /// the shared atomic meters at the batch boundaries — exact at any
    /// jobs level, and (since the per-engine `TransferMeter`) exactly the
    /// sum of the batch's per-run `summary.transfers`
    /// (`tests/sched_pool.rs` asserts the identity).
    pub transfers: TransferSnapshot,
    /// Wall-clock of the whole batch (the speedup denominator).
    pub wall_seconds: f64,
}

impl PoolRun {
    /// Total Adam steps executed across the batch.
    pub fn total_adam_steps(&self) -> usize {
        self.outputs.iter().map(|o| o.summary.adam_steps).sum()
    }
}

/// Per-key entry slot of the [`ArtifactCache`]: the outer map lock is held
/// only long enough to fetch or create a slot, never across disk I/O or
/// manifest parsing, so unrelated artifacts' first loads proceed
/// concurrently (the same pattern as `ExpContext::pretrained`).
type ArtifactSlot = Arc<Mutex<Option<Arc<Artifact>>>>;

/// Process-local cache mapping artifact keys to shared `Arc<Artifact>`s so
/// concurrent runs over the same artifact compile each program once.
///
/// Resolution order (`docs/artifact-store.md`): the in-memory slot, then
/// the local artifacts dir, then — when a content-addressed
/// [`ArtifactStore`] is attached via [`ArtifactCache::with_store`] — the
/// shared store, materializing the bundle into the local dir. Local builds
/// are published back into the store, so a second host (or a second
/// process in CI) resolves every artifact as a pure store hit. Lockfile
/// pins ([`ArtifactCache::pin`]) are verified against the canonical
/// content hash on first load and fail fast on any mismatch.
pub struct ArtifactCache {
    root: PathBuf,
    cached: Mutex<BTreeMap<String, ArtifactSlot>>,
    store: Option<Arc<ArtifactStore>>,
    /// Artifact key → pinned content hash, from a grid lockfile.
    pins: Mutex<BTreeMap<String, String>>,
}

impl ArtifactCache {
    pub fn new(root: PathBuf) -> ArtifactCache {
        ArtifactCache {
            root,
            cached: Mutex::new(BTreeMap::new()),
            store: None,
            pins: Mutex::new(BTreeMap::new()),
        }
    }

    /// A cache backed by a shared content-addressed store: local misses
    /// materialize from the store, local builds are published into it.
    pub fn with_store(root: PathBuf, store: Arc<ArtifactStore>) -> ArtifactCache {
        ArtifactCache { store: Some(store), ..ArtifactCache::new(root) }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn store(&self) -> Option<&Arc<ArtifactStore>> {
        self.store.as_ref()
    }

    /// Pin `key` to a content hash (from a grid lockfile): the local build
    /// must hash to exactly this, and store resolution fetches exactly
    /// this object — every shard runs bit-identical programs or errors.
    pub fn pin(&self, key: &str, hash: &str) {
        lock(&self.pins).insert(key.to_string(), hash.to_string());
    }

    /// The shared artifact for `key`, loading its manifest on first use.
    /// Programs compile lazily (and once) inside the artifact itself.
    pub fn load(&self, rt: &Arc<Runtime>, key: &str) -> Result<Arc<Artifact>> {
        // Two-level locking: the map lock covers only the slot lookup; the
        // load itself serializes per key on the slot's own lock, so two
        // runs racing on the *same* key still load it once while loads of
        // *different* keys no longer serialize behind each other.
        let slot: ArtifactSlot = {
            let mut cached = lock(&self.cached);
            Arc::clone(cached.entry(key.to_string()).or_default())
        };
        let mut entry = lock(&slot);
        if let Some(a) = entry.as_ref() {
            return Ok(Arc::clone(a));
        }
        let art = Arc::new(self.load_uncached(rt, key)?);
        *entry = Some(Arc::clone(&art));
        Ok(art)
    }

    /// The slow path: resolve the artifact *directory* (verifying pins
    /// and, with a store attached, publishing or materializing), then load
    /// and cross-check the manifest.
    // contract-lint: holds cache.slot (only called from `load` under the slot guard)
    fn load_uncached(&self, rt: &Arc<Runtime>, key: &str) -> Result<Artifact> {
        let dir = self.root.join(key);
        let pinned = lock(&self.pins).get(key).cloned();
        if dir.join("manifest.json").exists() {
            if pinned.is_some() || self.store.is_some() {
                crate::store::verify_local_artifact(&dir, key, pinned.as_deref())?;
            }
            if let Some(s) = &self.store {
                s.ingest_artifact(key, &dir)
                    .with_context(|| format!("publishing artifact '{key}' to the store"))?;
            }
        } else if let Some(s) = &self.store {
            s.materialize_artifact(key, pinned.as_deref(), &dir)
                .with_context(|| format!("materializing artifact '{key}' from the store"))?;
        }
        Artifact::load(rt, &dir).with_context(|| format!("artifact '{key}'"))
    }
}

/// A bounded pool of host worker threads with deterministic,
/// submission-ordered result collection (see module docs).
///
/// Threads are scoped per call — a pool is a *policy* (how many jobs may
/// be in flight), not a set of long-lived threads, so a `WorkerPool` is
/// cheap to construct wherever a harness wants fan-out.
#[derive(Debug, Clone, Copy)]
pub struct WorkerPool {
    jobs: usize,
}

impl WorkerPool {
    /// `jobs` is clamped to at least 1 — and to exactly 1 when
    /// [`threads_enabled`] is false, so [`WorkerPool::jobs`] always
    /// reports the *effective* width (benches and the selftest print
    /// honest numbers in gated builds). `jobs == 1` runs every item
    /// inline on the calling thread (no spawn overhead, trivially
    /// ordered).
    pub fn new(jobs: usize) -> WorkerPool {
        let jobs = if threads_enabled() { jobs.max(1) } else { 1 };
        WorkerPool { jobs }
    }

    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Run `f` over every item on up to `jobs` worker threads. Items are
    /// handed out in submission order from a shared queue; results come
    /// back **in submission order** regardless of completion order. The
    /// first failing item's error (by submission index) is returned after
    /// all workers settle; later items may then be unexecuted.
    #[cfg(feature = "xla-shared-client")]
    pub fn scatter<T, R, F>(&self, items: Vec<T>, f: F) -> Result<Vec<R>>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> Result<R> + Sync,
    {
        let n = items.len();
        if self.jobs == 1 || n <= 1 {
            return scatter_inline(items, f);
        }

        let queue: Mutex<VecDeque<(usize, T)>> =
            Mutex::new(items.into_iter().enumerate().collect());
        let slots: Mutex<Vec<Option<Result<R>>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let failed = std::sync::atomic::AtomicBool::new(false);
        let workers = self.jobs.min(n);
        std::thread::scope(|s| {
            for _ in 0..workers {
                let queue = &queue;
                let slots = &slots;
                let failed = &failed;
                let f = &f;
                s.spawn(move || loop {
                    if failed.load(std::sync::atomic::Ordering::Relaxed) {
                        return; // fail fast: leave the rest of the queue
                    }
                    let item = lock(queue).pop_front();
                    let Some((i, item)) = item else { return };
                    let r = f(i, item);
                    if r.is_err() {
                        failed.store(true, std::sync::atomic::Ordering::Relaxed);
                    }
                    lock(slots)[i] = Some(r);
                });
            }
        });

        let slots = slots.into_inner().unwrap_or_else(PoisonError::into_inner);
        // Report the lowest-index error first (deterministic), then demand
        // every remaining slot is filled.
        if let Some(i) = slots.iter().position(|s| matches!(s, Some(Err(_)))) {
            let e = match slots.into_iter().nth(i).flatten() {
                Some(Err(e)) => e,
                _ => unreachable!("slot {i} held an error"),
            };
            return Err(e.context(format!("scheduled job #{i}")));
        }
        let mut out = Vec::with_capacity(n);
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(r)) => out.push(r),
                Some(Err(_)) => unreachable!("errors returned above"),
                None => bail!("scheduled job #{i} was never executed"),
            }
        }
        Ok(out)
    }

    /// Sequential scatter: same signature and contract as the threaded
    /// version minus `Send`/`Sync` bounds — without the
    /// `xla-shared-client` feature the runtime wrappers are `!Send`/
    /// `!Sync` (see module docs, §Thread-safety gate), so nothing may
    /// cross threads and every batch runs inline in submission order.
    #[cfg(not(feature = "xla-shared-client"))]
    pub fn scatter<T, R, F>(&self, items: Vec<T>, f: F) -> Result<Vec<R>>
    where
        F: Fn(usize, T) -> Result<R>,
    {
        scatter_inline(items, f)
    }

    /// Execute whole `Trainer::run` jobs across the pool: one trainer per
    /// spec, constructed and dropped on its worker thread, artifacts and
    /// `W0` shared read-only. Results are submission-ordered; the batch's
    /// aggregate transfer traffic is measured exactly across the shared
    /// atomic meters.
    pub fn run_all(
        &self,
        rt: &Arc<Runtime>,
        artifacts: &ArtifactCache,
        specs: Vec<RunSpec>,
    ) -> Result<PoolRun> {
        let before = rt.stats.snapshot();
        let t0 = Instant::now();
        let outputs = self.scatter(specs, |_i, spec| execute_run(rt, artifacts, spec))?;
        Ok(PoolRun {
            outputs,
            transfers: rt.stats.snapshot().since(&before),
            wall_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

/// The inline execution path shared by both `scatter` variants:
/// submission order, fail-fast on the first error.
fn scatter_inline<T, R, F>(items: Vec<T>, f: F) -> Result<Vec<R>>
where
    F: Fn(usize, T) -> Result<R>,
{
    let mut out = Vec::with_capacity(items.len());
    for (i, item) in items.into_iter().enumerate() {
        out.push(f(i, item).with_context(|| format!("scheduled job #{i}"))?);
    }
    Ok(out)
}

/// Drive one [`RunSpec`] to completion on the current thread.
fn execute_run(rt: &Arc<Runtime>, artifacts: &ArtifactCache, spec: RunSpec) -> Result<RunOutput> {
    execute_run_cancellable(rt, artifacts, spec, None)
}

/// [`execute_run`] with an optional cooperative cancel flag installed on
/// the trainer: once raised, the run stops at its next step boundary and
/// the output's `summary.cancelled` is true (the [`RunQueue`] path).
pub(crate) fn execute_run_cancellable(
    rt: &Arc<Runtime>,
    artifacts: &ArtifactCache,
    spec: RunSpec,
    cancel: Option<Arc<AtomicBool>>,
) -> Result<RunOutput> {
    match execute_run_resumable(rt, artifacts, &spec, cancel, None, None, None)? {
        SlotOutcome::Finished(out) => Ok(out),
        SlotOutcome::Parked { .. } => unreachable!("no park flag or quantum was installed"),
    }
}

/// How one queue *slot* of a resumable run ended: the run reached its stop
/// rule (or honored a cooperative cancel), or it **parked** at an SGD step
/// boundary with its full trainable/optimizer/FF-controller state captured
/// for a later [`Trainer::resume_from`] on a fresh trainer.
pub(crate) enum SlotOutcome {
    Finished(RunOutput),
    Parked {
        state: Box<ParkState>,
        /// True when the park flag (preemption) forced the park rather
        /// than the step quantum expiring — preempted runs re-enter at
        /// the *front* of their priority class, quantum-expired runs at
        /// the back.
        preempted: bool,
        /// Wall-clock this slot occupied its worker.
        seconds: f64,
    },
}

/// The queue's preemptible execution surface: one *slot* of a training
/// run. Constructs a fresh `Trainer` (optionally restoring a parked
/// run's state via `resume`), installs the cooperative cancel and park
/// flags plus an optional fair-share step `quantum`, and drives the run
/// until it finishes, cancels, or parks at an SGD step boundary. The
/// spec is borrowed (`cfg` cloned per slot) so a parked run's closure can
/// re-enter with the same spec on its next slot.
pub(crate) fn execute_run_resumable(
    rt: &Arc<Runtime>,
    artifacts: &ArtifactCache,
    spec: &RunSpec,
    cancel: Option<Arc<AtomicBool>>,
    park: Option<Arc<AtomicBool>>,
    quantum: Option<usize>,
    resume: Option<&ParkState>,
) -> Result<SlotOutcome> {
    let t0 = Instant::now();
    // Window the shared store counters around this slot: at --jobs 1 the
    // delta is exactly this run's store traffic; under concurrency it is
    // an approximate window (the counters are process-wide atomics).
    let store0 = artifacts.store().map(|s| s.stats.snapshot());
    let art = artifacts.load(rt, &spec.cfg.artifact)?;
    let label = &spec.label;
    let mut t = Trainer::with_artifact(rt, art, spec.cfg.clone(), spec.base.as_deref())
        .with_context(|| format!("run '{label}'"))?;
    if let Some(k) = spec.drain_interval {
        t.set_drain_interval(k);
    }
    if let Some(flag) = cancel {
        t.set_cancel_flag(flag);
    }
    if let Some(flag) = park {
        t.set_park_flag(flag);
    }
    if let Some(q) = quantum {
        t.set_step_quantum(q);
    }
    if let Some(state) = resume {
        t.resume_from(state).with_context(|| format!("resuming parked run '{label}'"))?;
    }
    let mut summary = t.run(&spec.stop).with_context(|| format!("run '{label}'"))?;
    if let (Some(before), Some(store)) = (store0, artifacts.store()) {
        summary.store = Some(store.stats.snapshot().since(&before));
    }
    if summary.parked {
        return Ok(SlotOutcome::Parked {
            preempted: t.park_was_preemption(),
            state: Box::new(t.park_state().with_context(|| format!("parking run '{label}'"))?),
            seconds: t0.elapsed().as_secs_f64(),
        });
    }
    let sgd_losses = t
        .log
        .records
        .iter()
        .filter(|r| r.kind == StepKind::Sgd)
        .map(|r| r.loss)
        .collect();
    Ok(SlotOutcome::Finished(RunOutput {
        label: label.clone(),
        summary,
        stream: t.stream_stats().clone(),
        sgd_losses,
        stages: t.ffc.stages.clone(),
        seconds: t0.elapsed().as_secs_f64(),
    }))
}

#[cfg(test)]
mod tests {
    //! Pool mechanics only — running real trainers through the pool needs
    //! AOT artifacts and lives in `rust/tests/sched_pool.rs`.
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn jobs_clamp_to_one() {
        assert_eq!(WorkerPool::new(0).jobs(), 1);
        // Builds without the xla-shared-client feature have no thread
        // fan-out; the pool reports its effective (inline) width.
        let expected = if threads_enabled() { 3 } else { 1 };
        assert_eq!(WorkerPool::new(3).jobs(), expected);
    }

    #[test]
    fn scatter_returns_submission_order_at_any_width() {
        // Jobs finish in reverse submission order (earlier items sleep
        // longer); results must still come back in submission order.
        for jobs in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(jobs);
            let items: Vec<usize> = (0..8).collect();
            let out = pool
                .scatter(items, |i, item| {
                    assert_eq!(i, item);
                    std::thread::sleep(std::time::Duration::from_millis(
                        (8 - item as u64) * 3,
                    ));
                    Ok(item * 10)
                })
                .unwrap();
            assert_eq!(out, vec![0, 10, 20, 30, 40, 50, 60, 70], "jobs={jobs}");
        }
    }

    #[test]
    fn scatter_runs_every_item_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = WorkerPool::new(4)
            .scatter((0..100usize).collect(), |_i, item| {
                counter.fetch_add(1, Ordering::Relaxed);
                Ok(item)
            })
            .unwrap();
        assert_eq!(out.len(), 100);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(out, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn scatter_propagates_the_lowest_index_error() {
        let err = WorkerPool::new(4)
            .scatter((0..16usize).collect(), |_i, item| {
                if item == 3 || item == 11 {
                    bail!("boom at {item}");
                }
                Ok(item)
            })
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("scheduled job #3"), "{msg}");
        assert!(msg.contains("boom at 3"), "{msg}");
    }

    #[test]
    fn inline_path_short_circuits_on_error() {
        let counter = AtomicUsize::new(0);
        let err = WorkerPool::new(1)
            .scatter((0..10usize).collect(), |_i, item| {
                counter.fetch_add(1, Ordering::Relaxed);
                if item == 2 {
                    bail!("boom");
                }
                Ok(item)
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("scheduled job #2"));
        assert_eq!(counter.load(Ordering::Relaxed), 3, "inline is fail-fast");
    }

}
