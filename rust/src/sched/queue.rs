//! Long-lived multi-tenant run queue: the serving-shaped half of the
//! scheduler (`crate::sched`).
//!
//! [`WorkerPool::run_all`](crate::sched::WorkerPool::run_all) executes
//! *finite batches*: submit everything, wait for everything. A service
//! running "many concurrent finetuning workloads" (ROADMAP north star)
//! needs the other shape — a [`RunQueue`] that accepts submissions **at
//! any time**, hands back a [`RunHandle`] the caller can `poll`, `join`,
//! `cancel`, or `park`, schedules by **priority** (higher pops first)
//! with **fair share** within a class, and keeps **per-tenant
//! accounting** ([`TenantStats`]: runs, steps, FF stages, FLOPs, and
//! *exact* transfer bytes from each run's own `TransferMeter`).
//!
//! # Preemption: park / resume (survivable serving)
//!
//! Training runs submitted via [`RunQueue::submit_run`] are
//! **preemptible**: when a higher-priority submission arrives and every
//! worker is busy, the lowest-priority running run is asked to *park* —
//! at its next SGD step boundary it checkpoints its trainables, Adam
//! moments, step counters, FF-controller position, and full metric
//! trail to disk (`train::checkpoint::save_park_state`, temp-then-rename
//! so a crash mid-write never leaves a half checkpoint under the real
//! name), and re-enters the queue at the **front** of its class. On its
//! next slot a fresh trainer restores the state
//! (`Trainer::resume_from`) and continues — **resume, not restart**: the
//! resumed run's losses and final eval are bit-identical to an
//! uninterrupted run, with only the park/resume sync traffic added on
//! top (asserted exactly in `rust/tests/sched_queue.rs`; byte formulas
//! in `docs/transfer-contract.md` §5). [`RunQueue::set_step_quantum`]
//! uses the same machinery for time-slicing: every slot parks after N
//! Adam steps and re-queues at the *back* of its class (round-robin).
//! A cancel while parked deletes the checkpoint and finishes the handle;
//! dropping the queue **fails** parked handles loudly (their progress is
//! discarded — never silently) and removes their park files.
//!
//! # Streaming runs
//!
//! [`RunQueue::submit_stream`] admits a long-lived training run whose
//! data arrives **after** submission: the tenant appends examples
//! through the returned [`StreamHandle`] (`feed`), and the run consumes
//! one SGD step per `global_batch` examples fed. A slot that catches up
//! with the feed does not busy-wait: it checkpoints exactly like a park
//! and *holds* — its continuation moves off the ready queue into a side
//! map keyed by submission, and the next `feed`/`finish` re-enqueues it
//! (the hold and the wake are serialized on the stream's feed lock, so
//! a feed can never slip between "observe starved" and "hold").
//! Park, preempt, cancel, quota, and fair-share semantics are unchanged
//! — a streaming slot is billed through the same park/final folds as
//! any park-aware run, so tenant byte totals still sum exactly to the
//! global meter delta. [`StreamHandle::finish`] closes the stream: the
//! run consumes whatever remains and ends with the normal final eval,
//! so a streamed run's losses and final test loss are **bit-identical**
//! to a batch run over the same example sequence (asserted in
//! `rust/tests/sched_queue.rs`).
//!
//! # Completion-order streaming
//!
//! [`RunQueue::completions`] / [`RunQueue::next_completion`] yield
//! finished submissions in **completion order** — a finished
//! high-priority run streams out immediately instead of waiting behind
//! earlier submissions' `join`s. Each outcome is delivered exactly once
//! across both surfaces (a joined handle is skipped by the stream, and
//! joining a stream-delivered handle is a loud error).
//!
//! # Fair share and quotas
//!
//! Within a priority class the queue runs the entry whose tenant has
//! consumed the least schedule-weight (chargeable FLOPs plus exact
//! transfer bytes priced at [`BYTE_COST_FLOPS`] FLOPs/byte; ties to
//! fewest slots picked, then FIFO) — a deficit rule over the same
//! [`TenantStats`] meters the billing uses, so fairness and accounting
//! can't drift apart. One tenant degenerates to plain FIFO.
//! [`RunQueue::set_quota`] adds hard per-tenant budgets enforced at
//! admission ([`SubmitError::QuotaExceeded`]).
//!
//! # Backpressure
//!
//! [`RunQueue::set_capacity`] bounds in-flight depth: `submit` rejects
//! with [`SubmitError::Full`] (the job is not consumed silently — run
//! submissions return the error immediately), and
//! [`RunQueue::submit_wait`] blocks for space (inline-drain builds drain
//! queued work on the calling thread instead of blocking). Parked
//! re-entries never re-check capacity: admission is paid once.
//!
//! # Execution model
//!
//! * **With the `xla-shared-client` feature** (pinned + audited xla rev,
//!   see `crate::sched` §Thread-safety gate): `RunQueue::new(jobs)` spawns
//!   `jobs` long-lived worker threads. Each worker pops the
//!   highest-priority, oldest submission, runs it to completion, and
//!   parks on a condvar when the queue is empty.
//! * **Without the feature** (the default): nothing xla-backed may cross
//!   a thread, so the queue spawns **no** workers. Submissions accumulate
//!   and are drained *inline*, on the thread that calls
//!   [`RunHandle::join`], strictly in priority order (FIFO within a
//!   class) — deterministic, and bit-identical to a single worker
//!   draining the same queue. `rust/tests/sched_queue.rs` asserts queue
//!   results are bit-identical to `WorkerPool::run_all` in both builds.
//!
//! # Same-artifact packing
//!
//! [`RunQueue::submit_run_packable`] opts a training run into **batched
//! group dispatch**: when its job is popped and K−1 compatible
//! submissions (same artifact, priority, step count, batch geometry,
//! and frozen-weight source — the `pack_signature`) are still queued,
//! the popped job *leads*: it claims them and drives all K runs as one
//! `*_batched{K}` program group (`crate::train::batched`), ~K× fewer
//! dispatches per step. Each member still joins its own handle with a
//! [`RunOutput`] whose losses are **bit-identical** to a solo run and
//! whose `summary.transfers` is its exact byte slice of the group
//! traffic; tenants are billed exactly as if every run went solo.
//! Ineligible specs (loss-targeted stop, FF stages, artifacts without
//! batched programs) fall back to solo execution transparently.
//!
//! # Cancellation
//!
//! [`RunHandle::cancel`] is two-phase:
//!
//! * **Queued** submissions are marked `Cancelled` immediately and are
//!   never executed — for training runs, no `Trainer` (and no device
//!   state) is ever constructed. **Parked** submissions are cancelled the
//!   same way; their on-disk checkpoint is deleted (nothing will resume
//!   it).
//! * **Running** submissions get a cooperative flag ([`CancelToken`],
//!   installed via `Trainer::set_cancel_flag`) that the policy loop
//!   checks at every step boundary: the run stops cleanly, drains its
//!   pipeline, evaluates, and reports `Cancelled` **with** its partial
//!   output — never an error, never a torn state. Members of an
//!   in-flight *batched group* have no per-step cancel point: they run
//!   to the group's end and join `Done` (cancel lands at the batch
//!   boundary).
//!
//! # Determinism and accounting
//!
//! A run's dispatch sequence depends only on its spec, never on queue
//! siblings, so queue execution is bit-identical to `run_all` for equal
//! specs at any worker count. Per-tenant transfer totals sum the per-run
//! exact meters, so across a quiescent queue they add up *exactly* to the
//! global `Runtime::stats` delta (`rust/tests/sched_queue.rs`).

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::metrics::StepKind;
use crate::runtime::{Runtime, StreamStats, TransferSnapshot};
use crate::sched::lifecycle::{ClaimedFrom, Lifecycle, Outcome, Phase};
use crate::sched::{
    execute_run_cancellable, execute_run_resumable, lock, ArtifactCache, RunOutput, RunSpec,
    SlotOutcome,
};
use crate::train::batched::{pack_eligible, run_batched_group, MemberSpec};
use crate::train::checkpoint::{load_park_state, save_park_state, ParkState};
use crate::train::StopRule;

/// How a job reports back to the queue: done, cancelled-with-partial-
/// output when the job itself observed (and honored) the cooperative
/// flag, or **parked** — the job checkpointed its progress at a step
/// boundary and hands back a continuation `next` to re-queue (at the
/// front of its priority class when a preemption forced the park, at the
/// back when its fair-share step quantum expired). Jobs classify their
/// *own* outcome so a racing `cancel()` that landed after the work fully
/// completed cannot misreport a delivered run as cancelled —
/// `submit_run` classifies from the trainer's authoritative
/// `summary.cancelled`; plain-closure submissions ([`RunQueue::submit`])
/// fall back to the token state at return.
enum JobYield<R> {
    Done(R),
    Cancelled(R),
    Parked { next: Job<R>, front: bool },
    /// The job checkpointed and parked its continuation **off the ready
    /// queue** into [`Shared::streams`] (a data-starved streaming run,
    /// [`RunQueue::submit_stream`]): nothing to re-enqueue here —
    /// [`StreamHandle::feed`]/[`StreamHandle::finish`] wakes it.
    Held,
}

/// One queued job: takes the submission's [`CancelToken`] (so
/// long-running work can stop cooperatively) and returns its
/// self-classified result.
#[cfg(feature = "xla-shared-client")]
type Job<R> = Box<dyn FnOnce(&CancelToken) -> Result<JobYield<R>> + Send + 'static>;
/// Ungated variant: no worker threads exist, jobs never cross a thread,
/// so no `Send` bound (see `crate::sched`, §Thread-safety gate).
#[cfg(not(feature = "xla-shared-client"))]
type Job<R> = Box<dyn FnOnce(&CancelToken) -> Result<JobYield<R>> + 'static>;

/// The cooperative signals handed to every job: a cancellation flag and a
/// **park** flag. Long-running jobs poll [`CancelToken::is_cancelled`]
/// (or install [`CancelToken::flag`] on a `Trainer`) and stop at their
/// next clean boundary; park-aware jobs additionally install
/// [`CancelToken::park_flag`] (`Trainer::set_park_flag`) so a preemption
/// lands at the next SGD step boundary. Quick jobs may ignore both. The
/// token also carries the submission's park-file slot so a parked run's
/// continuation finds its checkpoint on the next slot.
pub struct CancelToken {
    flag: Arc<AtomicBool>,
    park: Arc<AtomicBool>,
    park_file: Arc<Mutex<Option<PathBuf>>>,
}

impl CancelToken {
    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    /// The underlying shared flag (install on a
    /// `Trainer` via `set_cancel_flag` so cancellation lands at the next
    /// step boundary of the policy loop).
    pub fn flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.flag)
    }

    /// True once a preemption (or [`RunHandle::park`]) asked this job to
    /// park at its next clean boundary.
    pub fn park_requested(&self) -> bool {
        self.park.load(Ordering::SeqCst)
    }

    /// The shared park flag (install on a `Trainer` via `set_park_flag`
    /// so a preemption parks the run at its next SGD step boundary).
    pub fn park_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.park)
    }

    /// Where this submission's parked state lives on disk, if an earlier
    /// slot parked it (the resume side of the park protocol).
    fn park_file(&self) -> Option<PathBuf> {
        lock(&self.park_file).clone()
    }

    /// Record where this slot parked the run's state. The queue deletes
    /// the file when the submission reaches a terminal state.
    fn set_park_file(&self, path: PathBuf) {
        *lock(&self.park_file) = Some(path);
    }
}

/// Why [`RunQueue::try_submit`]-family admission rejected a submission.
#[derive(Debug)]
pub enum SubmitError {
    /// The queue's bounded depth ([`RunQueue::set_capacity`]) is reached:
    /// `capacity` submissions are admitted and unfinished. Re-submit
    /// later, or use [`RunQueue::submit_wait`] to block for space.
    Full { capacity: usize },
    /// The tenant exhausted a configured budget
    /// ([`RunQueue::set_quota`]). Quotas only ever fill up, so this is a
    /// permanent rejection until the quota is raised.
    QuotaExceeded { tenant: String, reason: String },
    /// The tenant hit its time-window rate limit
    /// ([`TenantQuota::per_window`]): unlike [`SubmitError::QuotaExceeded`]
    /// this is *transient* — re-submitting after `retry_after` lands in a
    /// fresh window and is admitted (budget permitting).
    RateLimited { tenant: String, retry_after: Duration },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full { capacity } => {
                write!(f, "queue is full ({capacity} submissions in flight)")
            }
            SubmitError::QuotaExceeded { tenant, reason } => {
                write!(f, "tenant '{tenant}' over quota: {reason}")
            }
            SubmitError::RateLimited { tenant, retry_after } => {
                write!(
                    f,
                    "tenant '{tenant}' rate-limited: window budget spent, retry in {:.1}s",
                    retry_after.as_secs_f64()
                )
            }
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-tenant resource budgets, enforced at **admission**: a tenant whose
/// consumed totals ([`TenantStats`]) meet or exceed a budget cannot
/// submit new work (already-admitted runs are unaffected — budgets bound
/// future admissions, they never tear down running work). `None` fields
/// are unlimited.
#[derive(Debug, Clone, Copy, Default)]
pub struct TenantQuota {
    /// Maximum chargeable FLOPs across the tenant's finished/parked work.
    pub max_flops: Option<u64>,
    /// Maximum host↔device bytes (uploads + downloads + donations).
    pub max_bytes: Option<u64>,
    /// Time-window rate limit `(flops, bytes, window)`: within any one
    /// window the tenant may consume strictly less than `flops`
    /// chargeable FLOPs and `bytes` transfer bytes before admission
    /// rejects with [`SubmitError::RateLimited`] (use `u64::MAX` to
    /// rate-limit one dimension only). The window opens at the tenant's
    /// first admission (baseline = its consumed totals at that instant)
    /// and rolls over `window` later; [`RunQueue::set_quota`] resets it.
    /// Unlike the hard budgets above, a spent window clears on its own.
    pub per_window: Option<(u64, u64, Duration)>,
}

/// Non-blocking status of a submission ([`RunHandle::poll`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunPoll {
    /// Waiting in the queue (not started).
    Queued,
    /// A worker is executing it.
    Running,
    /// Parked at a step boundary (preempted or quantum-expired): its
    /// progress is checkpointed and it is waiting in the queue to resume.
    Parked,
    /// Finished successfully; `join` will return [`RunResult::Done`].
    Done,
    /// Cancelled (before start, or cooperatively mid-run).
    Cancelled,
    /// The job returned an error; `join` will surface it.
    Failed,
}

/// What a successfully-joined submission produced.
pub enum RunResult<R = RunOutput> {
    /// Ran to completion.
    Done(R),
    /// Cancelled: `None` when the submission was cancelled before it ever
    /// started (nothing was constructed or executed), `Some` when a
    /// running job honored the cooperative flag and returned its partial
    /// output (for training runs, a consistent summary with
    /// `summary.cancelled == true`).
    Cancelled(Option<R>),
}

impl<R> RunResult<R> {
    pub fn is_cancelled(&self) -> bool {
        matches!(self, RunResult::Cancelled(_))
    }

    /// The completed output, if the run finished normally.
    pub fn done(self) -> Option<R> {
        match self {
            RunResult::Done(r) => Some(r),
            RunResult::Cancelled(_) => None,
        }
    }

    /// Whatever output exists — complete, or the partial output of a
    /// cooperative mid-run cancellation.
    pub fn into_output(self) -> Option<R> {
        match self {
            RunResult::Done(r) => Some(r),
            RunResult::Cancelled(r) => r,
        }
    }
}

/// Per-tenant accounting, updated as the tenant's submissions move
/// through the queue. Counters (`submitted`/`completed`/…) are maintained
/// by the queue itself; the per-run fields (`adam_steps`, `flops`,
/// `transfers`, …) are folded in by training-run submissions
/// ([`RunQueue::submit_run`]) from each run's own summary — `transfers`
/// sums the runs' **exact** per-engine meters, so tenant byte totals add
/// up exactly to the global `Runtime::stats` delta across a quiescent
/// queue whose runs all completed or were cancelled. (A *failed* run has
/// no summary to fold: its partial traffic stays in the global meters
/// only, and `failed` counts it.)
#[derive(Debug, Default, Clone)]
pub struct TenantStats {
    pub submitted: u64,
    pub completed: u64,
    pub cancelled: u64,
    pub failed: u64,
    /// Execution slots handed to this tenant's submissions (each
    /// park/resume slot of one run counts once) — the fair-share
    /// tiebreak when weighted costs are equal.
    pub picked: u64,
    /// Park events across the tenant's runs (preemptions + expired step
    /// quanta).
    pub parked: u64,
    /// Adam steps across the tenant's finished runs (cancelled runs
    /// included — their partial work is real work).
    pub adam_steps: u64,
    /// FF simulated steps across the tenant's finished runs.
    pub sim_steps: u64,
    /// FF stages executed across the tenant's finished runs.
    pub ff_stages: u64,
    /// Chargeable FLOPs across the tenant's finished runs.
    pub flops: u64,
    /// Wall-clock seconds its runs occupied workers.
    pub seconds: f64,
    /// Exact host↔device traffic of the tenant's finished runs (sum of
    /// per-run `TransferMeter`s).
    pub transfers: TransferSnapshot,
}

/// Shared between a [`RunHandle`] and the queue: one per submission.
/// The `state` field holds the submission's [`Lifecycle`] — the pure
/// state machine (claim exclusivity, terminal gate, exactly-once
/// delivery) extracted into `crate::sched::lifecycle` and model-checked
/// exhaustively in `rust/tests/lifecycle_model.rs`; this queue supplies
/// the locks, condvars, and I/O around it.
struct HandleShared<R> {
    seq: u64,
    tenant: String,
    /// The priority class the submission re-enters on a park.
    priority: i32,
    cancel: Arc<AtomicBool>,
    /// Raised to ask the job to park at its next clean boundary
    /// (preemption, or an explicit [`RunHandle::park`]).
    park: Arc<AtomicBool>,
    /// Where the parked state lives on disk between slots; the queue
    /// deletes it at any terminal transition ([`finish_handle`]).
    park_file: Arc<Mutex<Option<PathBuf>>>,
    /// True for park-aware training runs ([`RunQueue::submit_run`]):
    /// only these register as preemption victims while running. Packed
    /// submissions and plain closures are not preemptible — a packed
    /// group has no per-member park point (preemption composes with
    /// packing at group boundaries only).
    preemptible: bool,
    state: Mutex<Lifecycle<R>>,
    cv: Condvar,
}

struct Entry<R> {
    job: Job<R>,
    handle: Arc<HandleShared<R>>,
}

/// What a pack leader needs to run a claimed sibling's member: its spec
/// and tenant (for accounting). Parked in [`Shared::pack_pool`] by
/// [`RunQueue::submit_run_packable`] until the submission's own job
/// takes it back (solo) or a leader claims it (batched).
struct PackData {
    spec: RunSpec,
    tenant: String,
}

/// One tenant's open rate window ([`TenantQuota::per_window`]): the
/// baseline is the tenant's consumed totals when the window opened, so
/// "spent this window" is a plain subtraction against [`TenantStats`] —
/// no per-admission bookkeeping beyond this struct.
struct WindowState {
    started: Instant,
    flops_at_start: u64,
    bytes_at_start: u64,
}

/// A packable submission parked for group formation. The `data` slot is
/// the exclusivity token: whoever takes the `PackData` — the
/// submission's own job, or a pack leader that flipped its handle
/// `Queued → Running` first — owns the run. Slots found empty (or
/// handles found past `Queued`) are stale and dropped from the pool.
struct PackMate<R> {
    handle: Arc<HandleShared<R>>,
    data: Arc<Mutex<Option<PackData>>>,
}

struct QueueState<R> {
    /// priority class → submissions, oldest first. Pop = highest class,
    /// fair-share pick within it ([`take_next`]); empty classes are
    /// removed eagerly.
    ready: BTreeMap<i32, VecDeque<Entry<R>>>,
    /// Entries currently in `ready` (including submissions cancelled
    /// while queued that no worker has reaped yet, and parked re-entries
    /// waiting to resume).
    queued: usize,
    /// Admitted-and-unfinished submissions (queued + running + parked).
    /// This is what [`RunQueue::set_capacity`] bounds; parked re-entries
    /// were counted at admission and stay counted until terminal.
    live: usize,
    /// Bounded depth: `None` = unbounded (the default).
    capacity: Option<usize>,
    /// Finished submissions awaiting the completions stream, completion
    /// order. Entries whose outcome a `join` already took are skipped at
    /// claim time.
    done: VecDeque<Arc<HandleShared<R>>>,
    next_seq: u64,
    paused: bool,
    shutdown: bool,
}

struct Shared<R> {
    state: Mutex<QueueState<R>>,
    /// Workers (and pause/shutdown transitions) wait/notify here.
    cv: Condvar,
    /// Completion-stream consumers wait here (paired with `state`);
    /// notified by [`finish_handle`].
    done_cv: Condvar,
    /// [`RunQueue::submit_wait`] callers wait here (paired with `state`)
    /// for `live` to drop below capacity.
    space_cv: Condvar,
    tenants: Mutex<BTreeMap<String, TenantStats>>,
    /// Per-tenant admission budgets ([`RunQueue::set_quota`]).
    quotas: Mutex<BTreeMap<String, TenantQuota>>,
    /// Open rate windows ([`TenantQuota::per_window`]), keyed by tenant.
    /// Leaf lock, taken only inside `admission_error`/`set_quota`.
    windows: Mutex<BTreeMap<String, WindowState>>,
    /// Fair-share step quantum for park-aware runs
    /// ([`RunQueue::set_step_quantum`]): a running slot parks after this
    /// many Adam steps and re-queues at the back of its class.
    quantum: Mutex<Option<usize>>,
    /// Currently-executing *preemptible* submissions: seq → (priority,
    /// park flag). Leaf lock (nothing else is taken while held): the
    /// preemption scan picks the lowest-priority youngest victim.
    running: Mutex<BTreeMap<u64, (i32, Arc<AtomicBool>)>>,
    /// Packable submissions awaiting group formation, keyed by pack
    /// signature (artifact | priority | steps | batch geometry | frozen
    /// source — see `pack_signature`). Lock order: `pack_pool` before
    /// any `HandleShared::state`, never the other way.
    pack_pool: Mutex<BTreeMap<String, Vec<PackMate<R>>>>,
    /// Streaming submissions ([`RunQueue::submit_stream`]) whose next
    /// slot is **data-starved**: the continuation waits here, keyed by
    /// seq, off the ready queue (workers never busy-poll it) until
    /// [`StreamHandle::feed`]/[`finish`] re-enqueues it. Insertions and
    /// removals happen under the owning stream's `StreamCtl::feed`
    /// lock (acquired first), so a feed either lands before the slot
    /// observes starvation or finds the held entry — never between.
    /// Queue drop drains this map and fails the held runs loudly, the
    /// same policy as parked entries.
    streams: Mutex<BTreeMap<u64, Entry<R>>>,
}

/// Plain-closure cancel classification ([`RunQueue::submit`]): the best
/// signal a generic job has is the token state at return. Jobs with an
/// authoritative marker of their own (training runs: `summary.cancelled`)
/// build the [`JobYield`] themselves instead.
fn yield_by_token<R>(out: R, token: &CancelToken) -> Result<JobYield<R>> {
    if token.is_cancelled() {
        Ok(JobYield::Cancelled(out))
    } else {
        Ok(JobYield::Done(out))
    }
}

/// Render a caught panic payload as the submission's error (the common
/// payloads are `&str`/`String` from panic!/assert!/expect).
fn panic_error(payload: Box<dyn std::any::Any + Send>) -> anyhow::Error {
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string());
    anyhow::anyhow!("queued job panicked: {msg}")
}

/// Schedule-weight of one byte moved, in FLOPs: low-rank training is
/// transfer/overhead-bound at small ranks (ROADMAP), so fairness must
/// price traffic, not just compute. One deficit unit = 1 FLOP.
const BYTE_COST_FLOPS: u128 = 512;

/// A tenant's consumed schedule-weight: chargeable FLOPs plus its exact
/// transfer bytes priced at [`BYTE_COST_FLOPS`]. The deficit-style pick
/// rule runs the *least*-consuming tenant's oldest entry first.
fn fair_cost(t: &TenantStats) -> u128 {
    let bytes = t.transfers.uploaded_bytes
        + t.transfers.downloaded_bytes
        + t.transfers.donated_bytes;
    t.flops as u128 + (bytes as u128) * BYTE_COST_FLOPS
}

/// Pop the next runnable entry: highest priority class first; **within**
/// a class, a deficit-style fair-share pick — each waiting tenant is
/// represented by its oldest entry, and the entry whose tenant has the
/// lowest consumed weight ([`fair_cost`], ties broken by fewest slots
/// picked, then lowest seq) runs next. A single-tenant class degenerates
/// to FIFO, so priority/FIFO ordering guarantees are unchanged for one
/// tenant. Submissions cancelled while queued are reaped (dropped
/// unexecuted) here. Returns `None` when paused or empty.
// contract-lint: holds queue.state (callers pass the `shared.state` guard as `st`)
fn take_next<R>(shared: &Shared<R>, st: &mut QueueState<R>) -> Option<Entry<R>> {
    if st.paused {
        return None;
    }
    loop {
        let prio = *st.ready.keys().next_back()?;
        let class = st.ready.get_mut(&prio).expect("key just observed");
        let idx = {
            let tenants = lock(&shared.tenants);
            let mut seen: BTreeSet<&str> = BTreeSet::new();
            let mut best: Option<(usize, (u128, u64, u64))> = None;
            for (i, e) in class.iter().enumerate() {
                if !seen.insert(e.handle.tenant.as_str()) {
                    continue; // only each tenant's oldest entry competes
                }
                let (cost, picked) = tenants
                    .get(e.handle.tenant.as_str())
                    .map(|t| (fair_cost(t), t.picked))
                    .unwrap_or((0, 0));
                let key = (cost, picked, e.handle.seq);
                if best.as_ref().map_or(true, |(_, b)| key < *b) {
                    best = Some((i, key));
                }
            }
            best.expect("empty classes are removed").0
        };
        let entry = class.remove(idx).expect("index just computed");
        if class.is_empty() {
            st.ready.remove(&prio);
        }
        st.queued -= 1;
        let finished = lock(&entry.handle.state).is_finished();
        if finished {
            continue; // cancelled while queued: never execute
        }
        return Some(entry);
    }
}

/// The single terminal-transition gate: every path that ends a
/// submission — worker completion, pack publish, cancel-before-start,
/// cancel-of-parked, queue drop — funnels through here so the
/// invariants hold everywhere: the park file (if any) is deleted, the
/// outcome is published and joiners woken, `live` is decremented, and
/// the handle enters the completions stream exactly once. Tenant
/// counters are bumped by the *caller* (the outcome classification is
/// call-site-specific). Lock discipline: `handle.state` is taken and
/// released before `shared.state` (never nested — [`take_next`] nests
/// the other way around).
fn finish_handle<R>(shared: &Shared<R>, handle: &Arc<HandleShared<R>>, outcome: Outcome<R>) {
    if let Some(path) = lock(&handle.park_file).take() {
        let _ = std::fs::remove_file(path);
    }
    // Lifecycle::finish asserts the caller won the Running claim first —
    // the exactly-once half of this gate is mechanized in the state
    // machine itself, not in this function's call sites.
    lock(&handle.state).finish(outcome);
    handle.cv.notify_all();
    {
        let mut st = lock(&shared.state);
        st.live = st.live.saturating_sub(1);
        st.done.push_back(Arc::clone(handle));
    }
    shared.done_cv.notify_all();
    shared.space_cv.notify_all();
}

/// Re-queue a job that parked: publish the `Parked` state, then push the
/// continuation back into its priority class — at the **front** when a
/// preemption forced the park (the victim must be next in line once the
/// preemptor is done), at the back when its step quantum expired
/// (round-robin). A cancel that raced the park is honored here (the
/// parked state will never resume — `finish_handle` deletes it); a
/// shutdown that raced it fails the handle loudly so joiners never hang
/// on a queue nobody drains.
fn repark_entry<R>(shared: &Shared<R>, handle: Arc<HandleShared<R>>, next: Job<R>, front: bool) {
    if handle.cancel.load(Ordering::SeqCst) {
        lock(&shared.tenants).entry(handle.tenant.clone()).or_default().cancelled += 1;
        finish_handle(shared, &handle, Outcome::Cancelled(None));
        return;
    }
    lock(&handle.state).park();
    lock(&shared.tenants).entry(handle.tenant.clone()).or_default().parked += 1;
    {
        let mut st = lock(&shared.state);
        if st.shutdown {
            drop(st);
            lock(&shared.tenants).entry(handle.tenant.clone()).or_default().failed += 1;
            finish_handle(
                shared,
                &handle,
                Outcome::Failed(anyhow::anyhow!(
                    "queue shut down while run #{} was parked — its checkpointed progress \
                     is discarded",
                    handle.seq
                )),
            );
            return;
        }
        let class = st.ready.entry(handle.priority).or_default();
        let entry = Entry { job: next, handle: Arc::clone(&handle) };
        if front {
            class.push_front(entry);
        } else {
            class.push_back(entry);
        }
        st.queued += 1;
    }
    shared.cv.notify_one();
}

/// Execute one popped entry to completion and publish its outcome. Shared
/// by the gated worker threads and the ungated inline drain, so both
/// builds run the same state machine.
fn run_entry<R>(shared: &Shared<R>, entry: Entry<R>) {
    let handle = entry.handle;
    // The exclusivity transition (Lifecycle::try_claim). A lost claim
    // means either a cancel raced the pop (finish_handle already
    // published the outcome) or a pack leader / transient cancel claim
    // owns the submission — the claimant publishes the outcome and the
    // queue entry is just a husk. Those claims only land on entries
    // whose job is recoverable elsewhere, so the dropped `entry.job`
    // loses nothing.
    if lock(&handle.state).try_claim().is_none() {
        return;
    }
    lock(&shared.tenants).entry(handle.tenant.clone()).or_default().picked += 1;
    if handle.preemptible {
        lock(&shared.running).insert(handle.seq, (handle.priority, Arc::clone(&handle.park)));
    }
    let token = CancelToken {
        flag: Arc::clone(&handle.cancel),
        park: Arc::clone(&handle.park),
        park_file: Arc::clone(&handle.park_file),
    };
    // The job classifies its own outcome (see [`JobYield`]): a cancel
    // honored mid-run comes back Cancelled with the partial output; a
    // cancel that raced a fully-completed job stays Done. A *panicking*
    // job must not unwind past here — it would kill the worker with the
    // handle stuck at Running, hanging every joiner forever (the pool's
    // scoped threads re-raise at scope exit; a long-lived queue has no
    // scope exit) — so the unwind is caught and reported as a failure.
    let job = entry.job;
    let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&token)));
    if handle.preemptible {
        lock(&shared.running).remove(&handle.seq);
    }
    let outcome = match caught {
        Err(payload) => Outcome::Failed(panic_error(payload)),
        Ok(Err(e)) => Outcome::Failed(e),
        Ok(Ok(JobYield::Parked { next, front })) => {
            // not terminal: checkpointed and re-queued to resume. (A
            // preemption flag raised *after* the job already yielded
            // costs at most one immediate repark on the next slot —
            // never a lost run.)
            repark_entry(shared, handle, next, front);
            return;
        }
        Ok(Ok(JobYield::Held)) => {
            // Not terminal: the job parked its continuation into
            // `shared.streams`; a feed/finish re-enqueues it. A cancel
            // that raced the hold (flag raised while the job was still
            // Running, so cancel()'s claim lost) is honored here —
            // mirroring repark_entry — by taking the held entry back
            // out and finishing Cancelled; a feed that got the entry
            // first just re-enqueues it, and the resumed slot observes
            // the flag cooperatively instead.
            if handle.cancel.load(Ordering::SeqCst)
                && lock(&shared.streams).remove(&handle.seq).is_some()
                && lock(&handle.state).try_claim().is_some()
            {
                lock(&shared.tenants).entry(handle.tenant.clone()).or_default().cancelled += 1;
                finish_handle(shared, &handle, Outcome::Cancelled(None));
            }
            return;
        }
        Ok(Ok(JobYield::Cancelled(out))) => Outcome::Cancelled(Some(out)),
        Ok(Ok(JobYield::Done(out))) => Outcome::Done(out),
    };
    {
        let mut tenants = lock(&shared.tenants);
        let t = tenants.entry(handle.tenant.clone()).or_default();
        match &outcome {
            Outcome::Done(_) => t.completed += 1,
            Outcome::Cancelled(_) => t.cancelled += 1,
            Outcome::Failed(_) => t.failed += 1,
        }
    }
    finish_handle(shared, &handle, outcome);
}

#[cfg(feature = "xla-shared-client")]
fn worker_loop<R: Send + 'static>(shared: &Shared<R>) {
    loop {
        let entry = {
            let mut st = lock(&shared.state);
            loop {
                if let Some(e) = take_next(shared, &mut st) {
                    break Some(e);
                }
                if st.shutdown {
                    break None;
                }
                st = shared.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        match entry {
            Some(e) => run_entry(shared, e),
            None => return,
        }
    }
}

/// The long-lived submission queue (see module docs). Generic over the
/// job result `R` so the scheduling/handle machinery is exercised by
/// plain closures in unit tests; training runs use `R = `[`RunOutput`]
/// via [`RunQueue::submit_run`].
pub struct RunQueue<R = RunOutput> {
    shared: Arc<Shared<R>>,
    /// Worker threads actually spawned: `jobs` with the
    /// `xla-shared-client` feature, 0 without it (inline drain on join).
    workers: usize,
    #[cfg(feature = "xla-shared-client")]
    threads: Vec<std::thread::JoinHandle<()>>,
}

fn new_shared<R>(paused: bool) -> Arc<Shared<R>> {
    Arc::new(Shared {
        state: Mutex::new(QueueState {
            ready: BTreeMap::new(),
            queued: 0,
            live: 0,
            capacity: None,
            done: VecDeque::new(),
            next_seq: 0,
            paused,
            shutdown: false,
        }),
        cv: Condvar::new(),
        done_cv: Condvar::new(),
        space_cv: Condvar::new(),
        tenants: Mutex::new(BTreeMap::new()),
        quotas: Mutex::new(BTreeMap::new()),
        windows: Mutex::new(BTreeMap::new()),
        quantum: Mutex::new(None),
        running: Mutex::new(BTreeMap::new()),
        pack_pool: Mutex::new(BTreeMap::new()),
        streams: Mutex::new(BTreeMap::new()),
    })
}

#[cfg(feature = "xla-shared-client")]
impl<R: Send + 'static> RunQueue<R> {
    /// A queue draining on `jobs` long-lived worker threads (clamped to
    /// at least 1).
    pub fn new(jobs: usize) -> RunQueue<R> {
        Self::build(jobs, false)
    }

    /// A queue whose workers hold until [`RunQueue::release`] — lets a
    /// caller submit a cold backlog and observe pure priority order.
    pub fn new_paused(jobs: usize) -> RunQueue<R> {
        Self::build(jobs, true)
    }

    fn build(jobs: usize, paused: bool) -> RunQueue<R> {
        let shared = new_shared(paused);
        let workers = jobs.max(1);
        let threads = (0..workers)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(shared.as_ref()))
            })
            .collect();
        RunQueue { shared, workers, threads }
    }
}

#[cfg(not(feature = "xla-shared-client"))]
impl<R: 'static> RunQueue<R> {
    /// Without the `xla-shared-client` feature no worker threads exist
    /// (nothing xla-backed may cross a thread — see `crate::sched`,
    /// §Thread-safety gate): submissions queue up and execute inline, in
    /// priority order, on the thread that calls [`RunHandle::join`].
    /// Same results, same ordering contract, no wall-clock overlap;
    /// `jobs` is accepted for CLI symmetry and ignored.
    pub fn new(jobs: usize) -> RunQueue<R> {
        let _ = jobs;
        Self::build(false)
    }

    /// Paused variant of [`RunQueue::new`]; [`RunQueue::release`] opens
    /// the queue for the inline drain.
    pub fn new_paused(jobs: usize) -> RunQueue<R> {
        let _ = jobs;
        Self::build(true)
    }

    fn build(paused: bool) -> RunQueue<R> {
        RunQueue { shared: new_shared(paused), workers: 0 }
    }
}

impl<R: 'static> RunQueue<R> {
    /// Submit one job under a tenant at a priority; returns immediately
    /// with the submission's [`RunHandle`]. Higher priorities pop first;
    /// within a class, tenants share fairly ([`take_next`]). Rejected
    /// with [`SubmitError`] only when a bounded depth
    /// ([`RunQueue::set_capacity`]) or a tenant quota
    /// ([`RunQueue::set_quota`]) is configured and hit — an unlimited
    /// queue never rejects. If the job returns with its cancel token
    /// raised, it joins as `Cancelled` with the (partial) output.
    #[cfg(feature = "xla-shared-client")]
    pub fn submit<F>(
        &self,
        tenant: &str,
        priority: i32,
        job: F,
    ) -> std::result::Result<RunHandle<R>, SubmitError>
    where
        F: FnOnce(&CancelToken) -> Result<R> + Send + 'static,
    {
        self.submit_boxed(tenant, priority, Box::new(move |t| yield_by_token(job(t)?, t)))
    }

    /// Submit one job under a tenant at a priority (inline-drain build:
    /// no `Send` bound — the job never crosses a thread). Admission and
    /// cancel classification as in the gated variant.
    #[cfg(not(feature = "xla-shared-client"))]
    pub fn submit<F>(
        &self,
        tenant: &str,
        priority: i32,
        job: F,
    ) -> std::result::Result<RunHandle<R>, SubmitError>
    where
        F: FnOnce(&CancelToken) -> Result<R> + 'static,
    {
        self.submit_boxed(tenant, priority, Box::new(move |t| yield_by_token(job(t)?, t)))
    }

    /// Like [`RunQueue::submit`], but **blocks for space** instead of
    /// rejecting when the queue is at capacity: the backpressure-absorbing
    /// submission path. Quota rejections stay errors (a quota only ever
    /// fills, so waiting cannot clear it). In the inline-drain build the
    /// calling thread *drains queued work itself* to free a slot —
    /// submitting to a paused full queue is a loud error, not a hang.
    #[cfg(feature = "xla-shared-client")]
    pub fn submit_wait<F>(&self, tenant: &str, priority: i32, job: F) -> Result<RunHandle<R>>
    where
        F: FnOnce(&CancelToken) -> Result<R> + Send + 'static,
    {
        let mut boxed: Job<R> = Box::new(move |t| yield_by_token(job(t)?, t));
        loop {
            match self.try_submit_inner(tenant, priority, boxed, false) {
                Ok(h) => return Ok(h),
                Err((
                    err @ (SubmitError::QuotaExceeded { .. } | SubmitError::RateLimited { .. }),
                    _,
                )) => return Err(err.into()),
                Err((SubmitError::Full { .. }, j)) => {
                    boxed = j;
                    let mut st = lock(&self.shared.state);
                    loop {
                        if st.shutdown {
                            anyhow::bail!("submit_wait: queue shut down while waiting for space");
                        }
                        if !st.capacity.is_some_and(|cap| st.live >= cap) {
                            break; // space freed — retry admission
                        }
                        st = self
                            .shared
                            .space_cv
                            .wait(st)
                            .unwrap_or_else(PoisonError::into_inner);
                    }
                }
            }
        }
    }

    /// Inline-drain variant of [`RunQueue::submit_wait`]: no workers
    /// exist, so the submitting thread runs queued entries itself until
    /// a slot frees. See the gated variant for the contract.
    #[cfg(not(feature = "xla-shared-client"))]
    pub fn submit_wait<F>(&self, tenant: &str, priority: i32, job: F) -> Result<RunHandle<R>>
    where
        F: FnOnce(&CancelToken) -> Result<R> + 'static,
    {
        let mut boxed: Job<R> = Box::new(move |t| yield_by_token(job(t)?, t));
        loop {
            match self.try_submit_inner(tenant, priority, boxed, false) {
                Ok(h) => return Ok(h),
                Err((
                    err @ (SubmitError::QuotaExceeded { .. } | SubmitError::RateLimited { .. }),
                    _,
                )) => return Err(err.into()),
                Err((SubmitError::Full { .. }, j)) => {
                    boxed = j;
                    let (entry, paused) = {
                        let mut st = lock(&self.shared.state);
                        let e = take_next(&self.shared, &mut st);
                        (e, st.paused)
                    };
                    match entry {
                        Some(e) => run_entry(&self.shared, e),
                        None if paused => anyhow::bail!(
                            "submit_wait on a paused full queue: this build has no worker \
                             threads (xla-shared-client off), so nothing can free a slot \
                             until RunQueue::release() is called"
                        ),
                        None => anyhow::bail!(
                            "submit_wait: queue is full but has no runnable work to drain \
                             (deadlock guard)"
                        ),
                    }
                }
            }
        }
    }

    fn submit_boxed(
        &self,
        tenant: &str,
        priority: i32,
        job: Job<R>,
    ) -> std::result::Result<RunHandle<R>, SubmitError> {
        self.try_submit_inner(tenant, priority, job, false).map_err(|(e, _)| e)
    }

    /// Admission + enqueue. On rejection the job is handed back so
    /// [`RunQueue::submit_wait`] can retry it (a boxed `FnOnce` cannot be
    /// rebuilt by the caller). `preemptible` marks park-aware training
    /// runs that may be preempted while running ([`run_entry`] registers
    /// them as victims).
    fn try_submit_inner(
        &self,
        tenant: &str,
        priority: i32,
        job: Job<R>,
        preemptible: bool,
    ) -> std::result::Result<RunHandle<R>, (SubmitError, Job<R>)> {
        if let Some(err) = self.admission_error(tenant) {
            return Err((err, job));
        }
        let handle = {
            let mut st = lock(&self.shared.state);
            if let Some(cap) = st.capacity {
                if st.live >= cap {
                    return Err((SubmitError::Full { capacity: cap }, job));
                }
            }
            let handle = Arc::new(HandleShared {
                seq: st.next_seq,
                tenant: tenant.to_string(),
                priority,
                cancel: Arc::new(AtomicBool::new(false)),
                park: Arc::new(AtomicBool::new(false)),
                park_file: Arc::new(Mutex::new(None)),
                preemptible,
                state: Mutex::new(Lifecycle::new()),
                cv: Condvar::new(),
            });
            st.next_seq += 1;
            st.ready
                .entry(priority)
                .or_default()
                .push_back(Entry { job, handle: Arc::clone(&handle) });
            st.queued += 1;
            st.live += 1;
            handle
        };
        lock(&self.shared.tenants).entry(tenant.to_string()).or_default().submitted += 1;
        self.shared.cv.notify_one();
        #[cfg(feature = "xla-shared-client")]
        self.maybe_preempt(priority);
        Ok(RunHandle { handle, shared: Arc::clone(&self.shared) })
    }

    /// Quota check at admission: `Some(err)` when the tenant's consumed
    /// totals meet or exceed a configured budget, or its open rate window
    /// ([`TenantQuota::per_window`]) is spent.
    fn admission_error(&self, tenant: &str) -> Option<SubmitError> {
        let quota = *lock(&self.shared.quotas).get(tenant)?;
        let t = lock(&self.shared.tenants).get(tenant).cloned().unwrap_or_default();
        let used = t.transfers.uploaded_bytes
            + t.transfers.downloaded_bytes
            + t.transfers.donated_bytes;
        if let Some(max) = quota.max_flops {
            if t.flops >= max {
                return Some(SubmitError::QuotaExceeded {
                    tenant: tenant.to_string(),
                    reason: format!(
                        "FLOP budget exhausted ({} of {max} chargeable FLOPs consumed)",
                        t.flops
                    ),
                });
            }
        }
        if let Some(max) = quota.max_bytes {
            if used >= max {
                return Some(SubmitError::QuotaExceeded {
                    tenant: tenant.to_string(),
                    reason: format!("transfer budget exhausted ({used} of {max} bytes moved)"),
                });
            }
        }
        if let Some((win_flops, win_bytes, window)) = quota.per_window {
            let now = Instant::now();
            let mut windows = lock(&self.shared.windows);
            let w = windows.entry(tenant.to_string()).or_insert_with(|| WindowState {
                started: now,
                flops_at_start: t.flops,
                bytes_at_start: used,
            });
            if now.duration_since(w.started) >= window {
                // Rollover: a fresh window opens now, with the tenant's
                // current totals as its baseline.
                *w = WindowState { started: now, flops_at_start: t.flops, bytes_at_start: used };
            }
            let spent_flops = t.flops.saturating_sub(w.flops_at_start);
            let spent_bytes = used.saturating_sub(w.bytes_at_start);
            if spent_flops >= win_flops || spent_bytes >= win_bytes {
                return Some(SubmitError::RateLimited {
                    tenant: tenant.to_string(),
                    retry_after: window.saturating_sub(now.duration_since(w.started)),
                });
            }
        }
        None
    }

    /// Best-effort preemption on submission: if every worker is occupied
    /// by a preemptible run and the lowest-priority one (youngest on
    /// ties) sits **below** the new submission's class, raise its park
    /// flag — it checkpoints at its next SGD step boundary, re-enters at
    /// the *front* of its class, and the freed worker picks up the
    /// higher-priority work. Best-effort: workers running non-preemptible
    /// jobs (plain closures, packed groups) are invisible here, and a
    /// victim that finishes before the flag lands just completes.
    #[cfg(feature = "xla-shared-client")]
    fn maybe_preempt(&self, priority: i32) {
        let running = lock(&self.shared.running);
        if running.len() < self.workers {
            return; // an idle worker can take the new submission
        }
        let victim = running
            .iter()
            .min_by_key(|(seq, (prio, _))| (*prio, std::cmp::Reverse(**seq)));
        if let Some((_, (vprio, flag))) = victim {
            if *vprio < priority {
                flag.store(true, Ordering::SeqCst);
            }
        }
    }

    /// Bound the queue's in-flight depth (queued + running + parked
    /// submissions): once `cap` are admitted and unfinished,
    /// [`RunQueue::submit`] rejects with [`SubmitError::Full`] and
    /// [`RunQueue::submit_wait`] blocks. Parked re-entries never
    /// re-check capacity — they were admitted once and stay admitted.
    pub fn set_capacity(&self, cap: usize) {
        lock(&self.shared.state).capacity = Some(cap.max(1));
    }

    /// Install (or replace) a tenant's admission budget; see
    /// [`TenantQuota`]. Replacing a quota also discards the tenant's open
    /// rate window — the next admission opens a fresh one baselined at
    /// the tenant's current totals.
    pub fn set_quota(&self, tenant: &str, quota: TenantQuota) {
        lock(&self.shared.quotas).insert(tenant.to_string(), quota);
        lock(&self.shared.windows).remove(tenant);
    }

    /// Fair-share time-slicing for park-aware training runs
    /// ([`RunQueue::submit_run`]): each execution slot parks the run
    /// after `steps` Adam steps (clamped to ≥ 1) and re-queues it at the
    /// back of its priority class, so same-class tenants interleave at
    /// step granularity instead of run granularity. Unset (the default)
    /// runs execute to completion per slot.
    pub fn set_step_quantum(&self, steps: usize) {
        *lock(&self.shared.quantum) = Some(steps.max(1));
    }

    /// Open a paused queue ([`RunQueue::new_paused`]). No-op otherwise.
    pub fn release(&self) {
        lock(&self.shared.state).paused = false;
        self.shared.cv.notify_all();
    }

    /// Submissions still in the queue structure (not yet picked up;
    /// includes queued-then-cancelled entries no worker has reaped yet
    /// and parked re-entries waiting to resume).
    pub fn pending(&self) -> usize {
        lock(&self.shared.state).queued
    }

    /// Admitted-and-unfinished submissions (queued + running + parked) —
    /// the depth [`RunQueue::set_capacity`] bounds.
    pub fn live(&self) -> usize {
        lock(&self.shared.state).live
    }

    /// Worker threads this queue actually spawned (0 = inline drain; see
    /// [`RunQueue::new`] in builds without the thread-safety feature).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Point-in-time copy of every tenant's accounting.
    pub fn tenants(&self) -> BTreeMap<String, TenantStats> {
        lock(&self.shared.tenants).clone()
    }

    /// One tenant's accounting (default-zero if it never submitted).
    pub fn tenant(&self, name: &str) -> TenantStats {
        lock(&self.shared.tenants).get(name).cloned().unwrap_or_default()
    }

    /// The next finished submission in **completion order** — a finished
    /// high-priority run streams out immediately instead of waiting for
    /// earlier submissions to join first (the ROADMAP's
    /// completion-order-streaming item). Blocks while live work remains
    /// (gated build); returns `Ok(None)` once no admitted submission is
    /// unfinished and the stream is drained. Submissions whose outcome a
    /// [`RunHandle::join`] already took are skipped — each outcome is
    /// delivered exactly once, on whichever side asks first.
    #[cfg(feature = "xla-shared-client")]
    pub fn next_completion(&self) -> Result<Option<Completion<R>>> {
        loop {
            let handle = {
                let mut st = lock(&self.shared.state);
                loop {
                    if let Some(h) = st.done.pop_front() {
                        break h;
                    }
                    if st.live == 0 {
                        return Ok(None);
                    }
                    st = self
                        .shared
                        .done_cv
                        .wait(st)
                        .unwrap_or_else(PoisonError::into_inner);
                }
            };
            if let Some(c) = claim_completion(handle) {
                return Ok(Some(c));
            }
        }
    }

    /// Inline-drain variant of [`RunQueue::next_completion`]: no workers
    /// exist, so this call *is* the execution pump — it runs queued
    /// entries on the calling thread until one finishes. A still-paused
    /// queue with live work is a loud error (nothing else could ever run
    /// it), matching [`RunHandle::join`]'s contract.
    #[cfg(not(feature = "xla-shared-client"))]
    pub fn next_completion(&self) -> Result<Option<Completion<R>>> {
        loop {
            let (done, entry, paused) = {
                let mut st = lock(&self.shared.state);
                if let Some(h) = st.done.pop_front() {
                    (Some(h), None, st.paused)
                } else if st.live == 0 {
                    return Ok(None);
                } else {
                    let e = take_next(&self.shared, &mut st);
                    (None, e, st.paused)
                }
            };
            if let Some(h) = done {
                if let Some(c) = claim_completion(h) {
                    return Ok(Some(c));
                }
                continue; // outcome already joined elsewhere: skip
            }
            match entry {
                Some(e) => run_entry(&self.shared, e),
                None if paused => anyhow::bail!(
                    "next_completion on a paused queue: this build has no worker threads \
                     (xla-shared-client off), so nothing can run the remaining submissions \
                     until RunQueue::release() is called"
                ),
                None => anyhow::bail!(
                    "next_completion: live submissions remain but nothing is runnable \
                     (deadlock guard)"
                ),
            }
        }
    }

    /// Iterator over [`RunQueue::next_completion`]: drains finished
    /// submissions in completion order until no live work remains.
    /// `for c in q.completions() { ... }`
    pub fn completions(&self) -> Completions<'_, R> {
        Completions { queue: self }
    }
}

/// One delivered submission from the completions stream: which
/// submission it was (`seq`, assigned at submit time), whose it was, and
/// how it ended (`Err` = the job failed, with the submission index in
/// the error context — same classification as [`RunHandle::join`]).
pub struct Completion<R = RunOutput> {
    pub seq: u64,
    pub tenant: String,
    pub result: Result<RunResult<R>>,
}

/// Take a finished handle's outcome for the completions stream. `None`
/// when a `join` got there first (the stream skips it — exactly-once
/// delivery across both surfaces).
fn claim_completion<R>(h: Arc<HandleShared<R>>) -> Option<Completion<R>> {
    // take_outcome is None when a `join` got there first (the stream
    // skips it) — and, vacuously, on a non-terminal state, which cannot
    // occur here: only finish_handle queues into `done`, Finished first.
    let outcome = lock(&h.state).take_outcome()?;
    let result = match outcome {
        Outcome::Done(r) => Ok(RunResult::Done(r)),
        Outcome::Cancelled(r) => Ok(RunResult::Cancelled(r)),
        Outcome::Failed(e) => Err(e.context(format!("queued run #{}", h.seq))),
    };
    Some(Completion { seq: h.seq, tenant: h.tenant.clone(), result })
}

/// See [`RunQueue::completions`].
pub struct Completions<'a, R = RunOutput> {
    queue: &'a RunQueue<R>,
}

impl<R: 'static> Iterator for Completions<'_, R> {
    type Item = Result<Completion<R>>;

    fn next(&mut self) -> Option<Self::Item> {
        match self.queue.next_completion() {
            Ok(Some(c)) => Some(Ok(c)),
            Ok(None) => None,
            Err(e) => Some(Err(e)),
        }
    }
}

/// What a multi-slot (parked/resumed) run has already billed to its
/// tenant: whole-run totals as of the last park. Each slot folds only
/// the delta past these marks, so a run that parks N times is billed
/// **exactly once** for every step, FLOP, and byte — including the
/// park/resume sync traffic itself, which the trainer's carried meter
/// charges to the run.
#[derive(Debug, Default, Clone, Copy)]
struct Billed {
    adam_steps: u64,
    sim_steps: u64,
    ff_stages: u64,
    flops: u64,
    transfers: TransferSnapshot,
}

/// Fold one finished run's accounting into its tenant, net of what
/// earlier slots already billed (steps, FLOPs, wall-clock, and the
/// run's **exact** transfer meter). `seconds` is per-slot wall-clock and
/// is always added whole.
fn fold_final(shared: &Shared<RunOutput>, tenant: &str, billed: Billed, out: &RunOutput) {
    let mut tenants = lock(&shared.tenants);
    let t = tenants.entry(tenant.to_string()).or_default();
    t.adam_steps += (out.summary.adam_steps as u64).saturating_sub(billed.adam_steps);
    t.sim_steps += (out.summary.sim_steps as u64).saturating_sub(billed.sim_steps);
    t.ff_stages += (out.stages.len() as u64).saturating_sub(billed.ff_stages);
    t.flops += out.summary.flops.total().saturating_sub(billed.flops);
    t.seconds += out.seconds;
    t.transfers = t.transfers.plus(&out.summary.transfers.since(&billed.transfers));
}

/// Fold one finished run's per-run accounting into its tenant (steps,
/// FLOPs, wall-clock, and the run's **exact** transfer meter).
fn fold_run_stats(shared: &Shared<RunOutput>, tenant: &str, out: &RunOutput) {
    fold_final(shared, tenant, Billed::default(), out);
}

/// Bill a *parking* slot's progress delta to its tenant and return the
/// new whole-run billing marks for the next slot. The park state's
/// carried meter already includes the park-sync downloads (read after
/// `sync_host`), so the parked side pays for its own checkpoint.
fn fold_park_progress(
    shared: &Shared<RunOutput>,
    tenant: &str,
    billed: Billed,
    state: &ParkState,
    seconds: f64,
) -> Billed {
    let now = Billed {
        adam_steps: state.adam_steps as u64,
        sim_steps: state
            .records
            .iter()
            .filter(|r| r.kind == StepKind::FastForward)
            .count() as u64,
        ff_stages: state.stages.len() as u64,
        flops: state.flops.total(),
        transfers: state.transfers,
    };
    let mut tenants = lock(&shared.tenants);
    let t = tenants.entry(tenant.to_string()).or_default();
    t.adam_steps += now.adam_steps.saturating_sub(billed.adam_steps);
    t.sim_steps += now.sim_steps.saturating_sub(billed.sim_steps);
    t.ff_stages += now.ff_stages.saturating_sub(billed.ff_stages);
    t.flops += now.flops.saturating_sub(billed.flops);
    t.seconds += seconds;
    t.transfers = t.transfers.plus(&now.transfers.since(&billed.transfers));
    now
}

/// Fresh on-disk location for one submission's parked state.
fn fresh_park_path() -> PathBuf {
    static NEXT: AtomicU64 = AtomicU64::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!("ffq-park-{}-{n}.ffpk", std::process::id()))
}

/// The body of one park-aware training submission's execution slot:
/// resume from the park file if an earlier slot parked, run under the
/// cancel + park flags and the queue's step quantum, then either finish
/// (billing the final delta) or checkpoint to disk and yield a
/// continuation that re-enters here on the next slot. A park file that
/// fails to load (truncated, corrupted — see `train::checkpoint`'s
/// fault-injection tests) fails the submission loudly; it never resumes
/// from torn state, and [`finish_handle`] deletes the file.
fn run_park_aware(
    rt: Arc<Runtime>,
    artifacts: Arc<ArtifactCache>,
    shared: Arc<Shared<RunOutput>>,
    spec: RunSpec,
    tenant: String,
    billed: Billed,
    token: &CancelToken,
) -> Result<JobYield<RunOutput>> {
    let quantum = *lock(&shared.quantum);
    let resume_file = token.park_file();
    let resume_state = match &resume_file {
        Some(path) => Some(load_park_state(path).with_context(|| {
            format!("resuming run '{}' from parked state {}", spec.label, path.display())
        })?),
        None => None,
    };
    let slot = execute_run_resumable(
        &rt,
        &artifacts,
        &spec,
        Some(token.flag()),
        Some(token.park_flag()),
        quantum,
        resume_state.as_ref(),
    )?;
    match slot {
        SlotOutcome::Parked { state, preempted, seconds } => {
            let path = resume_file.unwrap_or_else(fresh_park_path);
            save_park_state(&path, &state).with_context(|| {
                format!("parking run '{}' to {}", spec.label, path.display())
            })?;
            token.set_park_file(path);
            let billed = fold_park_progress(&shared, &tenant, billed, &state, seconds);
            let next: Job<RunOutput> = Box::new(move |tok: &CancelToken| {
                run_park_aware(rt, artifacts, shared, spec, tenant, billed, tok)
            });
            Ok(JobYield::Parked { next, front: preempted })
        }
        SlotOutcome::Finished(out) => {
            fold_final(&shared, &tenant, billed, &out);
            // The trainer's summary is the authoritative cancel marker: a
            // cancel that raced a fully-delivered run stays Done.
            if out.summary.cancelled {
                Ok(JobYield::Cancelled(out))
            } else {
                Ok(JobYield::Done(out))
            }
        }
    }
}

/// Shared feed ledger between a [`StreamHandle`] and its run's execution
/// slots: how many examples the tenant has appended, and whether the
/// stream is closed. The owning [`StreamCtl::feed`] mutex also
/// serializes the starved-hold handshake (see [`Shared::streams`]).
struct StreamFeed {
    fed_examples: u64,
    finished: bool,
}

/// Control block of one streaming submission
/// ([`RunQueue::submit_stream`]), shared by the [`StreamHandle`] and the
/// job's slots.
struct StreamCtl {
    feed: Mutex<StreamFeed>,
}

/// The body of one **streaming** submission's execution slot
/// ([`RunQueue::submit_stream`]): like [`run_park_aware`], but the run
/// may only consume examples its tenant has already fed (one SGD step
/// per `global_batch` examples). With no consumable step and the stream
/// still open, the slot **holds**: it publishes `Parked` and moves its
/// continuation into [`Shared::streams`] under the feed lock — so a
/// racing `feed` either lands before starvation is observed or finds
/// the held entry to re-enqueue, never between — and yields the worker
/// without constructing a trainer. With data available it runs with the
/// step quantum clamped to the consumable budget, parking at exactly
/// the data horizon through the ordinary park machinery (same billing
/// folds, same park files). Once the stream is finished the remaining
/// steps run as a plain bounded slot ending in the normal final eval,
/// so the streamed run's losses and final loss are bit-identical to a
/// batch run over the same example sequence.
fn run_stream_slot(
    rt: Arc<Runtime>,
    artifacts: Arc<ArtifactCache>,
    shared: Arc<Shared<RunOutput>>,
    spec: RunSpec,
    tenant: String,
    billed: Billed,
    ctl: Arc<StreamCtl>,
    handle: Arc<HandleShared<RunOutput>>,
    token: &CancelToken,
) -> Result<JobYield<RunOutput>> {
    let max_steps = match &spec.stop {
        StopRule::MaxSteps(n) => *n,
        _ => unreachable!("submit_stream admits StopRule::MaxSteps only"),
    };
    let resume_file = token.park_file();
    let resume_state = match &resume_file {
        Some(path) => Some(load_park_state(path).with_context(|| {
            format!(
                "resuming streaming run '{}' from parked state {}",
                spec.label,
                path.display()
            )
        })?),
        None => None,
    };
    let consumed = resume_state.as_ref().map_or(0, |s| s.adam_steps);
    let per_step = (spec.cfg.global_batch.max(1)) as u64;
    let (target, finished) = {
        let feed = lock(&ctl.feed);
        let target = ((feed.fed_examples / per_step) as usize).min(max_steps);
        if target <= consumed && !feed.finished {
            // Data-starved: hold. Publish Parked *before* registering
            // the continuation — a feed may re-enqueue it the instant
            // it lands in `streams`, and a popped entry whose handle
            // were still Running would lose its claim and strand the
            // joiner. The feed lock is held throughout, so the wake
            // cannot be lost.
            lock(&handle.state).park();
            let next: Job<RunOutput> = {
                let rt = Arc::clone(&rt);
                let artifacts = Arc::clone(&artifacts);
                let sh = Arc::clone(&shared);
                let ctl = Arc::clone(&ctl);
                let h = Arc::clone(&handle);
                Box::new(move |tok: &CancelToken| {
                    run_stream_slot(rt, artifacts, sh, spec, tenant, billed, ctl, h, tok)
                })
            };
            lock(&shared.streams)
                .insert(handle.seq, Entry { job: next, handle: Arc::clone(&handle) });
            return Ok(JobYield::Held);
        }
        (target, feed.finished)
    };
    // A finished stream runs out its fed total and ends with the normal
    // final eval; an open stream keeps the full stop bound but clamps
    // the slot's quantum to the consumable budget so it parks exactly
    // at the data horizon.
    let slot_spec = RunSpec {
        label: spec.label.clone(),
        cfg: spec.cfg.clone(),
        stop: StopRule::MaxSteps(if finished { target } else { max_steps }),
        base: spec.base.clone(),
        drain_interval: spec.drain_interval,
    };
    let quantum = {
        let q = *lock(&shared.quantum);
        if finished {
            q
        } else {
            Some(q.map_or(target - consumed, |q| q.min(target - consumed)))
        }
    };
    let slot = execute_run_resumable(
        &rt,
        &artifacts,
        &slot_spec,
        Some(token.flag()),
        Some(token.park_flag()),
        quantum,
        resume_state.as_ref(),
    )?;
    match slot {
        SlotOutcome::Parked { state, preempted, seconds } => {
            let path = resume_file.unwrap_or_else(fresh_park_path);
            save_park_state(&path, &state).with_context(|| {
                format!("parking streaming run '{}' to {}", spec.label, path.display())
            })?;
            token.set_park_file(path);
            let billed = fold_park_progress(&shared, &tenant, billed, &state, seconds);
            let next: Job<RunOutput> = Box::new(move |tok: &CancelToken| {
                run_stream_slot(rt, artifacts, shared, spec, tenant, billed, ctl, handle, tok)
            });
            Ok(JobYield::Parked { next, front: preempted })
        }
        SlotOutcome::Finished(out) => {
            fold_final(&shared, &tenant, billed, &out);
            if out.summary.cancelled {
                Ok(JobYield::Cancelled(out))
            } else {
                Ok(JobYield::Done(out))
            }
        }
    }
}

/// The pack key two submissions must share to ride one batched dispatch:
/// same artifact (same programs and batch geometry), same priority (the
/// leader must not pull work ahead of its class), same step count
/// (members stay in lock-step to the end), same eval-set size (final
/// eval chunks stack), same `global_batch`, and the same frozen-weight
/// source — a shared base checkpoint (by identity) or an equal seed,
/// since `init_params` derives the frozen base from the seed and the
/// batched programs share one unstacked base across the group
/// (`run_batched_group` re-verifies this bitwise at claim time).
///
/// `None` means the spec can never pack (loss-targeted stop rule or FF
/// stages) and should be submitted solo.
fn pack_signature(spec: &RunSpec, priority: i32) -> Option<String> {
    let steps = match &spec.stop {
        StopRule::MaxSteps(n) => *n,
        _ => return None,
    };
    if spec.cfg.ff.enabled {
        return None;
    }
    let frozen_src = match &spec.base {
        Some(b) => format!("base:{:p}", Arc::as_ptr(b)),
        None => format!("seed:{}", spec.cfg.seed),
    };
    Some(format!(
        "{}|p{priority}|n{steps}|gb{}|te{}|{frozen_src}",
        spec.cfg.artifact, spec.cfg.global_batch, spec.cfg.test_examples
    ))
}

/// Drop one mate (identified by its slot) from the pack pool, if it is
/// still registered.
fn unregister_mate<R>(shared: &Shared<R>, sig: &str, slot: &Arc<Mutex<Option<PackData>>>) {
    let mut pool = lock(&shared.pack_pool);
    if let Some(list) = pool.get_mut(sig) {
        list.retain(|m| !Arc::ptr_eq(&m.data, slot));
        if list.is_empty() {
            pool.remove(sig);
        }
    }
}

/// Publish a claimed sibling's outcome: tenant counters first (matching
/// [`run_entry`]'s order), then the terminal transition via
/// [`finish_handle`] (joiners woken, completions stream fed, `live`
/// decremented).
fn publish_mate(
    shared: &Shared<RunOutput>,
    handle: &Arc<HandleShared<RunOutput>>,
    outcome: Outcome<RunOutput>,
) {
    {
        let mut tenants = lock(&shared.tenants);
        let t = tenants.entry(handle.tenant.clone()).or_default();
        match &outcome {
            Outcome::Done(_) => t.completed += 1,
            Outcome::Cancelled(_) => t.cancelled += 1,
            Outcome::Failed(_) => t.failed += 1,
        }
    }
    finish_handle(shared, handle, outcome);
}

/// Run one member solo (the no-mates fallback and the odd-size
/// remainder of a pack), folding its stats and classifying from the
/// trainer's authoritative `summary.cancelled`.
fn run_solo_member(
    rt: &Arc<Runtime>,
    artifacts: &ArtifactCache,
    shared: &Shared<RunOutput>,
    data: PackData,
    cancel: Option<Arc<AtomicBool>>,
) -> Result<JobYield<RunOutput>> {
    let PackData { spec, tenant } = data;
    let out = execute_run_cancellable(rt, artifacts, spec, cancel)?;
    fold_run_stats(shared, &tenant, &out);
    // The trainer's summary is the authoritative cancel marker: a
    // cancel that raced a fully-delivered run stays Done (and bills as
    // completed), not Cancelled.
    if out.summary.cancelled {
        Ok(JobYield::Cancelled(out))
    } else {
        Ok(JobYield::Done(out))
    }
}

impl RunQueue<RunOutput> {
    /// Submit one whole training run: the `Trainer` is constructed and
    /// driven on whichever worker pops the submission (inline at `join`
    /// in gated-off builds), with the handle's cancel flag installed so
    /// [`RunHandle::cancel`] lands at the next step boundary. The
    /// tenant's [`TenantStats`] are folded in from the run's summary when
    /// it finishes — including the run's **exact** per-engine transfer
    /// bytes.
    pub fn submit_run(
        &self,
        rt: &Arc<Runtime>,
        artifacts: &Arc<ArtifactCache>,
        spec: RunSpec,
        priority: i32,
        tenant: &str,
    ) -> std::result::Result<RunHandle<RunOutput>, SubmitError> {
        let rt = Arc::clone(rt);
        let artifacts = Arc::clone(artifacts);
        let shared = Arc::clone(&self.shared);
        let tenant_name = tenant.to_string();
        self.try_submit_inner(
            tenant,
            priority,
            Box::new(move |token: &CancelToken| {
                run_park_aware(rt, artifacts, shared, spec, tenant_name, Billed::default(), token)
            }),
            true, // park-aware: a valid preemption victim while running
        )
        .map_err(|(e, _)| e)
    }

    /// Like [`RunQueue::submit_run`], but opted into **same-artifact
    /// packing**: when this submission reaches the front of the queue
    /// and K−1 compatible submissions (same [`pack_signature`]) are
    /// still waiting behind it, the popped job becomes the *pack
    /// leader* — it claims them out of the queue and drives all K runs
    /// as one `*_batched{K}` program group (2 dispatches per step for
    /// the whole group — see `rust/src/train/batched.rs`), then
    /// publishes every member's [`RunOutput`] to its own handle.
    ///
    /// The contract is unchanged from solo submission: each member's
    /// per-step losses and final test loss are **bit-identical** to
    /// running it alone, its `summary.transfers` is its exact byte
    /// slice of the group traffic, and its tenant is billed exactly as
    /// if it ran solo. Cancellation changes granularity only: a queued
    /// cancel still prevents execution, but once a group is in flight
    /// its members run to the end of the group (cancel lands at the
    /// batch boundary, `docs/step-pipeline.md`).
    ///
    /// Specs that can never pack (loss-targeted stop, FF stages) or
    /// whose artifact ships no batched programs fall back to solo
    /// execution automatically.
    /// Packed groups are **not** park-aware: an in-flight `*_batched{K}`
    /// group has no per-member park point, so preemption composes with
    /// packing at group boundaries only (a packed submission is never a
    /// preemption victim; the queue preempts around the group, not
    /// through it — `docs/queue-serving.md`).
    pub fn submit_run_packable(
        &self,
        rt: &Arc<Runtime>,
        artifacts: &Arc<ArtifactCache>,
        spec: RunSpec,
        priority: i32,
        tenant: &str,
    ) -> std::result::Result<RunHandle<RunOutput>, SubmitError> {
        let sig = match pack_signature(&spec, priority) {
            Some(sig) => sig,
            None => return self.submit_run(rt, artifacts, spec, priority, tenant),
        };
        let rt = Arc::clone(rt);
        let artifacts = Arc::clone(artifacts);
        let shared = Arc::clone(&self.shared);
        let slot = Arc::new(Mutex::new(Some(PackData {
            spec,
            tenant: tenant.to_string(),
        })));
        let job = {
            let (sig, slot) = (sig.clone(), Arc::clone(&slot));
            Box::new(move |token: &CancelToken| {
                lead_or_run_solo(&rt, &artifacts, &shared, &sig, &slot, token)
            })
        };
        let handle = self.submit_boxed(tenant, priority, job)?;
        // Register for claiming *after* submission (the handle must
        // exist first). If a worker already popped and ran the job in
        // between, the slot is empty and the registration is a stale
        // husk future leaders drop on sight.
        lock(&self.shared.pack_pool)
            .entry(sig)
            .or_default()
            .push(PackMate { handle: Arc::clone(&handle.handle), data: slot });
        Ok(handle)
    }

    /// Submit a **streaming** training run (module docs, §Streaming
    /// runs): admitted now, but it may only consume examples its tenant
    /// appends afterwards through the returned [`StreamHandle`] — one
    /// SGD step per `cfg.global_batch` examples fed. The spec's stop
    /// rule must be [`StopRule::MaxSteps`] (the stream's upper bound);
    /// [`StreamHandle::finish`] ends the run earlier, at whatever was
    /// fed. Admission (capacity, quotas, rate windows) and the handle
    /// contract (poll/join/cancel/park, completions stream, fair share,
    /// preemption) are identical to [`RunQueue::submit_run`].
    ///
    /// Unlike `submit_run` this is a bespoke submit path: the job
    /// closure needs its *own* handle (to hold itself in
    /// [`Shared::streams`] when starved), so handle construction and
    /// enqueue happen in one state-lock critical section — a worker
    /// popping the entry the instant it lands still finds a complete
    /// closure.
    pub fn submit_stream(
        &self,
        rt: &Arc<Runtime>,
        artifacts: &Arc<ArtifactCache>,
        spec: RunSpec,
        priority: i32,
        tenant: &str,
    ) -> Result<(RunHandle<RunOutput>, StreamHandle)> {
        if !matches!(spec.stop, StopRule::MaxSteps(_)) {
            anyhow::bail!(
                "submit_stream requires StopRule::MaxSteps (the stream's upper bound); \
                 run '{}' uses a different stop rule — close the stream with \
                 StreamHandle::finish to end it early",
                spec.label
            );
        }
        if let Some(err) = self.admission_error(tenant) {
            return Err(err.into());
        }
        let ctl = Arc::new(StreamCtl {
            feed: Mutex::new(StreamFeed { fed_examples: 0, finished: false }),
        });
        let rt = Arc::clone(rt);
        let artifacts = Arc::clone(artifacts);
        let shared = Arc::clone(&self.shared);
        let tenant_name = tenant.to_string();
        let handle = {
            let mut st = lock(&self.shared.state);
            if let Some(cap) = st.capacity {
                if st.live >= cap {
                    return Err(anyhow::Error::from(SubmitError::Full { capacity: cap }));
                }
            }
            let handle = Arc::new(HandleShared {
                seq: st.next_seq,
                tenant: tenant.to_string(),
                priority,
                cancel: Arc::new(AtomicBool::new(false)),
                park: Arc::new(AtomicBool::new(false)),
                park_file: Arc::new(Mutex::new(None)),
                preemptible: true, // park-aware, same as submit_run
                state: Mutex::new(Lifecycle::new()),
                cv: Condvar::new(),
            });
            st.next_seq += 1;
            let job: Job<RunOutput> = {
                let ctl = Arc::clone(&ctl);
                let h = Arc::clone(&handle);
                Box::new(move |token: &CancelToken| {
                    run_stream_slot(
                        rt,
                        artifacts,
                        shared,
                        spec,
                        tenant_name,
                        Billed::default(),
                        ctl,
                        h,
                        token,
                    )
                })
            };
            st.ready
                .entry(priority)
                .or_default()
                .push_back(Entry { job, handle: Arc::clone(&handle) });
            st.queued += 1;
            st.live += 1;
            handle
        };
        lock(&self.shared.tenants).entry(tenant.to_string()).or_default().submitted += 1;
        self.shared.cv.notify_one();
        #[cfg(feature = "xla-shared-client")]
        self.maybe_preempt(priority);
        Ok((
            RunHandle { handle: Arc::clone(&handle), shared: Arc::clone(&self.shared) },
            StreamHandle { ctl, handle, shared: Arc::clone(&self.shared) },
        ))
    }
}

/// The tenant's side of one streaming submission
/// ([`RunQueue::submit_stream`]): append examples with
/// [`StreamHandle::feed`], close the stream with
/// [`StreamHandle::finish`]. Both wake the run if its slot is holding
/// for data. Feeding a finished stream is a no-op (the run's step
/// budget is already fixed), as is feeding after cancel — the husk is
/// reaped at the next pop.
pub struct StreamHandle {
    ctl: Arc<StreamCtl>,
    handle: Arc<HandleShared<RunOutput>>,
    shared: Arc<Shared<RunOutput>>,
}

impl StreamHandle {
    /// Append `examples` training examples to the stream. The run may
    /// take one more SGD step per `cfg.global_batch` examples fed
    /// (a partial batch stays buffered until topped up).
    pub fn feed(&self, examples: u64) {
        self.push(examples, false);
    }

    /// Close the stream: the run consumes whatever remains fed (capped
    /// by its `MaxSteps` bound) and finishes with the normal final
    /// eval. Idempotent.
    pub fn finish(&self) {
        self.push(0, true);
    }

    /// Total examples fed so far.
    pub fn fed(&self) -> u64 {
        lock(&self.ctl.feed).fed_examples
    }

    fn push(&self, examples: u64, finish: bool) {
        let held = {
            let mut feed = lock(&self.ctl.feed);
            if feed.finished {
                return; // the step budget is already fixed
            }
            feed.fed_examples += examples;
            if finish {
                feed.finished = true;
            }
            // Under the same feed lock the starved hold uses: either
            // the slot saw this feed's total, or its held entry is here.
            lock(&self.shared.streams).remove(&self.handle.seq)
        };
        let Some(entry) = held else { return };
        // Re-enqueue the held continuation at the back of its class —
        // shutdown-aware, mirroring repark_entry: joiners must never
        // hang on a queue nobody drains.
        {
            let mut st = lock(&self.shared.state);
            if st.shutdown {
                drop(st);
                if lock(&entry.handle.state).try_claim().is_some() {
                    lock(&self.shared.tenants)
                        .entry(entry.handle.tenant.clone())
                        .or_default()
                        .failed += 1;
                    finish_handle(
                        &self.shared,
                        &entry.handle,
                        Outcome::Failed(anyhow::anyhow!(
                            "queue shut down while streaming run #{} was waiting for data \
                             — its checkpointed progress is discarded",
                            entry.handle.seq
                        )),
                    );
                }
                return;
            }
            st.ready.entry(entry.handle.priority).or_default().push_back(entry);
            st.queued += 1;
        }
        self.shared.cv.notify_one();
    }
}

/// The body of a packable submission's job: reclaim the spec from the
/// pack slot, then either lead a batched group over compatible waiting
/// submissions or fall back to solo execution.
fn lead_or_run_solo(
    rt: &Arc<Runtime>,
    artifacts: &Arc<ArtifactCache>,
    shared: &Arc<Shared<RunOutput>>,
    sig: &str,
    slot: &Arc<Mutex<Option<PackData>>>,
    token: &CancelToken,
) -> Result<JobYield<RunOutput>> {
    // Exclusive by the Queued→Running transition run_entry just made:
    // leaders only claim slots of still-Queued handles, so our own slot
    // is necessarily intact here.
    let own = lock(slot)
        .take()
        .ok_or_else(|| anyhow::anyhow!("pack slot emptied while queued (claim protocol bug)"))?;
    unregister_mate(shared.as_ref(), sig, slot);

    let art = artifacts.load(rt, &own.spec.cfg.artifact)?;
    if !pack_eligible(&art.manifest, &own.spec.cfg, &own.spec.stop) {
        return run_solo_member(rt, artifacts, shared, own, Some(token.flag()));
    }
    let steps = match &own.spec.stop {
        StopRule::MaxSteps(n) => *n,
        _ => unreachable!("pack_signature admits MaxSteps only"),
    };
    let sizes = art.manifest.batched_group_sizes();
    let max_r = *sizes.last().expect("pack_eligible implies batched programs");

    // Claim compatible waiting submissions, oldest first, up to the
    // largest emitted group size. A claim flips the sibling's handle
    // Queued → Running under its state lock — the same transition
    // run_entry makes — so each submission is owned exactly once no
    // matter which side gets there first.
    let mut members = vec![own];
    let mut claimed: Vec<Arc<HandleShared<RunOutput>>> = Vec::new();
    {
        let mut pool = lock(&shared.pack_pool);
        if let Some(list) = pool.get_mut(sig) {
            let mut kept = Vec::new();
            for mate in list.drain(..) {
                if members.len() >= max_r {
                    kept.push(mate);
                    continue;
                }
                let mut st = lock(&mate.handle.state);
                if st.phase() != Phase::Queued {
                    // cancelled while queued, or already running solo:
                    // drop the stale pool entry, never execute it here
                    continue;
                }
                match lock(&mate.data).take() {
                    Some(d) => {
                        // the state lock is held since the Queued check,
                        // so this leader claim cannot lose the race
                        let won = st.try_claim_queued();
                        assert!(won, "phase checked Queued under the held state lock");
                        drop(st);
                        members.push(d);
                        claimed.push(Arc::clone(&mate.handle));
                    }
                    None => {} // stale husk (job already ran): drop
                }
            }
            if kept.is_empty() {
                pool.remove(sig);
            } else {
                *list = kept;
            }
        }
    }

    // The group runs at the largest emitted size we filled; members
    // beyond it (odd remainders, e.g. 3 claimed with sizes {2, 4}) run
    // solo on this same worker rather than being released — a released
    // entry's queue slot may already have been reaped, which would
    // strand its joiner forever.
    let group_r = sizes.iter().rev().find(|&&r| r <= members.len()).copied();
    let group_r = match group_r {
        Some(r) => r,
        None => {
            // Hard assert: dropping a claimed entry here would strand its
            // joiner forever (its queue slot is already gone) — the
            // exactly-once-delivery contract the lifecycle model proves.
            assert!(claimed.is_empty(), "solo fallback with claimed pack mates");
            let own = members.pop().expect("leader is always present");
            return run_solo_member(rt, artifacts, shared, own, Some(token.flag()));
        }
    };
    let remainder: Vec<PackData> = members.split_off(group_r);
    let rem_handles: Vec<Arc<HandleShared<RunOutput>>> = claimed.split_off(group_r - 1);

    let specs: Vec<MemberSpec> = members
        .iter()
        .map(|d| MemberSpec {
            label: d.spec.label.clone(),
            cfg: d.spec.cfg.clone(),
            base: d.spec.base.clone(),
        })
        .collect();
    let group = run_batched_group(rt, &art, &specs, steps);

    let own_yield = match group {
        Err(e) => {
            // Every claimed handle — packed or remainder — fails with
            // the group: their joiners must not hang on a husk.
            let msg = format!("{e:#}");
            for h in claimed.iter().chain(&rem_handles) {
                publish_mate(
                    shared.as_ref(),
                    h,
                    Outcome::Failed(anyhow::anyhow!("batched group failed: {msg}")),
                );
            }
            return Err(e.context("batched group"));
        }
        Ok(outs) => {
            let mut own_yield = None;
            for (i, (m, d)) in outs.into_iter().zip(members.iter()).enumerate() {
                let out = RunOutput {
                    label: m.label,
                    summary: m.summary,
                    stream: StreamStats::default(),
                    sgd_losses: m.sgd_losses,
                    stages: Vec::new(),
                    seconds: m.seconds,
                };
                fold_run_stats(shared.as_ref(), &d.tenant, &out);
                if i == 0 {
                    own_yield = Some(JobYield::Done(out));
                } else {
                    publish_mate(shared.as_ref(), &claimed[i - 1], Outcome::Done(out));
                }
            }
            own_yield.expect("group returns one output per member")
        }
    };

    // Odd remainder: run each claimed-but-unpacked member solo right
    // here, honoring its own cancel flag, and publish to its handle.
    for (d, h) in remainder.into_iter().zip(rem_handles) {
        let cancel = Some(Arc::clone(&h.cancel));
        match run_solo_member(rt, artifacts, shared.as_ref(), d, cancel) {
            Ok(JobYield::Done(out)) => publish_mate(shared.as_ref(), &h, Outcome::Done(out)),
            Ok(JobYield::Cancelled(out)) => {
                publish_mate(shared.as_ref(), &h, Outcome::Cancelled(Some(out)))
            }
            Err(e) => publish_mate(shared.as_ref(), &h, Outcome::Failed(e)),
        }
    }
    Ok(own_yield)
}

impl<R> Drop for RunQueue<R> {
    /// Shutting the queue down cancels everything still **queued** and
    /// *fails* everything still **parked** (so joiners can never hang on
    /// work nobody will run — a parked submission is not "queued work
    /// that never started", it is an interrupted run whose silent loss
    /// would read as success; its park file is deleted either way), lets
    /// in-flight jobs finish, and joins the workers. A job that tries to
    /// park *after* shutdown fails at [`repark_entry`].
    fn drop(&mut self) {
        let leftovers: Vec<Entry<R>> = {
            let mut st = lock(&self.shared.state);
            st.shutdown = true;
            st.paused = false;
            let mut out = Vec::new();
            while let Some((_, mut class)) = st.ready.pop_last() {
                while let Some(e) = class.pop_front() {
                    st.queued -= 1;
                    out.push(e);
                }
            }
            out
        };
        // Held streaming continuations are parked runs waiting for data
        // nobody will ever feed now: fail them with the same loudness
        // as parked entries. Shutdown is already published, so a feed
        // racing this drain either loses the removal (and is a no-op)
        // or wins it and fails the run itself on the shutdown check.
        let held: Vec<Entry<R>> = {
            let mut streams = lock(&self.shared.streams);
            std::mem::take(&mut *streams).into_values().collect()
        };
        self.shared.cv.notify_all();
        self.shared.space_cv.notify_all();
        for e in leftovers.into_iter().chain(held) {
            // Claim Queued/Parked entries with a transient Running (the
            // same exclusivity transition cancel() and the workers use)
            // so a racing claim settles exactly one owner. A lost claim
            // means a husk — individually cancelled, or pack-claimed
            // with its real outcome published by the leader — and
            // shutdown must not clobber it.
            let claimed = lock(&e.handle.state).try_claim();
            match claimed {
                Some(ClaimedFrom::Queued) => {
                    lock(&self.shared.tenants)
                        .entry(e.handle.tenant.clone())
                        .or_default()
                        .cancelled += 1;
                    finish_handle(&self.shared, &e.handle, Outcome::Cancelled(None));
                }
                Some(ClaimedFrom::Parked) => {
                    lock(&self.shared.tenants)
                        .entry(e.handle.tenant.clone())
                        .or_default()
                        .failed += 1;
                    finish_handle(
                        &self.shared,
                        &e.handle,
                        Outcome::Failed(anyhow::anyhow!(
                            "queue dropped while run #{} was parked — its checkpointed \
                             progress is discarded",
                            e.handle.seq
                        )),
                    );
                }
                None => {}
            }
        }
        self.shared.done_cv.notify_all();
        #[cfg(feature = "xla-shared-client")]
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// The caller's side of one submission: poll it, cancel it, or join it.
/// Not cloneable — exactly one owner may consume the result.
pub struct RunHandle<R = RunOutput> {
    handle: Arc<HandleShared<R>>,
    shared: Arc<Shared<R>>,
}

impl<R: 'static> RunHandle<R> {
    /// Submission sequence number (global, monotone): the tiebreak order
    /// within a priority class, and the index [`join_all`] reports the
    /// first error by.
    pub fn seq(&self) -> u64 {
        self.handle.seq
    }

    pub fn tenant(&self) -> &str {
        &self.handle.tenant
    }

    /// Non-blocking status. Never executes work — in inline-drain builds
    /// a queued submission stays `Queued` until something `join`s.
    pub fn poll(&self) -> RunPoll {
        match lock(&self.handle.state).phase() {
            Phase::Queued => RunPoll::Queued,
            Phase::Running => RunPoll::Running,
            Phase::Parked => RunPoll::Parked,
            Phase::Done => RunPoll::Done,
            Phase::Cancelled => RunPoll::Cancelled,
            Phase::Failed => RunPoll::Failed,
            // the completions stream took the outcome (or join did, which
            // also consumes the handle): terminal and delivered.
            Phase::Delivered => RunPoll::Done,
        }
    }

    /// Ask a running park-aware submission to checkpoint and yield its
    /// worker at the next SGD step boundary (a manual preemption; see
    /// [`RunQueue::submit_run`]). Cooperative and advisory: plain-closure
    /// jobs that never read [`CancelToken::park_requested`] ignore it.
    pub fn park(&self) {
        self.handle.park.store(true, Ordering::SeqCst);
    }

    /// Request cancellation. A submission still **queued** or **parked**
    /// is finished `Cancelled` immediately and will never (re)execute —
    /// a parked run's checkpointed state is deleted, since nothing will
    /// resume it. A **running** submission keeps running until its next
    /// step boundary — the cooperative flag is the only signal; nothing
    /// is torn down mid-step.
    pub fn cancel(&self) {
        self.handle.cancel.store(true, Ordering::SeqCst);
        // Claim with a transient Running under the state lock (the same
        // exclusivity transition the workers and pack leaders use) so a
        // racing pop or pack claim settles exactly one owner; the queue
        // entry left behind is a husk the next take_next reaps.
        let claimed = lock(&self.handle.state).try_claim().is_some();
        if claimed {
            lock(&self.shared.tenants)
                .entry(self.handle.tenant.clone())
                .or_default()
                .cancelled += 1;
            finish_handle(&self.shared, &self.handle, Outcome::Cancelled(None));
        }
    }

    /// Block until the submission finishes and return its outcome.
    /// Job errors come back as `Err` with the submission index attached;
    /// cancellation is a normal [`RunResult::Cancelled`], never an error.
    ///
    /// In builds without the thread-safety feature this is also the drain
    /// pump: joining executes queued submissions inline, in priority
    /// order, until this one has finished (see module docs). Joining a
    /// still-**paused** queue there is an error, not a hang: no workers
    /// exist, so nothing could ever run the submission — call
    /// [`RunQueue::release`] first.
    pub fn join(self) -> Result<RunResult<R>> {
        self.drive_inline()?;
        let mut st = lock(&self.handle.state);
        loop {
            if st.is_finished() {
                let Some(outcome) = st.take_outcome() else {
                    // the completions stream claimed it first — each
                    // outcome is delivered exactly once, so this join
                    // came too late by construction, not by timing.
                    anyhow::bail!(
                        "run #{}: outcome already delivered via the completions stream",
                        self.handle.seq
                    );
                };
                return match outcome {
                    Outcome::Done(r) => Ok(RunResult::Done(r)),
                    Outcome::Cancelled(r) => Ok(RunResult::Cancelled(r)),
                    Outcome::Failed(e) => {
                        Err(e.context(format!("queued run #{}", self.handle.seq)))
                    }
                };
            }
            st = self.handle.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    #[cfg(feature = "xla-shared-client")]
    fn drive_inline(&self) -> Result<()> {
        Ok(())
    }

    /// No workers exist in this build: drain ready submissions — highest
    /// priority first, FIFO within a class — on this thread until the
    /// joined one finishes. A still-paused queue is a loud error: this
    /// thread is the only thing that could ever run the submission, so
    /// waiting would deadlock permanently.
    #[cfg(not(feature = "xla-shared-client"))]
    fn drive_inline(&self) -> Result<()> {
        loop {
            if lock(&self.handle.state).is_finished() {
                return Ok(());
            }
            let (entry, paused) = {
                let mut st = lock(&self.shared.state);
                let entry = take_next(&self.shared, &mut st);
                (entry, st.paused)
            };
            match entry {
                Some(e) => run_entry(&self.shared, e),
                None if paused => anyhow::bail!(
                    "join on a paused queue: this build has no worker \
                     threads (xla-shared-client off), so nothing can run \
                     submission #{} until RunQueue::release() is called",
                    self.handle.seq
                ),
                None => {
                    if lock(&self.handle.state).is_finished() {
                        return Ok(());
                    }
                    // The only way an unfinished submission has nothing
                    // runnable behind it is a data-starved streaming
                    // run held in `streams` — and this thread is the
                    // only executor, so waiting would deadlock.
                    anyhow::bail!(
                        "join would hang: streaming run #{} is waiting for data and this \
                         build has no worker threads (xla-shared-client off) — feed() or \
                         finish() its StreamHandle before joining",
                        self.handle.seq
                    )
                }
            }
        }
    }
}

/// Join every handle (in the given order) and return the results, or —
/// if any job failed — the error of the **lowest submission index**,
/// matching `WorkerPool::scatter`'s deterministic error contract.
/// Cancelled submissions are normal results, not errors.
pub fn join_all<R: 'static>(handles: Vec<RunHandle<R>>) -> Result<Vec<RunResult<R>>> {
    let mut out = Vec::with_capacity(handles.len());
    let mut first_err: Option<(u64, anyhow::Error)> = None;
    for h in handles {
        let seq = h.seq();
        match h.join() {
            Ok(r) => out.push(r),
            Err(e) => {
                let lower = match &first_err {
                    None => true,
                    Some((s, _)) => seq < *s,
                };
                if lower {
                    first_err = Some((seq, e));
                }
            }
        }
    }
    match first_err {
        Some((_, e)) => Err(e),
        None => Ok(out),
    }
}

#[cfg(test)]
mod tests {
    //! Queue mechanics only — plain-closure jobs, no xla, no artifacts.
    //! These run (and must hold) in both the gated build (real worker
    //! threads) and the default build (inline drain at `join`); training
    //! runs through the queue live in `rust/tests/sched_queue.rs`.
    use super::*;

    #[test]
    fn priority_pops_highest_first_fifo_within_class() {
        // Cold backlog: everything submitted while the queue is paused,
        // then released — execution order is pure scheduling policy.
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for (name, prio) in [("a0", 0), ("b1", 1), ("c0", 0), ("d1", 1), ("e2", 2)] {
            let order = Arc::clone(&order);
            handles.push(
                q.submit("t", prio, move |_| {
                    lock(&order).push(name);
                    Ok(1usize)
                })
                .unwrap(),
            );
        }
        assert_eq!(q.pending(), 5);
        assert!(handles.iter().all(|h| h.poll() == RunPoll::Queued));
        q.release();
        let results = join_all(handles).unwrap();
        assert_eq!(results.len(), 5);
        assert_eq!(
            *lock(&order),
            vec!["e2", "b1", "d1", "a0", "c0"],
            "highest class first, FIFO within a class"
        );
        assert_eq!(q.pending(), 0);
        let t = q.tenant("t");
        assert_eq!(t.submitted, 5);
        assert_eq!(t.completed, 5);
    }

    #[test]
    fn exactly_once_execution_and_submission_ordered_results() {
        // Hammer the queue with many shuffled-priority submissions:
        // every job runs exactly once and every handle joins to its own
        // job's result, regardless of execution order.
        let n = 200usize;
        let q: RunQueue<usize> = RunQueue::new(4);
        let counts: Arc<Mutex<Vec<u32>>> = Arc::new(Mutex::new(vec![0; n]));
        let mut handles = Vec::new();
        for i in 0..n {
            let counts = Arc::clone(&counts);
            handles.push(
                q.submit("t", (i % 5) as i32, move |_| {
                    lock(&counts)[i] += 1;
                    Ok(i * 3)
                })
                .unwrap(),
            );
        }
        let results = join_all(handles).unwrap();
        let vals: Vec<usize> = results.into_iter().map(|r| r.done().unwrap()).collect();
        assert_eq!(vals, (0..n).map(|i| i * 3).collect::<Vec<_>>());
        assert!(lock(&counts).iter().all(|&c| c == 1), "every job exactly once");
    }

    #[cfg(feature = "xla-shared-client")]
    #[test]
    fn concurrent_submitters_see_exactly_once_and_their_own_results() {
        // Many submitter threads share one queue; each joins only its own
        // handles. No lost wakeups, no cross-talk, exact tenant counts.
        let q = Arc::new(RunQueue::<u64>::new(3));
        let total = Arc::new(std::sync::atomic::AtomicU64::new(0));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let q = Arc::clone(&q);
                let total = Arc::clone(&total);
                s.spawn(move || {
                    let tenant = format!("t{t}");
                    let mut handles = Vec::new();
                    for i in 0..50u64 {
                        let total = Arc::clone(&total);
                        handles.push(
                            q.submit(&tenant, (i % 3) as i32, move |_| {
                                total.fetch_add(1, Ordering::Relaxed);
                                Ok(t * 1000 + i)
                            })
                            .unwrap(),
                        );
                    }
                    let rs = join_all(handles).unwrap();
                    for (i, r) in rs.into_iter().enumerate() {
                        assert_eq!(r.done().unwrap(), t * 1000 + i as u64);
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 200);
        let tenants = q.tenants();
        assert_eq!(tenants.len(), 4);
        for stats in tenants.values() {
            assert_eq!(stats.submitted, 50);
            assert_eq!(stats.completed, 50);
        }
    }

    #[test]
    fn panicking_job_fails_its_handle_instead_of_hanging_joiners() {
        // An unwinding job must not kill a worker with the handle stuck
        // at Running — joins would block forever. The unwind is caught
        // and surfaced as the submission's error; the queue keeps
        // serving later submissions.
        let q: RunQueue<usize> = RunQueue::new(1);
        let bad = q.submit("t", 1, |_| -> Result<usize> { panic!("boom in job") }).unwrap();
        let good = q.submit("t", 0, |_| Ok(5usize)).unwrap();
        let err = bad.join().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("panicked"), "{msg}");
        assert!(msg.contains("boom in job"), "{msg}");
        assert_eq!(good.join().unwrap().done(), Some(5), "queue survives the panic");
        assert_eq!(q.tenant("t").failed, 1);
    }

    #[test]
    fn join_all_reports_the_lowest_submission_index_error() {
        // Parity with WorkerPool::scatter's deterministic error contract.
        let q: RunQueue<usize> = RunQueue::new(2);
        let mut handles = Vec::new();
        for i in 0..16usize {
            handles.push(
                q.submit("t", 0, move |_| {
                    if i == 3 || i == 11 {
                        anyhow::bail!("boom at {i}");
                    }
                    Ok(i)
                })
                .unwrap(),
            );
        }
        let err = join_all(handles).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("queued run #3"), "{msg}");
        assert!(msg.contains("boom at 3"), "{msg}");
        let t = q.tenant("t");
        assert_eq!(t.failed, 2);
        assert_eq!(t.completed, 14);
    }

    #[test]
    fn cancel_before_start_never_runs_the_job() {
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let ran = Arc::new(Mutex::new(false));
        let h = {
            let ran = Arc::clone(&ran);
            q.submit("t", 0, move |_| {
                *lock(&ran) = true;
                Ok(1)
            })
            .unwrap()
        };
        let keeper = q.submit("t", 0, |_| Ok(2usize)).unwrap();
        h.cancel();
        assert_eq!(h.poll(), RunPoll::Cancelled);
        q.release();
        match h.join().unwrap() {
            RunResult::Cancelled(None) => {}
            _ => panic!("cancel-before-start must report Cancelled(None)"),
        }
        assert_eq!(keeper.join().unwrap().done(), Some(2));
        assert!(!*lock(&ran), "cancelled submission must never execute");
        let t = q.tenant("t");
        assert_eq!(t.submitted, 2);
        assert_eq!(t.cancelled, 1);
        assert_eq!(t.completed, 1);
    }

    #[test]
    fn cooperative_cancel_reports_cancelled_with_partial_output() {
        // A job that observes its cancel flag mid-way and stops at its
        // next boundary comes back Cancelled *with* the partial output —
        // the queue-level contract Trainer::run's cooperative flag rides.
        let q: RunQueue<&'static str> = RunQueue::new(1);
        let h = q
            .submit("t", 0, |token| {
                token.flag().store(true, Ordering::SeqCst);
                assert!(token.is_cancelled());
                Ok("partial")
            })
            .unwrap();
        match h.join().unwrap() {
            RunResult::Cancelled(Some("partial")) => {}
            _ => panic!("flagged job must come back Cancelled with output"),
        }
        assert_eq!(q.tenant("t").cancelled, 1);
    }

    #[cfg(not(feature = "xla-shared-client"))]
    #[test]
    fn joining_a_paused_queue_without_workers_errors_instead_of_hanging() {
        // Inline-drain build: the joining thread is the only thing that
        // could ever run the submission, so a paused queue must fail the
        // join loudly rather than deadlock on a condvar nobody signals.
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let h = q.submit("t", 0, |_| Ok(1)).unwrap();
        let err = h.join().unwrap_err();
        assert!(format!("{err:#}").contains("paused"), "{err:#}");
    }

    #[test]
    fn dropping_the_queue_cancels_queued_submissions() {
        // Joiners must never hang on work nobody will run.
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let h = q.submit("t", 0, |_| Ok(7)).unwrap();
        drop(q);
        match h.join().unwrap() {
            RunResult::Cancelled(None) => {}
            _ => panic!("queue drop must cancel still-queued submissions"),
        }
    }

    #[cfg(feature = "xla-shared-client")]
    #[test]
    fn join_never_misses_a_workers_completion() {
        let q: RunQueue<usize> = RunQueue::new(1);
        let h = q
            .submit("t", 0, |_| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                Ok(9)
            })
            .unwrap();
        assert!(matches!(h.poll(), RunPoll::Queued | RunPoll::Running | RunPoll::Done));
        assert_eq!(h.join().unwrap().done(), Some(9));
    }

    #[test]
    fn workers_reports_the_builds_effective_width() {
        let q: RunQueue<usize> = RunQueue::new(3);
        let expected = if crate::sched::threads_enabled() { 3 } else { 0 };
        assert_eq!(q.workers(), expected);
    }

    #[test]
    fn fair_share_alternates_between_tenants_within_a_class() {
        // Cold backlog, one drain lane: tenant alice floods 3 entries
        // before bob's 3 arrive. The deficit rule (all costs zero here,
        // so ties fall to fewest slots picked, then seq) must interleave
        // the tenants rather than draining alice's flood first.
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for name in ["a1", "a2", "a3"] {
            let order = Arc::clone(&order);
            handles.push(
                q.submit("alice", 0, move |_| {
                    lock(&order).push(name);
                    Ok(0usize)
                })
                .unwrap(),
            );
        }
        for name in ["b1", "b2", "b3"] {
            let order = Arc::clone(&order);
            handles.push(
                q.submit("bob", 0, move |_| {
                    lock(&order).push(name);
                    Ok(0usize)
                })
                .unwrap(),
            );
        }
        q.release();
        join_all(handles).unwrap();
        assert_eq!(
            *lock(&order),
            vec!["a1", "b1", "a2", "b2", "a3", "b3"],
            "same-class tenants must round-robin, not drain FIFO"
        );
        assert_eq!(q.tenant("alice").picked, 3);
        assert_eq!(q.tenant("bob").picked, 3);
    }

    #[test]
    fn capacity_full_rejects_until_space_frees() {
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        q.set_capacity(2);
        let h1 = q.submit("t", 0, |_| Ok(1usize)).unwrap();
        let h2 = q.submit("t", 0, |_| Ok(2usize)).unwrap();
        match q.submit("t", 0, |_| Ok(3usize)) {
            Err(SubmitError::Full { capacity: 2 }) => {}
            _ => panic!("third submission must be rejected at capacity 2"),
        }
        assert_eq!(q.live(), 2);
        assert_eq!(q.tenant("t").submitted, 2, "rejected submissions are not counted");
        q.release();
        assert_eq!(h1.join().unwrap().done(), Some(1));
        assert_eq!(h2.join().unwrap().done(), Some(2));
        // joiners can wake a hair before the live counter settles in the
        // threaded build; wait for quiescence before re-probing admission
        while q.live() != 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let h3 = q.submit("t", 0, |_| Ok(3usize)).unwrap();
        assert_eq!(h3.join().unwrap().done(), Some(3));
    }

    #[cfg(feature = "xla-shared-client")]
    #[test]
    fn submit_wait_blocks_for_space_instead_of_rejecting() {
        let q: RunQueue<usize> = RunQueue::new(1);
        q.set_capacity(1);
        let slow = q
            .submit("t", 0, |_| {
                std::thread::sleep(std::time::Duration::from_millis(30));
                Ok(1usize)
            })
            .unwrap();
        assert!(
            matches!(q.submit("t", 0, |_| Ok(0usize)), Err(SubmitError::Full { .. })),
            "plain submit must reject while the slow job holds the only slot"
        );
        let waited = q.submit_wait("t", 0, |_| Ok(2usize)).unwrap();
        assert_eq!(slow.join().unwrap().done(), Some(1));
        assert_eq!(waited.join().unwrap().done(), Some(2));
    }

    #[cfg(not(feature = "xla-shared-client"))]
    #[test]
    fn submit_wait_drains_inline_to_free_space() {
        // No workers exist: submit_wait must run queued work on the
        // calling thread to make room, never block on a condvar nobody
        // signals.
        let q: RunQueue<usize> = RunQueue::new(1);
        q.set_capacity(1);
        let ran = Arc::new(Mutex::new(false));
        let first = {
            let ran = Arc::clone(&ran);
            q.submit("t", 0, move |_| {
                *lock(&ran) = true;
                Ok(1usize)
            })
            .unwrap()
        };
        let second = q.submit_wait("t", 0, |_| Ok(2usize)).unwrap();
        assert!(*lock(&ran), "submit_wait must drain the first job inline");
        assert_eq!(first.join().unwrap().done(), Some(1));
        assert_eq!(second.join().unwrap().done(), Some(2));
    }

    #[test]
    fn zero_quota_rejects_submissions_at_admission() {
        let q: RunQueue<usize> = RunQueue::new(1);
        q.set_quota(
            "greedy",
            TenantQuota { max_flops: Some(0), max_bytes: None, per_window: None },
        );
        match q.submit("greedy", 0, |_| Ok(1usize)) {
            Err(SubmitError::QuotaExceeded { tenant, reason }) => {
                assert_eq!(tenant, "greedy");
                assert!(reason.contains("FLOP budget"), "{reason}");
            }
            _ => panic!("exhausted quota must reject at admission"),
        }
        // a tenant with headroom (or no quota) is unaffected
        q.set_quota(
            "frugal",
            TenantQuota { max_flops: Some(1_000_000), max_bytes: Some(1 << 30), per_window: None },
        );
        let h = q.submit("frugal", 0, |_| Ok(2usize)).unwrap();
        assert_eq!(h.join().unwrap().done(), Some(2));
        assert_eq!(q.tenant("greedy").submitted, 0, "rejected at admission, never counted");
    }

    #[test]
    fn rate_window_rejects_once_spent_and_reports_retry_after() {
        let q: RunQueue<usize> = RunQueue::new(1);
        // 60s window: cannot roll over mid-test, so the rejection below is
        // deterministic regardless of scheduler jitter.
        q.set_quota(
            "bursty",
            TenantQuota {
                max_flops: None,
                max_bytes: None,
                per_window: Some((10_000, u64::MAX, Duration::from_secs(60))),
            },
        );
        // First admission opens the window, baselined at current totals.
        let h = q.submit("bursty", 0, |_| Ok(1usize)).unwrap();
        assert_eq!(h.join().unwrap().done(), Some(1));
        // Spend the window's FLOP budget.
        lock(&q.shared.tenants).entry("bursty".into()).or_default().flops = 50_000;
        match q.submit("bursty", 0, |_| Ok(2usize)) {
            Err(SubmitError::RateLimited { tenant, retry_after }) => {
                assert_eq!(tenant, "bursty");
                assert!(retry_after <= Duration::from_secs(60), "{retry_after:?}");
                assert!(retry_after > Duration::from_secs(30), "{retry_after:?}");
            }
            Ok(_) => panic!("spent window must rate-limit, not admit"),
            Err(other) => panic!("spent window must rate-limit, got {other}"),
        }
        // Another tenant is unaffected.
        let h = q.submit("steady", 0, |_| Ok(3usize)).unwrap();
        assert_eq!(h.join().unwrap().done(), Some(3));
        // Reconfiguring the quota discards the open window: the next
        // admission re-baselines at the already-spent totals and admits.
        q.set_quota(
            "bursty",
            TenantQuota {
                max_flops: None,
                max_bytes: None,
                per_window: Some((10_000, u64::MAX, Duration::from_secs(60))),
            },
        );
        let h = q.submit("bursty", 0, |_| Ok(4usize)).unwrap();
        assert_eq!(h.join().unwrap().done(), Some(4));
    }

    #[test]
    fn rate_window_rolls_over_and_readmits() {
        let q: RunQueue<usize> = RunQueue::new(1);
        q.set_quota(
            "bursty",
            TenantQuota {
                max_flops: None,
                max_bytes: None,
                per_window: Some((10_000, u64::MAX, Duration::from_millis(30))),
            },
        );
        let h = q.submit("bursty", 0, |_| Ok(1usize)).unwrap();
        assert_eq!(h.join().unwrap().done(), Some(1));
        lock(&q.shared.tenants).entry("bursty".into()).or_default().flops = 50_000;
        assert!(
            matches!(q.submit("bursty", 0, |_| Ok(2usize)), Err(SubmitError::RateLimited { .. })),
            "spent window must rate-limit before rollover"
        );
        // Sleep past the window: elapsed >= 30ms is guaranteed, so the next
        // admission rolls the window over (baseline := current totals).
        std::thread::sleep(Duration::from_millis(50));
        let h = q.submit("bursty", 0, |_| Ok(3usize)).unwrap();
        assert_eq!(h.join().unwrap().done(), Some(3));
    }

    #[test]
    fn zero_width_rate_window_rejects_the_first_submission() {
        let q: RunQueue<usize> = RunQueue::new(1);
        q.set_quota(
            "never",
            TenantQuota {
                max_flops: None,
                max_bytes: None,
                per_window: Some((0, 0, Duration::from_secs(60))),
            },
        );
        // The very first admission opens a window with zero spend — and
        // zero spend already meets a zero budget.
        assert!(matches!(
            q.submit("never", 0, |_| Ok(1usize)),
            Err(SubmitError::RateLimited { .. })
        ));
        assert_eq!(q.tenant("never").submitted, 0);
    }

    #[test]
    fn completions_stream_in_completion_order_not_submission_order() {
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let mut seqs = Vec::new();
        for prio in [0i32, 1, 2] {
            seqs.push(q.submit("t", prio, move |_| Ok(prio as usize)).unwrap().seq());
        }
        q.release();
        let mut streamed = Vec::new();
        for c in q.completions() {
            let c = c.unwrap();
            streamed.push((c.seq, c.result.unwrap().done().unwrap()));
        }
        // highest priority runs (and streams) first: submission order
        // 0,1,2 comes back 2,1,0 — nothing waits behind an earlier seq
        assert_eq!(streamed, vec![(seqs[2], 2), (seqs[1], 1), (seqs[0], 0)]);
    }

    #[test]
    fn join_after_stream_delivery_is_a_loud_error() {
        let q: RunQueue<usize> = RunQueue::new(1);
        let h = q.submit("t", 0, |_| Ok(5usize)).unwrap();
        let c = q.next_completion().unwrap().expect("one live submission");
        assert_eq!(c.result.unwrap().done(), Some(5));
        let err = h.join().unwrap_err();
        assert!(format!("{err:#}").contains("already delivered"), "{err:#}");
        assert!(q.next_completion().unwrap().is_none(), "stream is drained");
    }

    /// Submit a job that freezes the queue and parks once, and drive it
    /// to the `Parked` state (inline in the default build; the worker
    /// gets there on its own in the gated build).
    fn park_one(q: &RunQueue<usize>) -> RunHandle<usize> {
        let shared = Arc::clone(&q.shared);
        let h = q
            .submit_boxed(
                "t",
                0,
                Box::new(move |_| {
                    // freeze the queue so the reparked continuation
                    // stays parked instead of resuming immediately
                    lock(&shared.state).paused = true;
                    Ok(JobYield::Parked {
                        next: Box::new(|_| Ok(JobYield::Done(7usize))),
                        front: false,
                    })
                }),
            )
            .unwrap();
        #[cfg(not(feature = "xla-shared-client"))]
        {
            let entry = {
                let mut st = lock(&q.shared.state);
                take_next(&q.shared, &mut st).expect("one entry queued")
            };
            run_entry(&q.shared, entry);
        }
        #[cfg(feature = "xla-shared-client")]
        while h.poll() != RunPoll::Parked {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(h.poll(), RunPoll::Parked);
        h
    }

    #[test]
    fn parked_submission_resumes_and_delivers() {
        let q: RunQueue<usize> = RunQueue::new(1);
        let h = park_one(&q);
        assert_eq!(q.tenant("t").parked, 1);
        assert_eq!(q.live(), 1, "parked stays admitted");
        q.release(); // un-freeze: the continuation resumes and completes
        assert_eq!(h.join().unwrap().done(), Some(7));
        assert_eq!(q.tenant("t").completed, 1);
        assert_eq!(q.tenant("t").picked, 2, "two slots: initial + resumed");
    }

    #[test]
    fn cancelling_a_parked_submission_finishes_it_immediately() {
        let q: RunQueue<usize> = RunQueue::new(1);
        let h = park_one(&q);
        h.cancel();
        assert_eq!(h.poll(), RunPoll::Cancelled);
        assert_eq!(q.live(), 0);
        assert_eq!(q.tenant("t").cancelled, 1);
        q.release();
        match h.join().unwrap() {
            RunResult::Cancelled(None) => {}
            _ => panic!("cancel-while-parked must report Cancelled(None)"),
        }
    }

    #[test]
    fn dropping_the_queue_fails_parked_submissions_instead_of_hanging() {
        // The Drop bugfix this PR ships: a parked entry is an interrupted
        // run, not not-yet-started work — shutdown must fail it loudly
        // (and delete its checkpoint), never leave its joiner hanging or
        // silently report it cancelled-before-start.
        let q: RunQueue<usize> = RunQueue::new(1);
        let h = park_one(&q);
        drop(q);
        let err = h.join().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("parked"), "{msg}");
        assert!(msg.contains("discarded"), "{msg}");
    }

    /// Bespoke submit mirroring [`RunQueue::submit_stream`]'s shape for
    /// plain closures: the job captures its own handle, parks itself
    /// into `shared.streams` on its first slot (what a data-starved
    /// streaming slot does), and completes on its second.
    fn submit_held(q: &RunQueue<usize>) -> RunHandle<usize> {
        let shared = Arc::clone(&q.shared);
        let handle = {
            let mut st = lock(&q.shared.state);
            let handle = Arc::new(HandleShared {
                seq: st.next_seq,
                tenant: "t".to_string(),
                priority: 0,
                cancel: Arc::new(AtomicBool::new(false)),
                park: Arc::new(AtomicBool::new(false)),
                park_file: Arc::new(Mutex::new(None)),
                preemptible: false,
                state: Mutex::new(Lifecycle::new()),
                cv: Condvar::new(),
            });
            st.next_seq += 1;
            let job: Job<usize> = {
                let sh = Arc::clone(&shared);
                let h = Arc::clone(&handle);
                Box::new(move |_| {
                    lock(&h.state).park();
                    let done: Job<usize> = Box::new(|_| Ok(JobYield::Done(7usize)));
                    lock(&sh.streams).insert(h.seq, Entry { job: done, handle: Arc::clone(&h) });
                    Ok(JobYield::Held)
                })
            };
            st.ready.entry(0).or_default().push_back(Entry { job, handle: Arc::clone(&handle) });
            st.queued += 1;
            st.live += 1;
            handle
        };
        lock(&q.shared.tenants).entry("t".to_string()).or_default().submitted += 1;
        q.shared.cv.notify_one();
        RunHandle { handle, shared }
    }

    /// Run the held submission's first slot (inline in the default
    /// build; the worker gets there on its own in the gated build).
    fn drive_to_held(q: &RunQueue<usize>, h: &RunHandle<usize>) {
        #[cfg(not(feature = "xla-shared-client"))]
        {
            let entry = {
                let mut st = lock(&q.shared.state);
                take_next(&q.shared, &mut st).expect("one entry queued")
            };
            run_entry(&q.shared, entry);
        }
        #[cfg(feature = "xla-shared-client")]
        while h.poll() != RunPoll::Parked {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        assert_eq!(h.poll(), RunPoll::Parked);
    }

    #[test]
    fn held_submission_waits_off_queue_and_resumes_on_requeue() {
        let q: RunQueue<usize> = RunQueue::new(1);
        let h = submit_held(&q);
        drive_to_held(&q, &h);
        assert_eq!(q.live(), 1, "held stays admitted");
        assert_eq!(q.pending(), 0, "held is off the ready queue — workers never busy-poll it");
        // What StreamHandle::feed does once data arrives: take the held
        // entry back out and re-enqueue it at the back of its class.
        let entry =
            lock(&q.shared.streams).remove(&h.handle.seq).expect("held entry registered");
        {
            let mut st = lock(&q.shared.state);
            st.ready.entry(entry.handle.priority).or_default().push_back(entry);
            st.queued += 1;
        }
        q.shared.cv.notify_one();
        assert_eq!(h.join().unwrap().done(), Some(7));
        assert_eq!(q.tenant("t").completed, 1);
    }

    #[test]
    fn cancel_racing_a_hold_finishes_the_submission() {
        // A cancel whose claim lost to the running job (flag up, nothing
        // claimed) must be honored when the job holds — run_entry's Held
        // arm reaps the held entry instead of leaving the joiner waiting
        // on a feed that will never matter.
        let q: RunQueue<usize> = RunQueue::new_paused(1);
        let h = submit_held(&q);
        h.handle.cancel.store(true, Ordering::SeqCst);
        q.release();
        match h.join().unwrap() {
            RunResult::Cancelled(None) => {}
            _ => panic!("a cancel racing the hold must finish Cancelled(None)"),
        }
        assert!(lock(&q.shared.streams).is_empty(), "held entry reaped");
        assert_eq!(q.live(), 0);
        assert_eq!(q.tenant("t").cancelled, 1);
    }

    #[cfg(not(feature = "xla-shared-client"))]
    #[test]
    fn joining_a_starved_held_submission_errors_instead_of_hanging() {
        // Inline-drain build: the joining thread is the only executor,
        // and a held stream has nothing runnable until its tenant feeds
        // it — the join must fail loudly, not deadlock.
        let q: RunQueue<usize> = RunQueue::new(1);
        let h = submit_held(&q);
        drive_to_held(&q, &h);
        let err = h.join().unwrap_err();
        assert!(format!("{err:#}").contains("waiting for data"), "{err:#}");
    }

    #[test]
    fn dropping_the_queue_fails_held_streaming_submissions() {
        // Same policy as parked entries: a held stream is an interrupted
        // run whose silent loss would read as success.
        let q: RunQueue<usize> = RunQueue::new(1);
        let h = submit_held(&q);
        drive_to_held(&q, &h);
        drop(q);
        let err = h.join().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("parked"), "{msg}");
        assert!(msg.contains("discarded"), "{msg}");
    }
}
